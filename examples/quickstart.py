"""Quickstart: train a tiny LM for a few steps, then predict what the same
step would cost on a TPU v5e pod — the paper's methodology as a pre-flight.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys

sys.path.insert(0, "src")

import jax

from repro import api
from repro.configs.base import RunConfig, ShapeConfig
from repro.models import get_smoke_config, model_specs
from repro.models.params import abstract_params
from repro.train import train
from repro.train.loop import make_train_step
from repro.train.optimizer import OptimizerConfig


def main() -> None:
    # 1) real training on this machine (smoke-scale llama)
    run = RunConfig(model=get_smoke_config("stablelm-12b"),
                    shape=ShapeConfig("quick", 64, 4, "train"),
                    learning_rate=1e-2)
    res = train(run, num_steps=10, log_every=2)
    print(f"trained {res.steps} steps; "
          f"loss {res.losses[0]:.3f} -> {res.final_loss:.3f}")

    # 2) the paper's contribution: cost the exported step on other hardware
    cfg = run.model
    specs = model_specs(cfg)
    opt_cfg = OptimizerConfig()
    step = make_train_step(cfg, opt_cfg)
    from repro.launch.dryrun import _opt_state_abstract
    from repro.models import input_specs
    params_abs = abstract_params(specs)
    opt_abs = _opt_state_abstract(specs, "adamw", None, None) \
        if False else None
    # export the forward+backward+update graph (single device)
    import jax.numpy as jnp
    from repro.train.optimizer import make_optimizer
    init_fn, _ = make_optimizer(opt_cfg)
    opt_abs = jax.eval_shape(lambda p: init_fn(p, opt_cfg), params_abs)
    batch_abs = input_specs(cfg, run.shape)
    session = api.Session()
    w = session.export(jax.jit(step), params_abs, opt_abs, batch_abs,
                       name="quickstart")
    p = session.predict(w, system="tpu-v5e", estimator="roofline",
                        topology="torus",
                        topology_params={"dims": (16, 16)},
                        slicer="linear")
    print(f"predicted v5e step time: {p.step_time_s*1e6:.1f} us "
          f"({p.num_segments} regions, {p.num_comm} collectives; "
          f"simulated in {p.simulation_wall_s:.2f}s wall)")


if __name__ == "__main__":
    main()
