"""Cross-architecture campaign sweep via the stable ``repro.api`` facade.

One exported workload costed over systems × estimator fidelities ×
slicers in parallel, with a persistent (H, C, R) cache shared across
runs — rerun this script and watch the cache line hit 100 %.

    PYTHONPATH=src python examples/campaign_sweep.py [--arch llama3-100m]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro import api
from repro.campaign.summary import format_table
from repro.configs.base import ShapeConfig
from repro.models import get_config, input_specs, model_specs
from repro.models.params import abstract_params
from repro.models.transformer import forward


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-100m")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--executor", default="thread",
                    choices=("serial", "thread", "process"))
    ap.add_argument("--out", default="artifacts/campaign_sweep")
    ap.add_argument("--cache", default="artifacts/campaign_sweep/hcr.json")
    ap.add_argument("--systems", action="append", default=[],
                    help="extra system-catalog file/dir (JSON records)")
    args = ap.parse_args()

    session = api.Session(systems=args.systems, cache_path=args.cache)

    cfg = get_config(args.arch)
    shape = ShapeConfig("sweep", args.seq, args.batch, "train")
    w = session.export(
        jax.jit(lambda p, b: forward(cfg, p, b)),
        abstract_params(model_specs(cfg)), input_specs(cfg, shape),
        name=args.arch)

    # the workload is provided in-memory below, so its spec is name-only
    result = session.campaign(
        {
            "name": f"sweep-{args.arch}",
            "workloads": [{"name": args.arch}],
            "systems": ["a100", "h100", "b200", "tpu-v5e"],
            "estimators": [
                {"kind": "roofline"},
                {"kind": "roofline", "fidelity": "raw",
                 "options": {"mode": "per-op", "include_overheads": True}},
                {"kind": "mixed", "options": {"preset": "cocossim"}},
            ],
            "slicers": ["linear", "dep"],
        },
        workloads={args.arch: w}, out_dir=args.out,
        executor=args.executor)
    print(format_table(result.summary))
    print(f"rows: {result.csv_path}")


if __name__ == "__main__":
    main()
