"""Batched serving example: greedy decode with a KV cache.

    PYTHONPATH=src python examples/serve_decode.py
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.models import get_smoke_config, model_specs
from repro.models.params import init_params
from repro.serve import greedy_decode


def main() -> None:
    cfg = get_smoke_config("mixtral-8x22b")   # MoE decode path
    params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
    prompt = jnp.array([[5, 17, 42, 7], [9, 3, 3, 1]], jnp.int32)
    res = greedy_decode(cfg, params, prompt, max_new_tokens=12, max_len=32)
    print("generated token ids:")
    for row in res.tokens:
        print("  ", list(map(int, row)))


if __name__ == "__main__":
    main()
