"""Cross-architecture what-if analysis: one exported workload, costed on
five systems × two estimator fidelities — the heart of the paper.

    PYTHONPATH=src python examples/perf_predict.py [--arch llama3-100m]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro import api
from repro.campaign.spec import TopologySpec
from repro.models import get_config, input_specs, model_specs
from repro.models.params import abstract_params
from repro.models.transformer import forward
from repro.configs.base import ShapeConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-100m")
    ap.add_argument("--seq", type=int, default=512)
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    session = api.Session()
    cfg = get_config(args.arch)
    shape = ShapeConfig("whatif", args.seq, args.batch, "train")
    params_abs = abstract_params(model_specs(cfg))
    batch_abs = input_specs(cfg, shape)
    w = session.export(jax.jit(lambda p, b: forward(cfg, p, b)),
                       params_abs, batch_abs, name=args.arch)
    plan = session.plan(w, slicer="linear")

    print(f"{'system':12s} {'roofline':>12s} {'systolic+roofline':>18s}")
    for name in ("a100", "h100", "b200", "tpu-v3", "tpu-v5e"):
        tspec = TopologySpec.from_dict(
            {"kind": "torus", "params": {"dims": [2, 2]}} if "tpu" in name
            else {"kind": "a2a", "params": {"num_devices": 4}})
        ana = session.predict(plan, system=name, estimator="roofline",
                              topology=tspec).step_time_s
        sysl = session.predict(plan, system=name, estimator="mixed",
                               options={"preset": "cocossim"},
                               topology=tspec).step_time_s
        print(f"{name:12s} {ana*1e3:10.2f}ms {sysl*1e3:16.2f}ms")


if __name__ == "__main__":
    main()
