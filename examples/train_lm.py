"""End-to-end driver: train a ~100M-parameter llama-style LM for a few
hundred steps with checkpointing and fault-tolerant restart.

    PYTHONPATH=src python examples/train_lm.py --steps 300 --inject-failure
(defaults to 30 steps so the example finishes quickly on CPU; pass
--steps 300 for the full run)
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.configs.base import RunConfig, ShapeConfig
from repro.models import get_config
from repro.train import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt")
    ap.add_argument("--inject-failure", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_config("llama3-100m").scaled(remat="none")
    run = RunConfig(model=cfg,
                    shape=ShapeConfig("train", args.seq, args.batch,
                                      "train"),
                    learning_rate=3e-4)
    res = train(run, num_steps=args.steps, checkpoint_dir=args.ckpt,
                checkpoint_every=10, resume=args.resume, log_every=10,
                inject_failure_at=args.steps // 2
                if args.inject_failure else None)
    print(f"done: {res.steps} steps, {res.restarts} restarts, "
          f"final loss {res.final_loss:.4f}, "
          f"median step {sorted(res.step_times)[len(res.step_times)//2]*1e3:.0f} ms")


if __name__ == "__main__":
    main()
