"""Talk to the warm prediction daemon: boot `python -m repro.serve`,
then ask what-if questions over HTTP without ever paying cold start
again.

Self-contained — boots its own daemon on an ephemeral port with the
Fig 10 GEMM spec preloaded, runs a few predictions and a streamed
campaign through :class:`repro.serve.client.ServeClient`, prints the
daemon's warm-state counters, and shuts it down gracefully.

    PYTHONPATH=src python examples/serve_client.py

Point ``--url`` at an already-running daemon to skip the boot.
See docs/serving.md for the endpoint reference.
"""
import argparse
import json
import os
import subprocess
import sys

sys.path.insert(0, "src")

from repro.serve.client import ServeClient

SPEC = os.path.join("specs", "fig10_gemm.json")


def boot_daemon() -> tuple[subprocess.Popen, str]:
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.serve", "--port", "0",
         "--preload", SPEC],
        env=env, stdout=subprocess.PIPE, text=True)
    url = json.loads(proc.stdout.readline())["url"]  # first stdout line
    return proc, url


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--url", default=None,
                    help="existing daemon URL (default: boot one)")
    args = ap.parse_args()

    daemon = None
    if args.url:
        url = args.url
    else:
        daemon, url = boot_daemon()
        print(f"booted daemon at {url}")

    client = ServeClient(url)
    client.wait_ready()

    # --- single predictions: preloaded workloads are already planned ---
    print(f"\n{'workload':12s} {'preset':10s} {'step time':>12s}")
    for preset in ("onnxim", "scalesim"):
        row = client.predict("gemm-1024", system="tpu-v3",
                             estimator={"kind": "systolic",
                                        "options": {"preset": preset}})
        print(f"{row['workload']:12s} {preset:10s} "
              f"{row['step_time_s']*1e6:10.2f}us")

    # a workload the daemon has never seen ships its own source
    row = client.predict(
        {"name": "whatif-2048", "fidelity": "raw",
         "gemm": {"m": 2048, "n": 2048, "k": 2048, "dtype": "bf16"}},
        system="tpu-v3", estimator="roofline")
    print(f"{row['workload']:12s} {'roofline':10s} "
          f"{row['step_time_s']*1e6:10.2f}us")

    # --- a streamed campaign: rows arrive as jobs finish ---
    stream = client.campaign(spec_path=os.path.abspath(SPEC))
    rows, summary = stream.collect()
    print(f"\ncampaign {summary['campaign']}: {len(rows)} rows, "
          f"{summary['num_failed']} failed")

    # --- the daemon's warm state, by the numbers ---
    st = client.stats()
    print(f"stats: {st['predict']['served']} predicts "
          f"({st['predict']['cache_hits']} cache hits, "
          f"{st['predict']['duplicate_cold_misses']} duplicate cold "
          f"misses), plans resident {st['plans']['resident']}, "
          f"parse calls {st['plans']['parse_calls']}")

    if daemon is not None:
        client.shutdown()
        daemon.wait(timeout=30)
        print("daemon drained and exited")


if __name__ == "__main__":
    main()
