"""Collective cost models + event-driven scheduler, incl. hypothesis
property tests on scheduler invariants."""
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based tests need the hypothesis dev dependency "
           "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.ir.collectives import CommSpec
from repro.core.network import (AllToAllNode, Dragonfly, MultiPod, Torus,
                                collective_time, simulate)
from repro.core.trace import Trace


def mk_spec(kind, size, g):
    return CommSpec(kind=kind, bytes_in=size, bytes_out=size,
                    group_size=g, num_groups=1)


class TestCollectiveModels:
    def test_all_reduce_ring_formula(self):
        topo = AllToAllNode(num_devices=8, link_bw=100e9, link_latency=0)
        t = collective_time(mk_spec("all_reduce", 1e9, 8), topo)
        # ring: 2*(g-1)/g * S / (2*B bidirectional)
        expected = 2 * 7 / 8 * 1e9 / (2 * 100e9)
        assert t == pytest.approx(expected, rel=1e-6)

    def test_all_gather_half_of_all_reduce(self):
        topo = Torus(dims=(4, 4), link_latency=0)
        ar = collective_time(mk_spec("all_reduce", 1e8, 16), topo)
        ag = collective_time(mk_spec("all_gather", 1e8, 16), topo)
        assert ag == pytest.approx(ar / 2, rel=1e-6)

    def test_group_of_one_free(self):
        topo = Torus()
        assert collective_time(mk_spec("all_reduce", 1e9, 1), topo) == 0.0

    def test_compression_scales_payload(self):
        topo = Torus(link_latency=0)
        full = collective_time(mk_spec("all_reduce", 1e9, 16), topo)
        quart = collective_time(mk_spec("all_reduce", 1e9, 16), topo,
                                compression=0.25)
        assert quart == pytest.approx(full / 4, rel=1e-6)

    def test_hierarchical_dragonfly_slower_than_intranode(self):
        topo = Dragonfly(num_nodes=8, gpus_per_node=4)
        intra = collective_time(mk_spec("all_reduce", 1e8, 4), topo)
        inter = collective_time(mk_spec("all_reduce", 1e8, 32), topo)
        assert inter > intra

    def test_multipod_dcn_bottleneck(self):
        topo = MultiPod(pod=Torus(dims=(16, 16)), num_pods=2)
        in_pod = collective_time(mk_spec("all_reduce", 1e8, 256), topo)
        x_pod = collective_time(mk_spec("all_reduce", 1e8, 512), topo)
        assert x_pod > in_pod


def _chain_trace(durs, comm_every=0):
    t = Trace()
    prev = None
    for i, d in enumerate(durs):
        deps = [prev] if prev is not None else []
        if comm_every and i % comm_every == comm_every - 1:
            prev = t.add_comm("all_reduce", 1e6, 4, deps=deps)
        else:
            prev = t.add_comp(f"c{i}", d * 1e6, deps=deps)
    return t


class TestScheduler:
    def test_serial_chain_sums(self):
        t = _chain_trace([1.0, 2.0, 3.0])
        res = simulate(t, Torus())
        assert res.makespan_s == pytest.approx(6.0, rel=1e-6)

    def test_straggler_scales_comm_only(self):
        t = _chain_trace([1.0] * 4, comm_every=2)
        base = simulate(t, Torus(), straggler_factor=1.0)
        slow = simulate(t, Torus(), straggler_factor=3.0)
        assert slow.comm_busy_s == pytest.approx(3 * base.comm_busy_s)
        assert slow.compute_busy_s == pytest.approx(base.compute_busy_s)

    def test_overlap_no_worse_than_serial(self):
        t = Trace()
        a = t.add_comp("a", 100.0)
        c = t.add_comm("all_reduce", 1e8, 8, deps=[a])
        b = t.add_comp("b", 100.0, deps=[a])   # independent of the comm
        t.add_comp("join", 1.0, deps=[b, c])
        serial = simulate(t, Torus(), overlap=False)
        over = simulate(t, Torus(), overlap=True)
        assert over.makespan_s <= serial.makespan_s
        assert over.exposed_comm_s < serial.exposed_comm_s + 1e-12

    def test_cycle_detection(self):
        t = Trace()
        t.add_comp("a", 1.0)
        t.nodes[0].data_deps = [0]
        with pytest.raises(ValueError):
            t.validate()

    @settings(max_examples=40, deadline=None)
    @given(durs=st.lists(st.floats(0.001, 10.0), min_size=1, max_size=24),
           overlap=st.booleans(),
           straggler=st.floats(1.0, 4.0))
    def test_makespan_bounds(self, durs, overlap, straggler):
        """Property: max(comp node) <= makespan <= sum(all nodes)."""
        t = _chain_trace(durs, comm_every=3)
        res = simulate(t, Torus(), overlap=overlap,
                       straggler_factor=straggler)
        comp_durs = [n.duration_us * 1e-6 for n in t.nodes
                     if n.node_type == "COMP_NODE"]
        total = res.compute_busy_s + res.comm_busy_s
        assert res.makespan_s <= total + 1e-9
        if comp_durs:
            assert res.makespan_s >= max(comp_durs) - 1e-9
        assert res.exposed_comm_s >= -1e-12

    @settings(max_examples=25, deadline=None)
    @given(size=st.floats(1e3, 1e12), g=st.integers(2, 512))
    def test_collective_time_monotone_in_size(self, size, g):
        topo = Torus(dims=(32, 32))
        t1 = collective_time(mk_spec("all_reduce", size, g), topo)
        t2 = collective_time(mk_spec("all_reduce", size * 2, g), topo)
        assert t2 >= t1
        assert t1 > 0
