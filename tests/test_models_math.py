"""Numerical properties of model components beyond smoke coverage."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based tests need the hypothesis dev dependency "
           "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models.attention import (AttnArgs, _chunked_attention,
                                    _dense_attention)
from repro.models.common import rms_norm, softcap
from repro.models.mlp import moe_forward
from repro.models.rope import apply_mrope, apply_rope
from repro.models.ssm import _causal_conv, ssd_chunked
from repro.kernels.ssd_scan.ref import ssd_ref


class TestAttentionImpls:
    @pytest.mark.parametrize("causal,window", [(True, 0), (True, 64),
                                               (False, 0)])
    def test_chunked_equals_dense(self, causal, window):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 4, 128, 32), jnp.float32)
        k = jax.random.normal(ks[1], (2, 2, 128, 32), jnp.float32)
        v = jax.random.normal(ks[2], (2, 2, 128, 32), jnp.float32)
        args = AttnArgs(causal=causal, window=window)
        dense = _dense_attention(q, k, v, args)
        chunked = _chunked_attention(q, k, v, args, chunk=32)
        np.testing.assert_allclose(dense, chunked, atol=2e-5, rtol=2e-5)

    def test_chunked_handles_padding(self):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 100, 32), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 100, 32), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 100, 32), jnp.float32)
        args = AttnArgs(causal=True)
        dense = _dense_attention(q, k, v, args)
        chunked = _chunked_attention(q, k, v, args, chunk=64)  # pad to 128
        np.testing.assert_allclose(dense, chunked, atol=2e-5, rtol=2e-5)

    def test_dynamic_window_matches_static(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 64, 16), jnp.float32)
        k, v = q, q
        stat = _dense_attention(q, k, v, AttnArgs(causal=True, window=16))
        dyn = _dense_attention(q, k, v,
                               AttnArgs(causal=True, window=jnp.int32(16)))
        np.testing.assert_allclose(stat, dyn, atol=1e-6)


class TestRope:
    def test_rope_preserves_norm(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
        y = apply_rope(x, pos)
        np.testing.assert_allclose(
            jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1),
            rtol=1e-5)

    def test_rope_relative_property(self):
        """<rope(q,i), rope(k,j)> depends only on i-j."""
        q = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 64))
        k = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 64))

        def score(i, j):
            qr = apply_rope(q, jnp.full((1, 1), i))
            kr = apply_rope(k, jnp.full((1, 1), j))
            return float(jnp.sum(qr * kr))
        assert score(5, 3) == pytest.approx(score(10, 8), rel=1e-4)

    def test_mrope_matches_rope_for_equal_streams(self):
        x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 4, 32))
        pos = jnp.broadcast_to(jnp.arange(16), (2, 16))
        pos3 = jnp.stack([pos, pos, pos])
        a = apply_rope(x, pos)
        b = apply_mrope(x, pos3, (8, 4, 4))
        np.testing.assert_allclose(a, b, atol=1e-5)


class TestMoE:
    def _cfg(self, **kw):
        from repro.models import get_smoke_config
        return get_smoke_config("mixtral-8x22b").scaled(**kw)

    def test_output_finite_and_shaped(self):
        from repro.models.mlp import moe_specs
        from repro.models.params import init_params
        cfg = self._cfg()
        p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                              jnp.bfloat16)
        y = moe_forward(cfg, p, x)
        assert y.shape == x.shape
        assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))

    def test_capacity_drop_is_graceful(self):
        """With capacity factor << 1 most tokens drop, output stays finite
        and small."""
        from dataclasses import replace
        from repro.models.mlp import moe_specs
        from repro.models.params import init_params
        cfg = self._cfg()
        cfg = cfg.scaled(moe=replace(cfg.moe, capacity_factor=0.01))
        p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model),
                              jnp.bfloat16)
        y = moe_forward(cfg, p, x)
        assert bool(jnp.all(jnp.isfinite(y.astype(jnp.float32))))

    def test_flops_scale_with_topk_not_experts(self):
        """Sort-based dispatch: HLO flops track k·tokens, not E·tokens."""
        from repro.core.ir import parse, program_cost
        from repro.models.mlp import moe_specs
        from repro.models.params import abstract_params

        def flops_for(n_experts):
            from dataclasses import replace
            cfg = self._cfg()
            cfg = cfg.scaled(moe=replace(cfg.moe, num_experts=n_experts,
                                         capacity_factor=1.0))
            specs = moe_specs(cfg)
            pa = abstract_params(specs)
            xa = jax.ShapeDtypeStruct((2, 128, cfg.d_model), jnp.bfloat16)
            txt = jax.jit(lambda p, x: moe_forward(cfg, p, x)).lower(
                pa, xa).as_text()
            return program_cost(parse(txt)).flops

        f4, f8 = flops_for(4), flops_for(8)
        # doubling experts must NOT double compute (one-hot dispatch would)
        assert f8 < 1.5 * f4


class TestSSM:
    def test_causal_conv_is_causal(self):
        x = jnp.zeros((1, 16, 4)).at[0, 8, :].set(1.0)
        w = jnp.ones((4, 4))
        b = jnp.zeros((4,))
        y = _causal_conv(x, w, b)
        assert float(jnp.abs(y[0, :5]).sum()) == 0.0  # nothing before t=8-3

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 1000))
    def test_ssd_chunked_matches_sequential(self, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 5)
        b, s, h, p, g, n = 1, 64, 2, 8, 1, 4
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        bi = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
        ci = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
        y, st_ = ssd_chunked(x, dt, a, bi, ci, chunk=16)
        yr, sr = ssd_ref(x, dt, a, bi, ci)
        np.testing.assert_allclose(y, yr, atol=3e-3, rtol=3e-3)
        np.testing.assert_allclose(st_, sr, atol=3e-3, rtol=3e-3)


class TestNumerics:
    def test_softcap_bounded(self):
        x = jnp.array([-1e9, -1.0, 0.0, 1.0, 1e9])
        y = softcap(x, 30.0)
        assert bool(jnp.all(jnp.abs(y) <= 30.0))
        np.testing.assert_allclose(softcap(x, 0.0), x)

    def test_rms_norm_unit_scale(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (8, 64)) * 100
        y = rms_norm(x, jnp.ones(64))
        rms = jnp.sqrt(jnp.mean(y.astype(jnp.float32) ** 2, axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-2)
