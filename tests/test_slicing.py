"""Slicer invariants: coverage, alternation, dependency soundness."""
import pytest

from repro.core.ir import parse
from repro.core.ir.graph import ZERO_COST_OPS
from repro.core.slicing import (dependency_aware_split, linear_split,
                                region_fingerprint)
from tests.test_ir_parser import CANNED_HLO


@pytest.fixture(scope="module")
def prog():
    return parse(CANNED_HLO)


class TestLinearSplit:
    def test_alternation_and_counts(self, prog):
        segs = linear_split(prog)
        comm = [s for s in segs if s.kind == "COMM"]
        assert len(comm) == 1
        assert comm[0].repeat == 12            # inside the while body

    def test_flop_conservation(self, prog):
        """Sum of region flops × repeat == whole-program flops."""
        from repro.core.ir import program_cost
        segs = linear_split(prog)
        total = sum(s.region.cost.flops * s.repeat
                    for s in segs if s.kind == "COMP")
        assert total == pytest.approx(program_cost(prog).flops, rel=1e-6)

    def test_repeat_groups_share_group_id(self, prog):
        segs = linear_split(prog)
        in_loop = [s for s in segs if s.repeat == 12]
        assert in_loop and len({s.group for s in in_loop}) == 1


class TestDependencyAwareSplit:
    def test_acyclic_and_forward(self, prog):
        segs, deps = dependency_aware_split(prog)
        for idx, dset in deps.items():
            for d in dset:
                assert d < idx, "dependency edges must point backwards"

    def test_loop_iterations_serialized(self, prog):
        """Each unrolled iteration must depend (transitively) on the
        previous one — otherwise the scheduler could overlap iterations."""
        segs, deps = dependency_aware_split(prog)
        comm_idx = [i for i, s in enumerate(segs) if s.kind == "COMM"]
        assert len(comm_idx) == 12             # unrolled
        reach: dict[int, set[int]] = {}
        for i in range(len(segs)):
            r = set(deps.get(i, set()))
            for d in deps.get(i, set()):
                r |= reach.get(d, set())
            reach[i] = r
        for a, b in zip(comm_idx[:-1], comm_idx[1:]):
            assert a in reach[b], f"comm {b} does not depend on comm {a}"

    def test_flop_conservation(self, prog):
        from repro.core.ir import program_cost
        segs, _ = dependency_aware_split(prog)
        total = sum(s.region.cost.flops for s in segs if s.kind == "COMP")
        assert total == pytest.approx(program_cost(prog).flops, rel=1e-6)


class TestFingerprint:
    def test_identical_regions_share_fingerprint(self, prog):
        segs, _ = dependency_aware_split(prog)
        fps = [s.region.fingerprint for s in segs if s.kind == "COMP"
               and s.region.cost.flops > 0]
        # 12 unrolled iterations of an identical body
        assert len(fps) >= 12
        assert len(set(fps)) < len(fps)

    def test_fingerprint_distinguishes_shapes(self):
        from repro.core.ir.graph import OpNode
        from repro.core.ir.types import TensorType

        def mk(shape):
            t = TensorType(shape, "f32")
            return [OpNode(uid=1, results=("%a",), op="dot_general",
                           operands=("%x", "%y"), operand_types=(t, t),
                           result_types=(t,))]
        assert region_fingerprint(mk((4, 4))) != region_fingerprint(mk((8, 8)))


class TestBarrierSplitting:
    def test_barrier_splits_regions(self):
        import jax
        import jax.numpy as jnp

        def f(x):
            for _ in range(3):
                x = jax.lax.optimization_barrier(jnp.tanh(x @ x))
            return x
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 32), jnp.float32)).as_text()
        prog = parse(txt)
        segs = linear_split(prog)
        comp = [s for s in segs if s.kind == "COMP"]
        assert len(comp) == 3
        fps = {s.region.fingerprint for s in comp}
        assert len(fps) == 1                   # identical layer regions
