"""Differential campaign test: new cold path vs legacy, bit for bit.

PR 7's rewrite (streaming front end + vectorized grid evaluation) is
behavior-preserving by construction; this suite proves it at the level
users observe — a real checked-in grid (``specs/fig10_gemm.json``) run
end-to-end through both paths must produce *bit-identical* result rows,
wall-clock fields excluded.  The same exactness is wired into
``report --check``: its golden comparison now reports the observed
``max_drift`` (expected exactly 0 on the recording machine) alongside
the tolerance note explaining that the tolerance absorbs cross-platform
float variance only.
"""
import os

import pytest

import repro.core.ir.parser as parser_mod
import repro.core.pipeline as pipeline_mod
from repro.campaign import CampaignSpec, run_campaign
from repro.campaign.report import (check_rows, golden_path, load_json,
                                   make_golden)

SPEC_PATH = os.path.join(os.path.dirname(__file__), os.pardir,
                         "specs", "fig10_gemm.json")

#: fields that measure the runner, not the prediction — everything else
#: must match bit for bit between the legacy and vectorized paths
WALL_FIELDS = {"job_wall_s", "simulation_wall_s",
               "cache_saved_s", "cache_miss_cost_s"}


def _strip(row: dict) -> dict:
    return {k: v for k, v in row.items() if k not in WALL_FIELDS}


def _run_fig10(frontend: str, vectorize: bool) -> list[dict]:
    mp = pytest.MonkeyPatch()
    try:
        mp.setattr(parser_mod, "DEFAULT_FRONTEND", frontend)
        mp.setattr(pipeline_mod, "DEFAULT_VECTORIZE", vectorize)
        res = run_campaign(CampaignSpec.from_json(SPEC_PATH),
                           executor="serial")
    finally:
        mp.undo()
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    return res.rows


@pytest.fixture(scope="module")
def fig10_rows():
    return {
        "legacy": _run_fig10("legacy", vectorize=False),
        "new": _run_fig10("streaming", vectorize=True),
    }


class TestFig10Differential:
    def test_rows_bit_identical(self, fig10_rows):
        legacy, new = fig10_rows["legacy"], fig10_rows["new"]
        assert len(legacy) == len(new) and len(new) > 0
        for lr, nr in zip(legacy, new):
            assert _strip(lr) == _strip(nr)   # == on floats: bit-identity

    def test_wall_fields_present_but_excluded(self, fig10_rows):
        # the exclusion list must actually name row fields — a renamed
        # counter would silently widen the bit-identity claim
        row = fig10_rows["new"][0]
        assert {"job_wall_s", "simulation_wall_s"} <= set(row)

    def test_new_path_matches_checked_in_golden(self, fig10_rows):
        """The acceptance bar: the checked-in golden snapshot (recorded
        pre-rewrite) must pass with zero drift on the new path."""
        golden = load_json(golden_path(SPEC_PATH, "fig10-gemm"))
        assert golden is not None, "specs/golden/fig10-gemm.json missing"
        check = check_rows(golden, fig10_rows["new"])
        assert check["failures"] == []
        assert check["rows_checked"] == len(golden["rows"])
        assert check["max_drift"] == 0.0

    def test_check_rows_reports_tolerance_note(self, fig10_rows):
        golden = make_golden("fig10_gemm", fig10_rows["legacy"])
        check = check_rows(golden, fig10_rows["new"])
        assert check["failures"] == []
        assert check["max_drift"] == 0.0
        assert any("bit-identical" in n for n in check.get("notes", []))
