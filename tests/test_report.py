"""The campaign report subsystem: rank statistics (Kendall-τ /
Spearman-ρ), MAPE against recorded reference rows, fidelity-comparison
tables, golden-prediction snapshots (drift + grid-shape + rank-inversion
gates), the ``report`` CLI, and the paired-axis fig9 grid's parity with
its pre-port in-script campaign."""
import json
import os

import pytest

from repro.campaign import CampaignSpec, run_campaign
from repro.campaign import report as rpt
from repro.campaign.__main__ import main as campaign_main

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPECS = os.path.join(REPO, "specs")

#: the five checked-in paper grids and their campaign names
CHECKED_IN = {
    "fig6_gpu.json": "fig6-gpu",
    "fig7_resnet.json": "fig7-resnet",
    "fig9_scaleout.json": "fig9-scaleout",
    "fig10_gemm.json": "fig10-gemm",
    "fig11_tpu.json": "fig11-tpu",
}


def _row(workload, system, estimator, step, job_id=0, **over):
    r = {"job_id": job_id, "workload": workload, "fidelity": "raw",
         "system": system, "estimator": estimator, "slicer": "linear",
         "topology": "a2a1", "overlap": False, "straggler_factor": 1.0,
         "compression": 1.0, "step_time_s": step, "compute_s": step,
         "comm_s": 0.0, "exposed_comm_s": 0.0, "num_segments": 1,
         "num_comm": 0}
    r.update(over)
    return r


# ----------------------------- rank statistics -----------------------------


class TestRankStats:
    def test_kendall_tau_perfect_and_inverted(self):
        assert rpt.kendall_tau([1, 2, 3, 4], [10, 20, 30, 40]) == 1.0
        assert rpt.kendall_tau([1, 2, 3, 4], [40, 30, 20, 10]) == -1.0

    def test_kendall_tau_one_swap(self):
        # 5 concordant, 1 discordant pair of 6 -> tau = 4/6
        assert rpt.kendall_tau([1, 2, 3, 4], [1, 3, 2, 4]) \
            == pytest.approx(4 / 6)

    def test_kendall_tau_degenerate(self):
        assert rpt.kendall_tau([], []) == 0.0
        assert rpt.kendall_tau([1.0], [2.0]) == 0.0
        assert rpt.kendall_tau([1, 1, 1], [1, 2, 3]) == 0.0  # all ties in x

    def test_kendall_tau_tie_correction(self):
        # x has one tied pair: tau_b denominator shrinks accordingly
        tau = rpt.kendall_tau([1, 1, 2], [1, 2, 3])
        assert tau == pytest.approx(2 / (3 * 2) ** 0.5 / 1)
        import math
        assert tau == pytest.approx(2 / math.sqrt(2 * 3))

    def test_spearman_perfect_monotone_nonlinear(self):
        # rho is rank-based: any monotone map preserves 1.0
        assert rpt.spearman_rho([1, 2, 3, 4], [1, 8, 27, 1000]) == \
            pytest.approx(1.0)
        assert rpt.spearman_rho([1, 2, 3], [9, 4, 1]) == pytest.approx(-1.0)

    def test_spearman_ties_averaged(self):
        assert rpt._ranks([10.0, 20.0, 20.0, 30.0]) == [1.0, 2.5, 2.5, 4.0]

    def test_length_mismatch_raises(self):
        with pytest.raises(ValueError):
            rpt.kendall_tau([1], [1, 2])
        with pytest.raises(ValueError):
            rpt.spearman_rho([1], [1, 2])


# ----------------------------- report sections -----------------------------


def _two_estimator_rows(invert_on_h100=False):
    """Two workloads × two systems × two estimators.  Estimator `b` is
    uniformly 2× slower; with ``invert_on_h100`` it inverts the system
    ordering for workload w1."""
    rows, jid = [], 0
    for w, base in (("w1", 1.0), ("w2", 4.0)):
        for s, factor in (("a100", 1.0), ("h100", 0.5)):
            for est, scale in (("ana", 1.0), ("b", 2.0)):
                step = base * factor * scale
                if invert_on_h100 and (w, s, est) == ("w1", "h100", "b"):
                    step = base * 3.0  # slower than its a100 sibling
                rows.append(_row(w, s, est, step, job_id=jid))
                jid += 1
    return rows


class TestReportSections:
    def test_mape_hand_computed(self):
        rows = [_row("w", "a100", "ana", 1.1, job_id=0),
                _row("w", "a100", "prof", 0.8, job_id=1)]
        reference = {"source": "unit", "rows": [
            {"workload": "w", "system": "a100", "step_time_s": 1.0}]}
        acc = rpt.mape_against_reference(rows, reference)
        assert acc["reference_source"] == "unit"
        assert acc["mape_pct"]["ana"]["overall"] == pytest.approx(10.0)
        assert acc["mape_pct"]["prof"]["overall"] == pytest.approx(20.0)
        assert acc["mape_pct"]["ana"]["matched_rows"] == 1

    def test_mape_skips_unmatched_rows(self):
        rows = [_row("w", "a100", "ana", 1.0), _row("x", "a100", "ana", 9.9)]
        acc = rpt.mape_against_reference(rows, {"rows": [
            {"workload": "w", "system": "a100", "step_time_s": 1.0}]})
        assert acc["mape_pct"]["ana"]["matched_rows"] == 1
        assert acc["mape_pct"]["ana"]["overall"] == 0.0

    def test_rank_preservation_preserved(self):
        rp = rpt.rank_preservation(_two_estimator_rows())
        assert rp["all_trends_preserved"] is True
        assert rp["min_kendall_tau"] == 1.0
        assert rp["systems"]["w1"]["ana vs b"]["kendall_tau"] == 1.0
        assert rp["workloads"]["a100"]["ana vs b"]["spearman_rho"] == 1.0

    def test_rank_preservation_detects_inversion(self):
        rp = rpt.rank_preservation(_two_estimator_rows(invert_on_h100=True))
        assert rp["all_trends_preserved"] is False
        assert rp["systems"]["w1"]["ana vs b"]["kendall_tau"] == -1.0

    def test_trend_orderings(self):
        t = rpt.trend_orderings(_two_estimator_rows())
        assert t["systems"]["w1"]["ana"] == ["h100", "a100"]
        assert t["workloads"]["h100"]["b"] == ["w1", "w2"]

    def test_reference_estimator_is_lowest_job_id(self):
        rows = [_row("w", "a100", "zzz", 1.0, job_id=0),
                _row("w", "a100", "aaa", 2.0, job_id=1)]
        assert rpt.reference_estimator(rows) == "zzz"

    def test_fidelity_table_ratios(self):
        fc = rpt.fidelity_table(_two_estimator_rows())
        assert fc["reference_estimator"] == "ana"
        cell = next(r for r in fc["rows"]
                    if (r["workload"], r["system"]) == ("w1", "a100"))
        assert cell["ratio_vs_reference"]["b"] == pytest.approx(2.0)
        assert cell["ratio_vs_reference"]["ana"] == pytest.approx(1.0)

    def test_build_report_and_markdown(self):
        rows = _two_estimator_rows()
        reference = {"source": "unit", "rows": [
            {"workload": "w1", "system": "a100", "step_time_s": 1.0}]}
        report = rpt.build_report("unit-grid", rows, reference=reference)
        assert report["num_ok"] == len(rows)
        md = rpt.render_markdown(report)
        assert "# Campaign report: unit-grid" in md
        assert "Rank preservation" in md and "Fidelity comparison" in md
        assert "Accuracy vs recorded reference" in md

    def test_error_rows_excluded_but_counted(self):
        rows = _two_estimator_rows()
        rows.append({"job_id": 99, "workload": "w1", "system": "a100",
                     "estimator": "ana", "error": "boom"})
        report = rpt.build_report("unit-grid", rows)
        assert report["num_failed"] == 1
        assert report["num_ok"] == len(rows) - 1


# --------------------------- golden-snapshot gate ---------------------------


class TestGoldenCheck:
    def _golden(self, rows, tolerance=0.05):
        return rpt.make_golden("g", rows, tolerance=tolerance)

    def test_identity_passes(self):
        rows = _two_estimator_rows()
        check = rpt.check_rows(self._golden(rows), rows)
        assert check["failures"] == []
        assert check["rows_checked"] == len(rows)

    def test_drift_within_tolerance_passes(self):
        rows = _two_estimator_rows()
        golden = self._golden(rows, tolerance=0.05)
        moved = [dict(r, step_time_s=r["step_time_s"] * 1.01) for r in rows]
        assert rpt.check_rows(golden, moved)["failures"] == []

    def test_drift_beyond_tolerance_fails(self):
        rows = _two_estimator_rows()
        golden = self._golden(rows, tolerance=0.05)
        moved = [dict(r) for r in rows]
        moved[0]["step_time_s"] *= 1.2
        failures = rpt.check_rows(golden, moved)["failures"]
        assert len(failures) == 1 and "step_time_s drifted" in failures[0]

    def test_tolerance_override_wins(self):
        rows = _two_estimator_rows()
        golden = self._golden(rows, tolerance=0.05)
        moved = [dict(r, step_time_s=r["step_time_s"] * 1.01) for r in rows]
        failures = rpt.check_rows(golden, moved, tolerance=1e-6)["failures"]
        assert failures and all("drifted" in f for f in failures)

    def test_count_fields_compare_exactly(self):
        rows = _two_estimator_rows()
        golden = self._golden(rows)
        moved = [dict(r) for r in rows]
        moved[0]["num_comm"] += 1
        failures = rpt.check_rows(golden, moved)["failures"]
        assert len(failures) == 1 and "num_comm changed" in failures[0]

    def test_grid_shape_changes_fail(self):
        rows = _two_estimator_rows()
        golden = self._golden(rows)
        missing = rpt.check_rows(golden, rows[:-1])["failures"]
        assert any("missing from fresh run" in f for f in missing)
        extra = rows + [_row("w9", "a100", "ana", 1.0, job_id=77)]
        added = rpt.check_rows(golden, extra)["failures"]
        assert any("not in golden snapshot" in f for f in added)

    def test_rank_inversion_fails_even_within_tolerance(self):
        """The paper's headline claim is the gate's sharpest edge: two
        predictions may each drift within tolerance while *swapping
        order* — that must still fail."""
        rows = [_row("w", "a100", "ana", 1.000, job_id=0),
                _row("w", "h100", "ana", 1.001, job_id=1)]
        golden = self._golden(rows, tolerance=0.05)
        swapped = [dict(rows[0], step_time_s=1.001),
                   dict(rows[1], step_time_s=1.000)]
        failures = rpt.check_rows(golden, swapped)["failures"]
        assert len(failures) == 1 and "rank inversion" in failures[0]
        assert "['a100', 'h100']" in failures[0]

    def test_error_rows_fail_check(self):
        rows = _two_estimator_rows()
        golden = self._golden(rows)
        broken = rows[:-1] + [{"job_id": 99, "workload": "w2",
                               "error": "boom"}]
        failures = rpt.check_rows(golden, broken)["failures"]
        assert any("failed: boom" in f for f in failures)

    def test_make_golden_refuses_failing_campaign(self):
        rows = [_row("w", "a100", "ana", 1.0),
                {"job_id": 1, "workload": "w", "error": "boom"}]
        with pytest.raises(ValueError, match="refusing to snapshot"):
            rpt.make_golden("g", rows)

    def test_ambiguous_row_keys_refused_and_flagged(self):
        """Two topologies of one kind without a num_devices param share a
        label: their grid points collapse under row_key, so snapshotting
        must refuse and the gate must flag rather than silently checking
        half the grid."""
        rows = [_row("w", "a100", "ana", 1.0, job_id=0,
                     topology="dragonfly"),
                _row("w", "a100", "ana", 2.0, job_id=1,
                     topology="dragonfly")]
        with pytest.raises(ValueError, match="not distinguishable"):
            rpt.make_golden("g", rows)
        golden = self._golden(rows[:1])
        failures = rpt.check_rows(golden, rows)["failures"]
        assert any("duplicate fresh grid point" in f for f in failures)

    def test_tied_orderings_are_row_order_independent(self):
        """Exact ties break by name on both sides, so a golden (sorted
        by row key) and a fresh run (job order) never disagree about an
        unchanged, tied prediction set."""
        tied = [_row("w", "a100", "ana", 1.0, job_id=0),
                _row("w", "h100", "ana", 1.0, job_id=1)]
        fwd = rpt.trend_orderings(tied)
        rev = rpt.trend_orderings(list(reversed(tied)))
        assert fwd == rev
        assert fwd["systems"]["w"]["ana"] == ["a100", "h100"]
        assert rpt.check_rows(self._golden(tied),
                              list(reversed(tied)))["failures"] == []

    def test_make_reference_records_reference_estimator(self):
        rows = _two_estimator_rows()
        ref = rpt.make_reference("g", rows)
        assert ref["estimator"] == "ana"
        vals = {(r["workload"], r["system"]): r["step_time_s"]
                for r in ref["rows"]}
        assert vals[("w1", "a100")] == pytest.approx(1.0)
        assert len(vals) == 4


# ------------------------- checked-in golden surface ------------------------


class TestCheckedInGoldens:
    """The five paper grids each carry a golden snapshot + recorded
    reference whose grid points exactly match the spec expansion."""

    @pytest.mark.parametrize("spec_file,name", sorted(CHECKED_IN.items()))
    def test_golden_and_reference_exist_and_match_grid(self, spec_file,
                                                       name):
        spec = CampaignSpec.from_json(os.path.join(SPECS, spec_file))
        assert spec.name == name
        golden = rpt.load_json(rpt.golden_path(
            os.path.join(SPECS, spec_file), name))
        assert golden is not None, f"missing golden for {name}"
        reference = rpt.load_json(rpt.reference_path(
            os.path.join(SPECS, spec_file), name))
        assert reference is not None, f"missing reference for {name}"
        expected = {
            (j.workload, j.fidelity, j.system, j.estimator.label, j.slicer,
             j.topology.label, j.overlap, j.straggler_factor,
             j.compression)
            for j in spec.expand()}
        got = {rpt.row_key(r) for r in golden["rows"]}
        assert got == expected
        assert 0 < float(golden["tolerance"]) <= 0.05
        # reference rows cover every (workload, system) cell of the grid
        cells = {(j.workload, j.system) for j in spec.expand()}
        ref_cells = {(r["workload"], r["system"])
                     for r in reference["rows"]}
        assert ref_cells == cells

    def test_fig9_golden_uses_zip(self):
        """The fig9 snapshot must cover the *paired* grid: each workload
        appears only with its own fabric's predictions (16-GPU and
        128-GPU jobs have different comm profiles)."""
        golden = rpt.load_json(os.path.join(SPECS, "golden",
                                            "fig9-scaleout.json"))
        by_wl = {}
        for r in golden["rows"]:
            if r["estimator"] == "roofline":
                by_wl[r["workload"]] = r
        assert set(by_wl) == {"llama2-16", "llama2-128"}
        assert by_wl["llama2-16"]["comm_s"] \
            != by_wl["llama2-128"]["comm_s"]

    def test_fig10_golden_end_to_end(self):
        """The jax-free grid re-runs quickly: fresh predictions must pass
        their own checked-in gate (the CI golden job's core path)."""
        spec = CampaignSpec.from_json(os.path.join(SPECS,
                                                   "fig10_gemm.json"))
        golden = rpt.load_json(os.path.join(SPECS, "golden",
                                            "fig10-gemm.json"))
        res = run_campaign(spec, executor="serial")
        check = rpt.check_rows(golden, res.rows)
        assert check["failures"] == [], check["failures"]
        assert check["rows_checked"] == spec.num_points == 24


# --------------------------------- the CLI ---------------------------------


@pytest.fixture()
def tmp_specdir(tmp_path):
    """A private copy of the fig10 spec so golden/reference writes land
    in the test's own directory tree."""
    import shutil
    spec = tmp_path / "fig10_gemm.json"
    shutil.copy(os.path.join(SPECS, "fig10_gemm.json"), spec)
    return tmp_path


class TestReportCLI:
    def test_update_check_and_drift_cycle(self, tmp_specdir, capsys):
        spec = str(tmp_specdir / "fig10_gemm.json")
        out = str(tmp_specdir / "out")
        rc = campaign_main(["report", spec, "--out", out, "--quiet",
                            "--executor", "serial", "--update-golden",
                            "--tolerance", "1e-6"])
        assert rc == 0
        gpath = tmp_specdir / "golden" / "fig10-gemm.json"
        rpath = tmp_specdir / "references" / "fig10-gemm.json"
        assert gpath.exists() and rpath.exists()
        assert (tmp_specdir / "out" / "fig10-gemm" / "report.json").exists()
        md = (tmp_specdir / "out" / "fig10-gemm" / "report.md").read_text()
        assert "Golden-snapshot check" not in md  # no --check yet
        # the seeding run itself already reports MAPE vs the reference
        # it just recorded — no second invocation needed
        assert "Accuracy vs recorded reference" in md

        rc = campaign_main(["report", spec, "--out", out, "--quiet",
                            "--executor", "serial", "--check"])
        assert rc == 0
        capsys.readouterr()

        # corrupt one golden prediction: --check must fail loudly
        golden = json.loads(gpath.read_text())
        golden["rows"][0]["step_time_s"] *= 1.5
        gpath.write_text(json.dumps(golden))
        rc = campaign_main(["report", spec, "--out", out, "--quiet",
                            "--executor", "serial", "--check"])
        assert rc == 1
        printed = capsys.readouterr().out
        assert "GOLDEN-CHECK FAILURE" in printed and "drifted" in printed
        md = (tmp_specdir / "out" / "fig10-gemm" / "report.md").read_text()
        assert "**FAILED**" in md

    def test_failed_rows_make_report_exit_nonzero(self, tmp_path, capsys):
        """Like `run`, `report` must not exit 0 on a half-failed
        campaign just because the surviving rows produced a report."""
        hlo = tmp_path / "w.hlo"
        hlo.write_text("HloModule w")
        spec = tmp_path / "broken.json"
        # fidelity "raw" with only optimized text: every grid point
        # becomes a "no raw text" plan-error row
        spec.write_text(json.dumps({
            "name": "broken",
            "workloads": [{"name": "w", "fidelity": "raw",
                           "hlo_path": str(hlo)}],
        }))
        rc = campaign_main(["report", str(spec), "--quiet", "--executor",
                            "serial", "--out", str(tmp_path / "out")])
        assert rc == 1
        assert "grid points failed" in capsys.readouterr().out

    def test_check_without_golden_fails_with_hint(self, tmp_specdir,
                                                  capsys):
        spec = str(tmp_specdir / "fig10_gemm.json")
        rc = campaign_main(["report", spec, "--quiet", "--executor",
                            "serial", "--out",
                            str(tmp_specdir / "out"), "--check"])
        assert rc == 1
        assert "--update-golden" in capsys.readouterr().out

    def test_report_from_results_file(self, tmp_specdir):
        """`report --results` rebuilds the same evaluation from streamed
        rows without re-running (and without the estimator stack)."""
        spec = str(tmp_specdir / "fig10_gemm.json")
        out1 = str(tmp_specdir / "out1")
        assert campaign_main(["report", spec, "--out", out1, "--quiet",
                              "--executor", "serial"]) == 0
        results = os.path.join(out1, "fig10-gemm", "results.jsonl")
        out2 = str(tmp_specdir / "out2")
        assert campaign_main(["report", spec, "--results", results,
                              "--out", out2, "--quiet"]) == 0
        r1 = json.loads((tmp_specdir / "out1" / "fig10-gemm" /
                         "report.json").read_text())
        r2 = json.loads((tmp_specdir / "out2" / "fig10-gemm" /
                         "report.json").read_text())
        for section in ("rank_preservation", "fidelity_comparison",
                        "trend_orderings", "accuracy"):
            assert r1.get(section) == r2.get(section)

    def test_update_golden_keeps_existing_reference(self, tmp_specdir):
        """References are recorded baselines: --update-golden must not
        clobber one that exists (delete it to re-record)."""
        spec = str(tmp_specdir / "fig10_gemm.json")
        refdir = tmp_specdir / "references"
        refdir.mkdir()
        sentinel = {"campaign": "fig10-gemm", "source": "hand-recorded",
                    "rows": [{"workload": "gemm-256", "system": "tpu-v3",
                              "step_time_s": 1.0}]}
        (refdir / "fig10-gemm.json").write_text(json.dumps(sentinel))
        assert campaign_main(["report", spec, "--quiet", "--executor",
                              "serial", "--out",
                              str(tmp_specdir / "out"),
                              "--update-golden"]) == 0
        kept = json.loads((refdir / "fig10-gemm.json").read_text())
        assert kept["source"] == "hand-recorded"


# ------------------------ fig9 paired-axis parity ---------------------------


@pytest.fixture(scope="module")
def tiny_llama_workload():
    """A tiny train-step export standing in for both fig9 scale points
    (parity needs the real spec axes, not full-size 7B exports)."""
    pytest.importorskip("jax")
    from repro.core.pipeline import export_workload
    from repro.models.registry import get_smoke_config
    from repro.train.loop import train_step_exports

    cfg = get_smoke_config("llama3-100m")
    jitted, abs_args = train_step_exports(cfg, 32, 2, None)
    return export_workload(jitted, *abs_args, name="tiny-llama")


class TestFig9ZipParity:
    def test_zip_grid_matches_pre_port_loop(self, tiny_llama_workload):
        """Acceptance: the zipped fig9 spec is bit-identical to the
        pre-port in-script campaign — one single-(workload, fabric)
        campaign per scale, exactly as benchmarks/fig9_scaleout.py was
        written before the port."""
        spec = CampaignSpec.from_json(os.path.join(SPECS,
                                                   "fig9_scaleout.json"))
        provided = {w.name: tiny_llama_workload for w in spec.workloads}
        zipped = run_campaign(spec, workloads=provided, executor="serial")
        assert zipped.summary["num_failed"] == 0

        ref_rows: list[dict] = []
        for w, topo in zip(spec.workloads, spec.topologies):
            sub = CampaignSpec(
                name=f"fig9-{w.name}", workloads=[w],
                systems=list(spec.systems),
                estimators=list(spec.estimators),
                slicers=list(spec.slicers), topologies=[topo])
            res = run_campaign(sub, workloads={w.name: tiny_llama_workload},
                               executor="thread")
            assert res.summary["num_failed"] == 0
            ref_rows.extend(res.ok_rows)

        assert len(zipped.ok_rows) == len(ref_rows) == 4
        ref = {(r["workload"], r["estimator"]): r for r in ref_rows}
        for row in zipped.ok_rows:
            expect = ref[(row["workload"], row["estimator"])]
            for f in ("step_time_s", "compute_s", "comm_s",
                      "exposed_comm_s", "num_segments", "num_comm",
                      "topology", "fidelity"):
                assert row[f] == expect[f], (row["workload"], f)

    def test_fig9_spec_pairs_scales_with_fabrics(self):
        spec = CampaignSpec.from_json(os.path.join(SPECS,
                                                   "fig9_scaleout.json"))
        assert spec.zip_axes == [("workloads", "topologies")]
        jobs = spec.expand()
        assert len(jobs) == 4  # 2 paired scales × 2 estimator fidelities
        fabric_devices = {}
        for j, w in ((j, w) for j in jobs for w in spec.workloads
                     if w.name == j.workload):
            p = j.topology.params_dict
            fabric_devices[w.name] = (p["num_nodes"] * p["gpus_per_node"])
        # each scale's fabric carries exactly that scale's GPU count
        assert fabric_devices == {"llama2-16": 16, "llama2-128": 128}
        by_name = {w.name: w for w in spec.workloads}
        assert by_name["llama2-16"].mesh == (16, 1)
        assert by_name["llama2-128"].mesh == (128, 1)
        assert by_name["llama2-16"].batch == 32   # 2/GPU at 16 GPUs
        assert by_name["llama2-128"].batch == 128  # 1/GPU at 128 GPUs
