"""Compute API: analytical / systolic / profiling / cache / mixed."""
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based tests need the hypothesis dev dependency "
           "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.estimators import (CachedEstimator, MixedEstimator, PRESETS,
                                   ProfilingEstimator, RooflineEstimator,
                                   SystolicEstimator)
from repro.core.ir import parse
from repro.core.slicing import linear_split
from repro.core.systems import TPU_V5E, TPU_V3_CORE, host_system


@pytest.fixture(scope="module")
def gemm_region():
    def f(a, b):
        return jnp.tanh(a @ b)
    txt = jax.jit(f).lower(
        jax.ShapeDtypeStruct((512, 512), jnp.bfloat16),
        jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)).as_text()
    prog = parse(txt)
    segs = linear_split(prog)
    assert len(segs) == 1
    return prog, segs[0].region


class TestRoofline:
    def test_compute_bound_gemm(self, gemm_region):
        _, region = gemm_region
        est = RooflineEstimator(TPU_V5E, mode="region")
        t = est.get_run_time_estimate(region)
        flops = 2 * 512**3
        assert t >= flops / TPU_V5E.flops_for("bf16") * 0.99

    def test_per_op_slower_than_region(self, gemm_region):
        _, region = gemm_region
        fused = RooflineEstimator(TPU_V5E, mode="region")
        perop = RooflineEstimator(TPU_V5E, mode="per-op",
                                  include_overheads=True)
        assert perop.get_run_time_estimate(region) >= \
            fused.get_run_time_estimate(region)

    def test_faster_system_faster_estimate(self, gemm_region):
        _, region = gemm_region
        t_v3 = RooflineEstimator(TPU_V3_CORE).get_run_time_estimate(region)
        t_v5 = RooflineEstimator(TPU_V5E).get_run_time_estimate(region)
        assert t_v5 < t_v3


class TestSystolic:
    def test_supports_gemm_region(self, gemm_region):
        _, region = gemm_region
        est = SystolicEstimator(TPU_V5E, "cocossim")
        assert est.supports(region)

    def test_preset_ordering_large_gemm(self):
        """scalesim (no double buffer) >= cocossim >= zigzag (compute only)."""
        ts = {p: SystolicEstimator(TPU_V5E, p).gemm_latency(4096, 4096, 4096)
              for p in PRESETS}
        assert ts["scalesim"] >= ts["cocossim"] >= ts["zigzag"]

    def test_never_faster_than_mxu_peak(self):
        est = SystolicEstimator(TPU_V5E, "zigzag")
        for n in (256, 1024, 4096):
            t = est.gemm_latency(n, n, n)
            peak = TPU_V5E.mxu_rows * TPU_V5E.mxu_cols * 2 \
                * TPU_V5E.n_mxu * TPU_V5E.clock_hz
            assert t >= 2 * n**3 / peak * 0.99

    @settings(max_examples=30, deadline=None)
    @given(m=st.integers(8, 4096), n=st.integers(8, 4096),
           k=st.integers(8, 4096))
    def test_latency_positive_and_monotone_in_k(self, m, n, k):
        est = SystolicEstimator(TPU_V5E, "cocossim")
        t1 = est.gemm_latency(m, n, k)
        t2 = est.gemm_latency(m, n, 2 * k)
        assert 0 < t1 <= t2 * 1.001


class TestMixed:
    def test_fallback_for_non_gemm(self):
        def f(x):
            return jnp.cumsum(jnp.sin(x))
        txt = jax.jit(f).lower(
            jax.ShapeDtypeStruct((4096,), jnp.float32)).as_text()
        region = linear_split(parse(txt))[0].region
        sysl = SystolicEstimator(TPU_V5E, "cocossim")
        assert not sysl.supports(region)
        mixed = MixedEstimator(sysl, RooflineEstimator(TPU_V5E))
        assert mixed.get_run_time_estimate(region) > 0


class TestCache:
    def test_hit_semantics(self, gemm_region):
        _, region = gemm_region
        cached = CachedEstimator(RooflineEstimator(TPU_V5E))
        t1 = cached.get_run_time_estimate(region)
        t2 = cached.get_run_time_estimate(region)
        assert t1 == t2
        assert cached.stats.hits == 1 and cached.stats.misses == 1

    def test_hw_key_separates_systems(self, gemm_region):
        _, region = gemm_region
        c1 = CachedEstimator(RooflineEstimator(TPU_V5E))
        c2 = CachedEstimator(RooflineEstimator(TPU_V3_CORE))
        assert c1._key(region) != c2._key(region)

    def test_persistence(self, gemm_region, tmp_path):
        _, region = gemm_region
        path = str(tmp_path / "cache.json")
        c1 = CachedEstimator(RooflineEstimator(TPU_V5E), persist_path=path)
        c1.get_run_time_estimate(region)
        c1.flush()
        c2 = CachedEstimator(RooflineEstimator(TPU_V5E), persist_path=path)
        c2.get_run_time_estimate(region)
        assert c2.stats.hits == 1 and c2.stats.misses == 0


class TestProfiling:
    def test_executes_region(self, gemm_region):
        prog, region = gemm_region
        est = ProfilingEstimator(program=prog, runs=2)
        t = est.get_run_time_estimate(region)
        assert est.emit_failures == 0
        assert 1e-6 < t < 10.0

    def test_compute_api_surface(self, gemm_region):
        prog, _ = gemm_region
        est = ProfilingEstimator(program=prog, runs=3)
        assert est.get_exec_args()["runs"] == 3
        assert "backend" in est.get_compile_args()
