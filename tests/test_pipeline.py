"""End-to-end pipeline: export -> slice -> estimate -> netsim, plus the
Chakra trace format and the perf-predict pre-flight."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.estimators import (MixedEstimator, ProfilingEstimator,
                                   RooflineEstimator, SystolicEstimator)
from repro.core.network import AllToAllNode, Torus
from repro.core.pipeline import Workload, export_workload, predict
from repro.core.systems import TPU_V5E, get_system
from repro.core.trace import Trace


@pytest.fixture(scope="module")
def workload():
    def step(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return jnp.sum(h.astype(jnp.float32) ** 2)
    w = jax.ShapeDtypeStruct((6, 128, 128), jnp.bfloat16)
    x = jax.ShapeDtypeStruct((64, 128), jnp.bfloat16)
    return export_workload(jax.jit(jax.grad(step)), w, x, name="toy")


class TestExport:
    def test_both_fidelities(self, workload):
        assert workload.stablehlo_text and workload.hlo_text
        assert workload.program("raw").dialect == "stablehlo"
        assert workload.program("optimized").dialect == "hlo"

    def test_meta_captured(self, workload):
        assert "cost_analysis" in workload.meta
        assert workload.meta["cost_analysis"].get("flops", 0) > 0


class TestPredict:
    @pytest.mark.parametrize("slicer", ["linear", "dep"])
    @pytest.mark.parametrize("fidelity", ["raw", "optimized"])
    def test_all_paths_produce_time(self, workload, slicer, fidelity):
        prog = workload.program(fidelity)
        p = predict(prog, RooflineEstimator(TPU_V5E), Torus(dims=(2, 2)),
                    slicer=slicer, name="toy")
        assert p.step_time_s > 0
        assert p.compute_s > 0
        assert p.num_segments >= 1

    def test_overlap_never_slower(self, workload):
        prog = workload.program("optimized")
        base = predict(prog, RooflineEstimator(TPU_V5E), Torus(),
                       slicer="dep", overlap=False)
        over = predict(prog, RooflineEstimator(TPU_V5E), Torus(),
                       slicer="dep", overlap=True)
        assert over.step_time_s <= base.step_time_s + 1e-12

    def test_mixed_estimator_path(self, workload):
        prog = workload.program("optimized")
        est = MixedEstimator(SystolicEstimator(TPU_V5E, "onnxim"),
                             RooflineEstimator(TPU_V5E))
        p = predict(prog, est, Torus(), slicer="linear")
        assert p.step_time_s > 0

    def test_cache_reused_across_identical_layers(self):
        def f(w, x):
            for i in range(6):
                x = jax.lax.optimization_barrier(jnp.tanh(x @ w[i]))
            return x
        w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
        x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
        wl = export_workload(jax.jit(f), w, x, name="layers",
                             compile_workload=False)
        p = predict(wl.program("raw"), RooflineEstimator(TPU_V5E), Torus(),
                    slicer="linear", name="layers")
        # 6 identical per-layer regions -> 5+ cache hits
        assert p.cache_stats.hits >= 5
        assert p.cache_stats.hits > p.cache_stats.misses

    def test_cross_system_ordering(self, workload):
        prog = workload.program("optimized")
        t = {}
        for name in ("a100", "h100", "b200", "tpu-v5e"):
            t[name] = predict(prog, RooflineEstimator(get_system(name)),
                              AllToAllNode(num_devices=4),
                              slicer="linear").step_time_s
        assert t["b200"] < t["h100"] < t["a100"]

    def test_straggler_increases_makespan(self, workload):
        prog = workload.program("optimized")
        base = predict(prog, RooflineEstimator(TPU_V5E), Torus(),
                       slicer="linear")
        slow = predict(prog, RooflineEstimator(TPU_V5E), Torus(),
                       slicer="linear", straggler_factor=4.0)
        assert slow.step_time_s >= base.step_time_s


class TestTraceFormat:
    def test_roundtrip(self, tmp_path):
        t = Trace(meta={"workload": "x"})
        a = t.add_comp("embed", 12.5)
        b = t.add_comm("all_reduce", 1e6, 8, deps=[a])
        t.add_comp("head", 3.5, deps=[b])
        path = str(tmp_path / "trace.json")
        t.save(path)
        t2 = Trace.load(path)
        assert len(t2.nodes) == 3
        assert t2.nodes[1].comm_type == "ALL_REDUCE"
        assert t2.nodes[1].data_deps == [0]
        assert t2.total_comp_us == pytest.approx(16.0)
        t2.validate()

    def test_profiling_prediction_on_raw(self, workload):
        prog = workload.program("raw")
        est = ProfilingEstimator(program=prog, runs=1)
        p = predict(prog, est, AllToAllNode(num_devices=1), slicer="linear")
        assert est.emit_failures == 0
        assert p.step_time_s > 0
