"""Differential test: systolic ``evaluate_batch`` vs the scalar walk.

The vectorized path must be a bit-for-bit replay of
``get_run_time_estimate`` region by region (``==`` on floats, never
approx) across every preset — same float64 operations in the same
order — and must *decline* (return None) any batch it cannot replay
exactly, i.e. plans hiding a ``dot_general`` inside nested control
flow, where the scalar sum-then-multiply trip-count fold has no flat
vectorized equivalent.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core.catalog import default_registry
from repro.core.estimators import (CachedEstimator, PRESETS,
                                   SystolicEstimator)
from repro.core.ir import parse
from repro.core.ir.arrays import build_region_arrays
from repro.core.pipeline import build_plan
from repro.core.slicing import linear_split
from repro.core.systems import TPU_V3_CORE, TPU_V5E


def _region(f, *specs):
    txt = jax.jit(f).lower(*specs).as_text()
    segs = linear_split(parse(txt))
    assert len(segs) == 1
    return segs[0].region


@pytest.fixture(scope="module")
def mixed_regions():
    """A batch spanning the shapes the vector path must reproduce:
    square bf16, ragged f32 (non-divisible tiles), batched, chained
    dots in one region, and a GEMM-free region (exact zero)."""
    S = jax.ShapeDtypeStruct
    regions = [
        _region(lambda a, b: jnp.tanh(a @ b),
                S((512, 512), jnp.bfloat16), S((512, 512), jnp.bfloat16)),
        _region(lambda a, b: a @ b,
                S((300, 700), jnp.float32), S((700, 130), jnp.float32)),
        _region(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                S((4, 96, 160), jnp.bfloat16), S((4, 160, 320), jnp.bfloat16)),
        _region(lambda a, b, c: (a @ b) @ c,
                S((256, 128), jnp.bfloat16), S((128, 512), jnp.bfloat16),
                S((512, 64), jnp.bfloat16)),
        _region(lambda x: jnp.cumsum(jnp.sin(x)),
                S((4096,), jnp.float32)),
    ]
    return regions, build_region_arrays(regions)


#: a while loop whose inlined body holds the ``dot_general`` — below
#: the top level of the compute region, so the scalar walk's
#: sum-then-multiply trip-count fold applies (HLO text because jax
#: outlines scan bodies into calls; the HLO front end inlines them)
NESTED_DOT_HLO = """\
HloModule nested_dot

%cond.10 (p.11: (s32[], f32[64,64])) -> pred[] {
  %p.11 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.12 = s32[] get-tuple-element(%p.11), index=0
  %c.13 = s32[] constant(3)
  ROOT %cmp.14 = pred[] compare(%gte.12, %c.13), direction=LT
}

%body.20 (p.21: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p.21 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.22 = f32[64,64]{1,0} get-tuple-element(%p.21), index=1
  %dot.23 = f32[64,64]{1,0} dot(%gte.22, %gte.22), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %gte.25 = s32[] get-tuple-element(%p.21), index=0
  %c.26 = s32[] constant(1)
  %add.27 = s32[] add(%gte.25, %c.26)
  ROOT %tuple.28 = (s32[], f32[64,64]{1,0}) tuple(%add.27, %dot.23)
}

ENTRY %main.40 (arg.41: f32[64,64]) -> f32[64,64] {
  %arg.41 = f32[64,64]{1,0} parameter(0)
  %c.42 = s32[] constant(0)
  %tuple.43 = (s32[], f32[64,64]{1,0}) tuple(%c.42, %arg.41)
  %while.44 = (s32[], f32[64,64]{1,0}) while(%tuple.43), condition=%cond.10, body=%body.20
  ROOT %gte.45 = f32[64,64]{1,0} get-tuple-element(%while.44), index=1
}
"""


@pytest.fixture(scope="module")
def nested_regions():
    segs = linear_split(parse(NESTED_DOT_HLO))
    assert len(segs) == 1
    regions = [segs[0].region]
    return regions, build_region_arrays(regions)


_SYSTEMS = [TPU_V5E, TPU_V3_CORE,
            default_registry().get("a100"), default_registry().get("b200")]


class TestBitIdentity:
    @pytest.mark.parametrize("preset", sorted(PRESETS))
    @pytest.mark.parametrize("system", _SYSTEMS, ids=lambda s: s.name)
    def test_vector_equals_scalar(self, mixed_regions, preset, system):
        regions, arrays = mixed_regions
        est = SystolicEstimator(system, preset)
        scalar = [est.get_run_time_estimate(r) for r in regions]
        vector = est.evaluate_batch(arrays)
        assert vector == scalar          # == on floats: bit-identity
        assert scalar[0] > 0             # the batch is not trivially zero

    def test_gemm_free_region_is_exact_zero(self, mixed_regions):
        regions, arrays = mixed_regions
        est = SystolicEstimator(TPU_V5E, "cocossim")
        assert est.evaluate_batch(arrays)[-1] == 0.0
        assert est.get_run_time_estimate(regions[-1]) == 0.0

    def test_dispatch_through_batched_form(self, mixed_regions):
        regions, arrays = mixed_regions
        est = SystolicEstimator(TPU_V5E, "scalesim")
        assert est.get_run_time_estimates(regions, arrays=arrays) == \
            [est.get_run_time_estimate(r) for r in regions]

    def test_plan_arrays_carry_gemm_dims(self):
        """End-to-end: ``build_plan`` arrays feed the same fast path."""
        def f(a, b):
            return jnp.tanh(a @ b)
        S = jax.ShapeDtypeStruct
        txt = jax.jit(f).lower(S((384, 256), jnp.bfloat16),
                               S((256, 640), jnp.bfloat16)).as_text()
        plan = build_plan(parse(txt))
        assert plan.arrays.gemm_exact
        est = SystolicEstimator(TPU_V5E, "onnxim")
        assert est.evaluate_batch(plan.arrays) == \
            [est.get_run_time_estimate(r) for r in plan.compute_regions]


class TestDecline:
    def test_nested_gemm_clears_exact_flag(self, nested_regions):
        _, arrays = nested_regions
        assert not arrays.gemm_exact

    @pytest.mark.parametrize("preset", sorted(PRESETS))
    def test_batch_declined_and_scalar_fallback(self, nested_regions,
                                                preset):
        regions, arrays = nested_regions
        est = SystolicEstimator(TPU_V5E, preset)
        assert est.evaluate_batch(arrays) is None
        scalar = [est.get_run_time_estimate(r) for r in regions]
        assert scalar[0] > 0             # trip-counted dot, not dropped
        assert est.get_run_time_estimates(regions, arrays=arrays) == scalar


class TestThroughCache:
    def test_cold_batch_matches_scalar(self, mixed_regions):
        regions, arrays = mixed_regions
        est = SystolicEstimator(TPU_V5E, "cocossim")
        cached = CachedEstimator(SystolicEstimator(TPU_V5E, "cocossim"))
        got = cached.get_run_time_estimates(regions, arrays=arrays)
        assert got == [est.get_run_time_estimate(r) for r in regions]
        assert cached.stats.misses == len(regions)
        assert cached.stats.hits == 0

    def test_declined_batch_takes_loop(self, nested_regions):
        regions, arrays = nested_regions
        est = SystolicEstimator(TPU_V5E, "cocossim")
        cached = CachedEstimator(SystolicEstimator(TPU_V5E, "cocossim"))
        got = cached.get_run_time_estimates(regions, arrays=arrays)
        assert got == [est.get_run_time_estimate(r) for r in regions]
        assert cached.stats.misses == len(regions)
