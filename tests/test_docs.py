"""Docs and spec hygiene: intra-repo links resolve, the docs tree
exists, and every checked-in campaign spec validates and expands."""
import glob
import json
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_docs_tree_exists():
    for name in ("architecture.md", "campaign.md", "caching.md"):
        path = os.path.join(REPO, "docs", name)
        assert os.path.exists(path), f"missing docs/{name}"
        assert os.path.getsize(path) > 500, f"docs/{name} is a stub"


def test_intra_repo_links_resolve():
    sys.path.insert(0, os.path.join(REPO, "tools"))
    try:
        import check_links
    finally:
        sys.path.pop(0)
    files = check_links.collect(
        [os.path.join(REPO, "README.md"), os.path.join(REPO, "docs")])
    assert len(files) >= 4  # README + 3 docs pages
    errors = []
    for f in files:
        errors.extend(check_links.check_file(f))
    assert not errors, "\n".join(errors)


def test_checked_in_specs_validate_and_expand():
    """Every specs/*.json validates and expands without Python glue —
    including the paper_full suite covering fig6/fig7/fig10/fig11."""
    from repro.campaign.__main__ import load_specs

    spec_files = sorted(
        s for s in glob.glob(os.path.join(REPO, "specs", "*.json"))
        # bench_baselines.json is tools/bench_check.py data, not a grid
        if not s.endswith("bench_baselines.json"))
    from repro.search.spec import SearchSpec

    assert any(s.endswith("paper_full.json") for s in spec_files)
    names = set()
    for path in spec_files:
        with open(path) as f:
            raw = json.load(f)
        if "ladder" in raw or "objectives" in raw:
            # search specs live beside the campaign grids and validate
            # through their own schema (each ladder rung is a grid)
            sspec = SearchSpec.from_file_dict(raw, path)
            assert len(sspec.campaign_for_rung(0).expand()) > 0
            names.add(sspec.name)
            continue
        for name, spec in load_specs(path):
            spec.validate()
            jobs = spec.expand()
            assert len(jobs) == spec.num_points > 0
            names.add(name)
    assert {"fig6-gpu", "fig7-resnet", "fig10-gemm", "fig11-tpu",
            "search-gemm", "search-serving"} <= names


def test_paper_full_suite_covers_figure_specs():
    from repro.campaign.__main__ import load_specs

    suite = load_specs(os.path.join(REPO, "specs", "paper_full.json"))
    names = [n for n, _ in suite]
    assert names == ["fig6-gpu", "fig7-resnet", "fig9-scaleout",
                     "fig10-gemm", "fig11-tpu"]
    # the suite must exercise every workload source family and both modes
    kinds = set()
    for _, spec in suite:
        for w in spec.workloads:
            if w.gemm:
                kinds.add("gemm")
            elif w.arch and w.arch.startswith("resnet"):
                kinds.add("resnet-train")
            elif w.arch:
                kinds.add(f"lm-{w.mode}")
    assert {"gemm", "resnet-train", "lm-train"} <= kinds


def test_validate_needs_no_heavy_deps():
    """`python -m repro.campaign validate` must work with jax/numpy
    missing — the CI docs job installs nothing."""
    prog = (
        "import sys\n"
        "class B:\n"  # find_spec: the non-deprecated finder hook (3.12+)
        "    def find_spec(self, name, path=None, target=None):\n"
        "        if name.split('.')[0] in ('jax', 'jaxlib', 'numpy'):\n"
        "            raise ImportError('blocked: ' + name)\n"
        "sys.meta_path.insert(0, B())\n"
        "from repro.campaign.__main__ import main\n"
        "sys.exit(main(['validate', 'specs/paper_full.json']))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    p = subprocess.run([sys.executable, "-c", prog], cwd=REPO, env=env,
                       capture_output=True, text=True, timeout=60)
    assert p.returncode == 0, p.stdout + p.stderr


def test_check_links_cli_passes_on_repo():
    p = subprocess.run(
        [sys.executable, os.path.join(REPO, "tools", "check_links.py")],
        cwd=REPO, capture_output=True, text=True, timeout=60)
    assert p.returncode == 0, p.stdout + p.stderr
