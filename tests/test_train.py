"""Training substrate: optimizers, loop, checkpoint/restart, data pipeline,
fault tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig, ShapeConfig
from repro.models import get_smoke_config
from repro.train import (CheckpointManager, StragglerDetector, plan_remesh,
                         recommended_interval, train)
from repro.train.data import DataConfig, SyntheticSource
from repro.train.optimizer import (OptimizerConfig, adafactor_init,
                                   adafactor_update, adamw_init,
                                   adamw_update, clip_by_global_norm)

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=4, kind="train")


def _run_cfg(arch="llama3-100m", **kw):
    cfg = get_smoke_config(arch)
    return RunConfig(model=cfg, shape=SMOKE_SHAPE, learning_rate=1e-2, **kw)


class TestOptimizers:
    def _quadratic(self, update_fn, init_fn, steps=60):
        cfg = OptimizerConfig(learning_rate=0.1, weight_decay=0.0,
                              warmup_steps=1)
        params = {"w": jnp.array([3.0, -2.0])}
        state = init_fn(params, cfg)
        for _ in range(steps):
            grads = {"w": 2 * params["w"]}
            params, state, _ = update_fn(params, grads, state, cfg)
        return float(jnp.sum(params["w"] ** 2))

    def test_adamw_minimizes_quadratic(self):
        assert self._quadratic(adamw_update, adamw_init) < 0.05

    def test_adafactor_minimizes_quadratic(self):
        assert self._quadratic(adafactor_update, adafactor_init) < 0.3

    def test_adafactor_factored_state_shapes(self):
        cfg = OptimizerConfig(name="adafactor")
        params = {"big": jnp.zeros((256, 512)), "small": jnp.zeros((8, 8))}
        st = adafactor_init(params, cfg)
        assert st["v"]["big"]["vr"].shape == (256,)
        assert st["v"]["big"]["vc"].shape == (512,)
        assert st["v"]["small"]["v"].shape == (8, 8)

    def test_grad_clip(self):
        grads = {"w": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(grads, 1.0)
        assert float(norm) == pytest.approx(np.sqrt(10) * 100, rel=1e-5)
        cnorm = float(jnp.linalg.norm(clipped["w"]))
        assert cnorm == pytest.approx(1.0, rel=1e-4)


class TestTrainLoop:
    def test_loss_decreases(self, tmp_path):
        res = train(_run_cfg(), num_steps=12, log_every=0)
        assert res.steps == 12
        first = np.mean(res.losses[:3])
        last = np.mean(res.losses[-3:])
        assert np.isfinite(last)
        assert last < first, f"loss did not decrease: {first} -> {last}"

    def test_microbatch_matches_full_batch_loss_scale(self):
        r1 = train(_run_cfg(), num_steps=3, log_every=0)
        r2 = train(_run_cfg(microbatch=2), num_steps=3, log_every=0)
        assert np.isfinite(r2.final_loss)
        assert abs(r1.losses[0] - r2.losses[0]) / r1.losses[0] < 0.05

    def test_gradient_compression_trains(self):
        res = train(_run_cfg(gradient_compression=True), num_steps=6,
                    log_every=0)
        assert np.isfinite(res.final_loss)

    def test_failure_injection_restarts(self, tmp_path):
        res = train(_run_cfg(), num_steps=10, log_every=0,
                    checkpoint_dir=str(tmp_path), checkpoint_every=3,
                    inject_failure_at=7)
        assert res.restarts == 1
        assert np.isfinite(res.final_loss)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        state = {"params": {"w": jnp.arange(12.0).reshape(3, 4)},
                 "opt": {"step": jnp.int32(7)}}
        mgr.save(7, state, {"step": 7, "seed": 0}, blocking=True)
        restored, data_state, step = mgr.restore_latest()
        assert step == 7 and data_state["step"] == 7
        np.testing.assert_array_equal(
            restored["params"]["w"], np.arange(12.0).reshape(3, 4))

    def test_commit_protocol_ignores_partial(self, tmp_path):
        import os
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, {"w": jnp.ones(3)}, blocking=True)
        mgr.save(2, {"w": jnp.ones(3) * 2}, blocking=True)
        os.remove(os.path.join(str(tmp_path), "step_000000002", "COMMIT"))
        _, _, step = mgr.restore_latest()
        assert step == 1

    def test_gc_keeps_last_k(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
        for s in range(5):
            mgr.save(s, {"w": jnp.ones(2)}, blocking=True)
        assert mgr.committed_steps() == [3, 4]

    def test_corruption_detected(self, tmp_path):
        import os
        mgr = CheckpointManager(str(tmp_path), async_save=False)
        mgr.save(1, {"w": jnp.arange(1000.0)}, blocking=True)
        arr_dir = os.path.join(str(tmp_path), "step_000000001", "arrays")
        fn = os.path.join(arr_dir, os.listdir(arr_dir)[0])
        arr = np.load(fn)
        arr[0] = 1e9
        np.save(fn, arr)
        with pytest.raises(IOError):
            mgr.restore(1)


class TestData:
    def test_deterministic_and_resumable(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2, seed=3)
        a = SyntheticSource(cfg)
        b1 = next(a)
        b2 = next(a)
        state = a.state()
        b = SyntheticSource(cfg)
        b.restore(state)
        b3a, b3b = next(a), next(b)
        np.testing.assert_array_equal(b3a["tokens"], b3b["tokens"])
        assert not np.array_equal(b1["tokens"], b2["tokens"])

    def test_targets_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=2)
        batch = next(SyntheticSource(cfg))
        assert batch["tokens"].shape == batch["targets"].shape


class TestFaultTolerance:
    def test_young_daly_interval(self):
        t = recommended_interval(save_cost_s=30, node_mtbf_hours=1000,
                                 num_nodes=1000)
        assert t == pytest.approx(np.sqrt(2 * 30 * 3600), rel=1e-6)

    def test_straggler_detector(self):
        det = StragglerDetector(threshold=2.0)
        for i in range(10):
            assert not det.observe(i, 1.0)
        assert det.observe(10, 5.0)
        assert det.flagged and det.flagged[0][0] == 10

    def test_plan_remesh_keeps_model_axis(self):
        plan = plan_remesh(healthy_devices=480, model_parallel=16,
                           global_batch=256)
        assert plan.mesh_shape[1] == 16
        assert plan.mesh_shape[0] & (plan.mesh_shape[0] - 1) == 0  # pow2
        assert plan.mesh_shape[0] * 16 <= 480
        assert plan.global_batch % plan.mesh_shape[0] == 0

    def test_plan_remesh_raises_below_tp(self):
        with pytest.raises(RuntimeError):
            plan_remesh(healthy_devices=8, model_parallel=16,
                        global_batch=64)


class TestServe:
    def test_greedy_decode_runs(self):
        from repro.models import model_specs
        from repro.models.params import init_params
        from repro.serve import greedy_decode
        cfg = get_smoke_config("stablelm-12b")
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
        prompt = jnp.array([[1, 2, 3], [4, 5, 6]], jnp.int32)
        res = greedy_decode(cfg, params, prompt, max_new_tokens=4,
                            max_len=16)
        assert res.tokens.shape == (2, 4)
        assert bool(jnp.all(res.tokens >= 0))
        assert bool(jnp.all(res.tokens < cfg.vocab_size))

    def test_greedy_decode_rejects_overfull_cache(self):
        """Regression: prompt + max_new_tokens must fit the KV cache —
        one past the end raises up front instead of silently clamping
        writes at max_len."""
        from repro.models import model_specs
        from repro.models.params import init_params
        from repro.serve import greedy_decode
        cfg = get_smoke_config("stablelm-12b")
        params = init_params(model_specs(cfg), jax.random.PRNGKey(0))
        prompt = jnp.array([[1, 2, 3]], jnp.int32)
        # exactly full is fine: 3 + 5 == max_len
        res = greedy_decode(cfg, params, prompt, max_new_tokens=5,
                            max_len=8)
        assert res.tokens.shape == (1, 5)
        with pytest.raises(ValueError, match="max_len"):
            greedy_decode(cfg, params, prompt, max_new_tokens=6,
                          max_len=8)
