"""Prediction-as-a-service: the warm daemon, its client, and the
properties CI leans on — request coalescing under a thread burst (one
cold miss, /stats proves zero duplicates), graceful drain mid-campaign,
stats accounting, bounded client retry on connection-refused, and clean
4xx mapping for malformed requests."""
import json
import os
import socket
import threading
import time
import urllib.request

import pytest

from repro.serve.client import (CampaignStream, ServeClient, ServeError,
                                write_campaign_artifacts)
from repro.serve.server import (BadRequest, PredictionServer,
                                PredictionService)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
FIG10 = os.path.join(REPO, "specs", "fig10_gemm.json")

ONNXIM = {"kind": "systolic", "options": {"preset": "onnxim"}}


def gemm_workload(n: int, name: str | None = None) -> dict:
    return {"name": name or f"gemm-{n}", "fidelity": "raw",
            "gemm": {"m": n, "n": n, "k": n, "dtype": "bf16"}}


@pytest.fixture()
def served():
    """A live daemon on an ephemeral port + its client; drained after."""
    service = PredictionService()
    server = PredictionServer(service, port=0).start()
    client = ServeClient(server.url, connect_retries=0)
    yield service, server, client
    if not server.stopped.is_set():
        server.drain(timeout_s=10.0)
    assert server.stopped.is_set()


class TestEndpoints:
    def test_healthz_and_stats_shape(self, served):
        _, _, client = served
        h = client.healthz()
        assert h["status"] == "ok" and h["uptime_s"] >= 0
        st = client.stats()
        assert st["predict"]["served"] == 0
        assert st["plans"] == {"resident": 0, "workloads": 0,
                               "parse_calls": 0, "plans_built": 0}
        assert st["cache"]["entries"] == 0

    def test_predict_matches_local_session(self, served):
        _, _, client = served
        row = client.predict(gemm_workload(512), system="tpu-v3",
                             estimator=ONNXIM)
        from repro import api
        from repro.campaign.builders import build_workload
        from repro.campaign.spec import WorkloadSpec
        session = api.Session()
        w = build_workload(WorkloadSpec.from_dict(gemm_workload(512)))
        local = session.predict(w, system="tpu-v3", estimator="systolic",
                                options={"preset": "onnxim"},
                                fidelity="raw")
        assert row["step_time_s"] == pytest.approx(
            local.to_row()["step_time_s"], rel=0, abs=0)
        assert row["coalesced"] is False
        assert row["fidelity"] == "raw"

    def test_preload_makes_requests_parse_free(self, served):
        service, _, client = served
        info = service.preload(FIG10)
        assert len(info["workloads"]) == 6 and info["plans_built"] == 6
        parse0 = client.stats()["plans"]["parse_calls"]
        client.predict("gemm-256", system="tpu-v3", estimator=ONNXIM)
        assert client.stats()["plans"]["parse_calls"] == parse0

    def test_campaign_stream_rows_match_golden(self, served):
        _, _, client = served
        rows, summary = client.campaign(spec_path=FIG10,
                                        executor="thread").collect()
        assert len(rows) == 24 and summary["num_failed"] == 0
        from repro.campaign.report import check_rows, golden_path, load_json
        golden = load_json(golden_path(FIG10, "fig10-gemm"))
        assert golden is not None
        assert check_rows(golden, rows)["failures"] == []

    def test_warm_second_campaign_is_pure_hits(self, served):
        _, _, client = served
        _, s1 = client.campaign(spec_path=FIG10).collect()
        _, s2 = client.campaign(spec_path=FIG10).collect()
        assert s1["cache"]["misses"] == 24
        assert s2["cache"]["misses"] == 0
        assert s2["cache"]["hits"] == 24
        assert s2["plans"]["parse_calls"] == 0

    def test_report_endpoint_with_golden_check(self, served):
        _, _, client = served
        rep = client.report(FIG10, check=True)
        assert rep["golden_check"]["failures"] == []
        assert rep["golden_check"]["rows_checked"] == 24

    def test_inline_campaign_spec(self, served):
        _, _, client = served
        spec = {"name": "inline", "workloads": [gemm_workload(256)],
                "systems": ["a100"], "slicers": ["linear"]}
        rows, summary = client.campaign(spec=spec).collect()
        assert len(rows) == 1 and "step_time_s" in rows[0]

    def test_workload_reregistration_invalidates_stale_plan(self, served):
        _, _, client = served
        r1 = client.predict(gemm_workload(256, name="w"), system="a100")
        r2 = client.predict(gemm_workload(512, name="w"), system="a100")
        assert r1["step_time_s"] != r2["step_time_s"]
        # identical re-registration keeps plans hot (no new parse)
        parse0 = client.stats()["plans"]["parse_calls"]
        client.predict(gemm_workload(512, name="w"), system="a100")
        assert client.stats()["plans"]["parse_calls"] == parse0


class TestMalformedRequests:
    @pytest.mark.parametrize("body,fragment", [
        ({}, "needs a 'workload'"),
        ({"workload": "ghost"}, "unknown workload"),
        ({"workload": {"gemm": {"m": 1, "n": 1, "k": 1}}}, "needs a 'name'"),
        ({"workload": 42}, "must be a name or a workload-spec"),
        ({"workload": {"name": "x", "gemm": {"m": 1, "n": 1, "k": 1},
                       "arch": "llama3-1b"}}, "bad workload spec"),
        ({"workload": gemm_workload(64), "system": "a1000"},
         "unknown system"),
        ({"workload": gemm_workload(64), "estimator": "warp-drive"},
         "unknown estimator"),
        ({"workload": gemm_workload(64), "slicer": "diagonal"},
         "unknown slicer"),
        ({"workload": gemm_workload(64),
          "estimator": {"kind": "roofline", "bogus_field": 1}},
         "bad estimator spec"),
    ])
    def test_predict_4xx(self, served, body, fragment):
        _, server, _ = served
        req = urllib.request.Request(
            server.url + "/predict", data=json.dumps(body).encode(),
            headers={"Content-Type": "application/json"}, method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 400
        assert fragment in json.loads(ei.value.read())["error"]

    def test_invalid_json_body_is_400(self, served):
        _, server, _ = served
        req = urllib.request.Request(
            server.url + "/predict", data=b"{not json",
            headers={"Content-Type": "application/json"}, method="POST")
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(req)
        assert ei.value.code == 400
        assert "invalid JSON" in json.loads(ei.value.read())["error"]

    def test_unknown_endpoint_404_and_wrong_method_405(self, served):
        _, server, client = served
        with pytest.raises(ServeError) as ei:
            client._request("POST", "/teleport", {})
        assert ei.value.status == 404
        with pytest.raises(urllib.error.HTTPError) as ei2:
            urllib.request.urlopen(server.url + "/predict")
        assert ei2.value.code == 405

    def test_campaign_needs_exactly_one_spec_source(self, served):
        _, _, client = served
        with pytest.raises(ServeError) as ei:
            client._request("POST", "/campaign", {"executor": "thread"})
        assert ei.value.status == 400
        assert "exactly one of" in str(ei.value)

    def test_service_error_carries_status(self):
        assert BadRequest("x").status == 400
        assert isinstance(BadRequest("x"), ValueError)


class TestCoalescing:
    def test_burst_coalesces_to_one_cold_miss(self, served, monkeypatch):
        """A thread burst on one cold (H, C, R) keyset: exactly one
        request evaluates (the chain leader); the rest wait on it and
        resolve as pure hits.  The evaluation is artificially slowed so
        every burst member genuinely arrives while the leader is in
        flight — making the coalesced count deterministic, not just the
        miss count."""
        service, _, client = served
        from repro.campaign import runner as runner_mod
        real_execute = runner_mod._execute
        started = threading.Event()

        def slow_execute(job, plan, store, regs=None):
            started.set()
            time.sleep(0.3)
            return real_execute(job, plan, store, regs)

        # server.py binds runner._execute lazily inside predict(), so
        # patching the runner module intercepts the daemon's calls
        monkeypatch.setattr(runner_mod, "_execute", slow_execute)
        rows, errs = [], []

        def hit():
            try:
                rows.append(client.predict(gemm_workload(640),
                                           system="tpu-v3",
                                           estimator=ONNXIM))
            except Exception as e:  # noqa: BLE001
                errs.append(e)

        leader = threading.Thread(target=hit)
        leader.start()
        assert started.wait(5.0)          # burst lands mid-evaluation
        burst = [threading.Thread(target=hit) for _ in range(5)]
        for t in burst:
            t.start()
        for t in [leader, *burst]:
            t.join()

        assert not errs, errs
        st = client.stats()["predict"]
        assert st["served"] == 6
        assert st["cache_misses"] == 1
        assert st["cache_hits"] == 5
        assert st["duplicate_cold_misses"] == 0
        assert st["coalesced"] == 5
        assert sum(1 for r in rows if r["coalesced"]) == 5
        assert len({r["step_time_s"] for r in rows}) == 1

    def test_distinct_keysets_do_not_coalesce(self, served):
        _, _, client = served
        a = client.predict(gemm_workload(320), system="a100")
        b = client.predict(gemm_workload(320), system="h100")
        assert a["coalesced"] is False and b["coalesced"] is False
        st = client.stats()["predict"]
        assert st["cache_misses"] == 2
        assert st["duplicate_cold_misses"] == 0


class TestDrain:
    def test_drain_rejects_new_work_and_finishes_inflight(self, served,
                                                          monkeypatch):
        """SIGTERM semantics: a campaign caught mid-flight streams to
        completion; work submitted after the drain starts gets 503."""
        service, server, client = served
        from repro.campaign import runner as runner_mod
        real_execute = runner_mod._execute
        first_row = threading.Event()

        def slow_execute(job, plan, store, regs=None):
            first_row.set()
            time.sleep(0.1)
            return real_execute(job, plan, store, regs)

        monkeypatch.setattr(runner_mod, "_execute", slow_execute)
        spec = {"name": "drain-t",
                "workloads": [gemm_workload(256), gemm_workload(384),
                              gemm_workload(448)],
                "systems": ["a100"], "slicers": ["linear"]}
        result: dict = {}

        def run():
            rows, summary = client.campaign(spec=spec,
                                            executor="serial").collect()
            result["rows"], result["summary"] = rows, summary

        t = threading.Thread(target=run)
        t.start()
        assert first_row.wait(5.0)
        drained = threading.Thread(
            target=lambda: server.drain(timeout_s=30.0))
        drained.start()
        time.sleep(0.05)                  # let admission flip to draining
        with pytest.raises(ServeError) as ei:
            client.predict(gemm_workload(256), system="a100")
        assert ei.value.status in (503, 0)  # 503, or listener already gone
        t.join(timeout=30)
        drained.join(timeout=30)
        assert result["summary"]["num_failed"] == 0
        assert len(result["rows"]) == 3   # mid-flight campaign completed
        assert server.stopped.is_set()

    def test_shutdown_endpoint_drains(self, served):
        _, server, client = served
        assert client.shutdown() == {"draining": True}
        assert server.stopped.wait(10.0)

    def test_healthz_reports_draining(self):
        service = PredictionService()
        server = PredictionServer(service, port=0).start()
        client = ServeClient(server.url, connect_retries=0)
        service.draining = True           # drain flag only; listener up
        assert client.healthz()["status"] == "draining"
        assert client.stats()["draining"] is True
        service.draining = False
        server.drain(timeout_s=5.0)


class TestClient:
    def test_retry_on_connection_refused_bounded_backoff(self):
        """The client retries ONLY connect-refused (daemon still
        booting), with bounded exponential backoff, then gives up with
        status 0."""
        with socket.socket() as s:        # reserve a port nothing serves
            s.bind(("127.0.0.1", 0))
            port = s.getsockname()[1]
        client = ServeClient(f"http://127.0.0.1:{port}",
                             connect_retries=3, backoff_s=0.01)
        t0 = time.monotonic()
        with pytest.raises(ServeError) as ei:
            client.healthz()
        waited = time.monotonic() - t0
        assert ei.value.status == 0
        # 0.01 + 0.02 + 0.04 of backoff, and no unbounded spinning
        assert 0.07 <= waited < 5.0

    def test_wait_ready_rides_out_late_boot(self):
        service = PredictionService()
        server = PredictionServer(service, port=0)
        url = server.url
        threading.Thread(target=lambda: (time.sleep(0.3), server.start()),
                         daemon=True).start()
        client = ServeClient(url, connect_retries=0)
        assert client.wait_ready(timeout_s=10.0)["status"] == "ok"
        server.drain(timeout_s=5.0)

    def test_http_error_is_not_retried(self, served):
        _, _, client = served
        client.connect_retries = 50       # would take seconds if retried
        t0 = time.monotonic()
        with pytest.raises(ServeError) as ei:
            client.predict("ghost")
        assert ei.value.status == 400
        assert time.monotonic() - t0 < 2.0

    def test_write_campaign_artifacts_roundtrip(self, served, tmp_path):
        _, _, client = served
        rows, summary = client.campaign(spec_path=FIG10).collect()
        paths = write_campaign_artifacts(rows, summary, str(tmp_path))
        from repro.campaign.runner import load_jsonl
        assert load_jsonl(paths["jsonl"]) == rows
        with open(paths["summary"]) as f:
            assert json.load(f)["num_failed"] == 0
        with open(paths["csv"]) as f:
            assert f.readline().startswith("job_id,")

    def test_campaign_stream_surfaces_midstream_error(self):
        class FakeResp:
            lines = [b'{"job_id": 0}\n',
                     b'{"event": "error", "error": "boom"}\n']

            def __iter__(self):
                return iter(self.lines)

            def __enter__(self):
                return self

            def __exit__(self, *a):
                return False

        stream = CampaignStream(FakeResp())
        it = iter(stream)
        assert next(it) == {"job_id": 0}
        with pytest.raises(ServeError, match="boom"):
            next(it)


class TestStatsAccounting:
    def test_counters_add_up_across_mixed_traffic(self, served):
        service, _, client = served
        service.preload(FIG10)
        client.predict("gemm-256", system="tpu-v3", estimator=ONNXIM)
        client.predict("gemm-256", system="tpu-v3", estimator=ONNXIM)
        client.campaign(spec_path=FIG10).collect()
        client.report(FIG10, check=True)
        st = client.stats()
        assert st["requests"]["predict"] == 2
        assert st["requests"]["campaign"] == 1
        assert st["requests"]["report"] == 1
        assert st["predict"]["served"] == 2
        assert st["predict"]["cache_misses"] == 1   # second was a hit
        assert st["predict"]["cache_hits"] == 1
        assert st["predict"]["duplicate_cold_misses"] == 0
        # campaign verb ran twice (once inside /report)
        assert st["campaign"]["served"] == 2
        assert st["campaign"]["rows"] == 48
        assert st["campaign"]["duplicate_cold_misses"] == 0
        assert st["plans"]["resident"] == 6
        assert st["cache"]["entries"] == 24
        assert st["uptime_s"] > 0

    def test_lazy_serve_package_imports_without_jax(self):
        """The daemon/client half of repro.serve must not pull in the
        decode half's jax dependency (PEP 562 laziness)."""
        import subprocess
        import sys
        code = ("import sys\n"
                "from repro.serve import ServeClient, PredictionService\n"
                "assert 'jax' not in sys.modules, 'serve imported jax'\n"
                "from repro.serve import client, server\n"
                "assert 'jax' not in sys.modules\n")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        proc = subprocess.run([sys.executable, "-c", code], env=env,
                              capture_output=True, text=True, cwd=REPO)
        assert proc.returncode == 0, proc.stderr
