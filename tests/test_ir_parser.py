"""Unit tests for the unified IR front end (StableHLO-MLIR + HLO text)."""
import jax
import jax.numpy as jnp
import pytest

from repro.core.ir import (collect_collectives, parse, parse_hlo,
                           parse_stablehlo, program_cost,
                           total_collective_bytes)
from repro.core.ir.types import TensorType, parse_mlir_tensor

CANNED_HLO = """\
HloModule jit_toy, num_partitions=8

%add.1 (x.2: f32[], y.3: f32[]) -> f32[] {
  %x.2 = f32[] parameter(0)
  %y.3 = f32[] parameter(1)
  ROOT %add.4 = f32[] add(%x.2, %y.3)
}

%cond.10 (p.11: (s32[], f32[64,64])) -> pred[] {
  %p.11 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.12 = s32[] get-tuple-element(%p.11), index=0
  %c.13 = s32[] constant(12)
  ROOT %cmp.14 = pred[] compare(%gte.12, %c.13), direction=LT
}

%body.20 (p.21: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p.21 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.22 = f32[64,64]{1,0} get-tuple-element(%p.21), index=1
  %dot.23 = f32[64,64]{1,0} dot(%gte.22, %gte.22), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.24 = f32[64,64]{1,0} all-reduce(%dot.23), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add.1
  %gte.25 = s32[] get-tuple-element(%p.21), index=0
  %c.26 = s32[] constant(1)
  %add.27 = s32[] add(%gte.25, %c.26)
  ROOT %tuple.28 = (s32[], f32[64,64]{1,0}) tuple(%add.27, %ar.24)
}

ENTRY %main.40 (arg.41: f32[64,64]) -> f32[64,64] {
  %arg.41 = f32[64,64]{1,0} parameter(0)
  %c.42 = s32[] constant(0)
  %tuple.43 = (s32[], f32[64,64]{1,0}) tuple(%c.42, %arg.41)
  %while.44 = (s32[], f32[64,64]{1,0}) while(%tuple.43), condition=%cond.10, body=%body.20
  ROOT %gte.45 = f32[64,64]{1,0} get-tuple-element(%while.44), index=1
}
"""


class TestTypes:
    def test_parse_mlir_tensor(self):
        t = parse_mlir_tensor("4x6xf32")
        assert t.shape == (4, 6) and t.dtype == "f32"
        assert parse_mlir_tensor("bf16").shape == ()
        assert parse_mlir_tensor("1xi1").dtype == "i1"

    def test_nbytes(self):
        assert TensorType((4, 6), "f32").nbytes == 96
        assert TensorType((8,), "bf16").nbytes == 16
        assert TensorType((), "s32").nbytes == 4


class TestHloParser:
    def test_canned_module(self):
        prog = parse(CANNED_HLO)
        assert prog.dialect == "hlo"
        assert prog.meta["num_partitions"] == 8
        whiles = [op for op in prog.walk() if op.op == "while"]
        assert len(whiles) == 1
        assert whiles[0].trip_count == 12     # from %cond.10 constant

    def test_flops_with_trip_count(self):
        prog = parse(CANNED_HLO)
        cost = program_cost(prog)
        # dot 64x64x64 = 524288 flops, 12 iterations (+ trivial adds)
        assert cost.flops == pytest.approx(12 * 2 * 64**3, rel=0.01)

    def test_collective_multiplicity(self):
        prog = parse(CANNED_HLO)
        colls = collect_collectives(prog)
        assert len(colls) == 1
        spec, mult = colls[0]
        assert spec.kind == "all_reduce"
        assert spec.group_size == 4 and spec.num_groups == 2
        assert mult == 12
        totals = total_collective_bytes(prog)
        assert totals["all_reduce"] == pytest.approx(12 * 64 * 64 * 4)


class TestCommentStripping:
    def test_multiline_block_comment(self):
        # regression: _COMMENT_RE lacked re.DOTALL, so a /* ... */ that
        # spanned lines survived stripping and corrupted the op stream
        text = ("module @m {\n"
                "  /* header comment\n"
                "     spanning three\n"
                "     lines */\n"
                "  func.func public @main(%arg0: tensor<4x4xf32>) "
                "-> tensor<4x4xf32> {\n"
                "    %0 = stablehlo.add %arg0, %arg0 : tensor<4x4xf32>\n"
                "    return %0 : tensor<4x4xf32>\n"
                "  }\n"
                "}\n")
        for frontend in ("legacy", "streaming"):
            prog = parse_stablehlo(text, frontend=frontend)
            ops = [op.op for op in prog.walk()]
            assert ops == ["add"], frontend

    def test_inline_and_multiline_mixed(self):
        text = ("module @m { /* a */\n"
                "  func.func public @main(%arg0: tensor<2xf32>) "
                "-> tensor<2xf32> {\n"
                "    %0 = stablehlo.negate %arg0 : tensor<2xf32> "
                "/* trailing\n comment */\n"
                "    return %0 : tensor<2xf32>\n"
                "  }\n"
                "}\n")
        for frontend in ("legacy", "streaming"):
            prog = parse_stablehlo(text, frontend=frontend)
            assert [op.op for op in prog.walk()] == ["negate"], frontend


class TestStableHloParser:
    @pytest.fixture(scope="class")
    def export(self):
        def f(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(body, x, w)
            return jnp.sum(h.astype(jnp.float32) ** 2)
        w = jax.ShapeDtypeStruct((5, 64, 64), jnp.bfloat16)
        x = jax.ShapeDtypeStruct((32, 64), jnp.bfloat16)
        return jax.jit(jax.grad(f)).lower(w, x)

    def test_roundtrip_flops(self, export):
        prog = parse_stablehlo(export.as_text())
        assert prog.dialect == "stablehlo"
        cost = program_cost(prog)
        expected = 3 * 5 * 2 * 32 * 64 * 64   # fwd + 2 bwd dots x 5 layers
        assert cost.flops == pytest.approx(expected, rel=0.15)

    def test_while_trip_count(self, export):
        prog = parse_stablehlo(export.as_text())
        whiles = [op for op in prog.walk() if op.op == "while"]
        assert whiles and all(w.trip_count == 5 for w in whiles)

    def test_optimized_matches_raw_flops(self, export):
        raw = parse_stablehlo(export.as_text())
        opt = parse_hlo(export.compile().as_text())
        fr = program_cost(raw).flops
        fo = program_cost(opt).flops
        # same program, one device: parsed flops agree within 25 %
        # (fusion/rematerialization reshapes elementwise counts)
        assert fo == pytest.approx(fr, rel=0.25)
