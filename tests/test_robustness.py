"""Fault tolerance: the seeded fault-injection harness, campaign error
taxonomy / retries / resume, crash recovery across process kills and
torn cache appends, the client's timeout/retry/deadline contract, and
the serve fleet's routing, breaker, and supervision."""
import json
import os
import socket
import threading
import time

import pytest

from repro.campaign import CampaignSpec, run_campaign
from repro.campaign.runner import load_jsonl
from repro.serve import faults
from repro.serve.client import (TIMEOUT_HEADER, ServeClient, ServeError)
from repro.serve.faults import FaultInjected, FaultPlan
from repro.serve.fleet import _Breaker, request_class, route_index

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _no_leaked_faults():
    """Every test leaves the process fault-free."""
    yield
    for var in (faults.ENV_PLAN, faults.ENV_WORKER, faults.ENV_GENERATION):
        os.environ.pop(var, None)
    faults.install(None)


def gemm_spec(sizes=(64, 96), systems=("a100",)) -> CampaignSpec:
    """A small pure-python grid (len(sizes) x len(systems) roofline@raw
    jobs) that needs no files and runs in milliseconds."""
    return CampaignSpec.from_dict({
        "name": "robust-t",
        "workloads": [{"name": f"g{n}", "fidelity": "raw",
                       "gemm": {"m": n, "n": n, "k": n, "dtype": "bf16"}}
                      for n in sizes],
        "systems": list(systems),
        "estimators": [{"kind": "roofline"}],
        "slicers": ["linear"],
    })


# ------------------------------ fault plans ------------------------------


class TestFaultPlan:
    def test_at_range_is_seed_deterministic(self):
        doc = {"seed": 42, "faults": [
            {"site": "evaluate", "op": "error", "at": [1, 100]}]}
        a = FaultPlan(doc).faults[0].at
        b = FaultPlan(doc).faults[0].at
        assert a == b and 1 <= a <= 100

    def test_unknown_site_and_op_rejected(self):
        with pytest.raises(ValueError, match="unknown site"):
            FaultPlan({"faults": [{"site": "nope", "op": "error"}]})
        with pytest.raises(ValueError, match="unknown op"):
            FaultPlan({"faults": [{"site": "evaluate", "op": "explode"}]})

    def test_fires_at_counter_value_times_bounded(self):
        p = FaultPlan({"faults": [
            {"site": "evaluate", "op": "error", "at": 2}]})
        assert p.fire("evaluate") is None          # counter 1
        assert p.fire("evaluate").op == "error"    # counter 2: fires
        assert p.fire("evaluate") is None          # times=1 exhausted
        assert p.counters["evaluate"] == 3

    def test_worker_and_generation_filters(self):
        doc = {"faults": [
            {"site": "evaluate", "op": "error", "at": 1, "worker": 1}]}
        assert FaultPlan(doc, worker=0).fire("evaluate") is None
        assert FaultPlan(doc, worker=1).fire("evaluate") is not None
        # generation defaults to 0: a restarted worker (generation 1)
        # must NOT replay its predecessor's faults
        doc = {"faults": [{"site": "evaluate", "op": "error", "at": 1}]}
        assert FaultPlan(doc, generation=1).fire("evaluate") is None
        assert FaultPlan(doc, generation=0).fire("evaluate") is not None

    def test_context_match_filters(self):
        p = FaultPlan({"faults": [
            {"site": "evaluate", "op": "error", "at": 1,
             "workload": "g64"}]})
        assert p.fire("evaluate", workload="g96") is None
        p2 = FaultPlan({"faults": [
            {"site": "evaluate", "op": "error", "at": 1,
             "workload": "g64"}]})
        assert p2.fire("evaluate", workload="g64") is not None

    def test_trip_error_raises_fault_injected(self):
        faults.install({"faults": [
            {"site": "evaluate", "op": "error", "at": 1}]})
        with pytest.raises(FaultInjected, match="site=evaluate"):
            faults.trip("evaluate", workload="w")

    def test_env_round_trip_and_reresolution(self, monkeypatch):
        assert not faults.active()
        doc = {"faults": [{"site": "stream", "op": "reset", "at": 9}]}
        monkeypatch.setenv(faults.ENV_PLAN, json.dumps(doc))
        monkeypatch.setenv(faults.ENV_WORKER, "3")
        monkeypatch.setenv(faults.ENV_GENERATION, "2")
        assert faults.active()
        p = faults.plan()
        assert p.worker == 3 and p.generation == 2
        assert p.faults[0].site == "stream"
        monkeypatch.delenv(faults.ENV_PLAN)
        assert not faults.active()

    def test_env_accepts_plan_file(self, monkeypatch, tmp_path):
        path = tmp_path / "plan.json"
        path.write_text(json.dumps(
            {"faults": [{"site": "evaluate", "op": "hang", "at": 1,
                         "seconds": 0.01}]}))
        monkeypatch.setenv(faults.ENV_PLAN, str(path))
        assert faults.active()
        assert faults.plan().faults[0].seconds == 0.01

    def test_stats_reports_counters_and_fired(self):
        faults.install({"faults": [
            {"site": "evaluate", "op": "error", "at": 2}]})
        faults.fire("evaluate", workload="a")
        with pytest.raises(FaultInjected):
            faults.trip("evaluate", workload="b")
        st = faults.stats()
        assert st["counters"] == {"evaluate": 2}
        assert st["fired"] == [{"site": "evaluate", "op": "error",
                                "at": 2, "workload": "b"}]


# ------------------------ taxonomy, retries, resume ------------------------


class TestErrorTaxonomy:
    def test_injected_evaluate_error_row(self):
        faults.install({"faults": [
            {"site": "evaluate", "op": "error", "at": 1,
             "workload": "g64"}]})
        res = run_campaign(gemm_spec(), executor="serial")
        bad = [r for r in res.rows if "error" in r]
        assert len(bad) == 1
        assert bad[0]["error_type"] == "evaluate"
        assert "FaultInjected" in bad[0]["error"]
        assert res.summary["errors_by_type"] == {"evaluate": 1}
        assert res.summary["num_failed"] == 1

    def test_plan_failure_is_plan_type(self):
        # a provided in-memory workload with no IR text fails in the
        # plan phase (deterministic; retries must not touch it)
        from repro.core.pipeline import Workload
        spec = CampaignSpec.from_dict({
            "name": "t",
            "workloads": [{"name": "bad", "fidelity": "raw"}],
            "systems": ["a100"], "estimators": [{"kind": "roofline"}],
            "slicers": ["linear"]}, provided={"bad"})
        res = run_campaign(spec, executor="serial", retries=1,
                           workloads={"bad": Workload(name="bad")})
        assert all(r["error_type"] == "plan" for r in res.rows)
        assert "no raw text" in res.rows[0]["error"]
        assert res.summary["errors_by_type"] == {"plan": 1}
        assert res.retried_rows == 0    # plan errors are not retried

    def test_worker_sigkill_yields_transport_rows(self, monkeypatch,
                                                  tmp_path):
        """A SIGKILLed process-pool worker must not abort the campaign:
        every unfinished job gets a resumable transport error row."""
        monkeypatch.setenv(faults.ENV_PLAN, json.dumps(
            {"faults": [{"site": "evaluate", "op": "kill", "at": 1,
                         "times": 99}]}))
        out = str(tmp_path / "out")
        res = run_campaign(gemm_spec(sizes=(64, 96, 128, 160)),
                           executor="process", max_workers=2,
                           out_dir=out)
        assert len(res.rows) == 4          # no job silently vanished
        kinds = {r.get("error_type") for r in res.rows}
        assert kinds == {"transport"}
        assert res.summary["errors_by_type"] == {"transport": 4}
        # and the artifact on disk is parseable, ready for --resume
        rows = load_jsonl(os.path.join(out, "results.jsonl"))
        assert len(rows) == 4


class TestRetries:
    def test_retry_absorbs_one_shot_evaluate_error(self):
        faults.install({"faults": [
            {"site": "evaluate", "op": "error", "at": 1}]})
        res = run_campaign(gemm_spec(), executor="serial", retries=1)
        assert res.summary["num_failed"] == 0
        assert res.retried_rows == 1
        assert res.summary["retries"] == {"configured": 1,
                                          "rows_retried": 1}

    def test_no_retry_by_default(self):
        faults.install({"faults": [
            {"site": "evaluate", "op": "error", "at": 1}]})
        res = run_campaign(gemm_spec(), executor="serial")
        assert res.summary["num_failed"] == 1
        assert "retries" not in res.summary

    def test_retries_match_clean_run_thread_executor(self):
        clean = run_campaign(gemm_spec(), executor="serial")
        faults.install({"faults": [
            {"site": "evaluate", "op": "error", "at": 1}]})
        res = run_campaign(gemm_spec(), executor="thread", retries=2)
        assert res.summary["num_failed"] == 0
        t = {r["job_id"]: r["step_time_s"] for r in res.ok_rows}
        tc = {r["job_id"]: r["step_time_s"] for r in clean.ok_rows}
        assert t == tc


class TestResume:
    def test_resume_replays_trusted_rows_identically(self):
        clean = run_campaign(gemm_spec(sizes=(64, 96, 128)),
                             executor="serial")
        partial = clean.rows[:2]
        streamed = []
        res = run_campaign(gemm_spec(sizes=(64, 96, 128)),
                          executor="serial", resume_rows=partial,
                          on_row=streamed.append)
        assert res.resumed_rows == 2
        assert res.summary["resume"]["resumed"] == 2
        assert res.summary["resume"]["missing"] == 1
        t = {r["job_id"]: r["step_time_s"] for r in res.rows}
        tc = {r["job_id"]: r["step_time_s"] for r in clean.rows}
        assert t == tc
        # replayed rows are tagged and NOT re-streamed to on_row
        tagged = [r for r in res.rows if r.get("resumed")]
        assert len(tagged) == 2
        assert {r["job_id"] for r in streamed} == {
            r["job_id"] for r in res.rows} - {
            r["job_id"] for r in tagged}

    def test_error_rows_are_rerun_and_counted_by_type(self):
        clean = run_campaign(gemm_spec(), executor="serial")
        broken = [dict(r) for r in clean.rows]
        broken[0] = {**broken[0], "error": "Boom: injected",
                     "error_type": "evaluate"}
        broken[0].pop("step_time_s", None)
        res = run_campaign(gemm_spec(), executor="serial",
                           resume_rows=broken)
        rep = res.summary["resume"]
        assert rep["rerun_errors"] == 1
        assert rep["rerun_errors_by_type"] == {"evaluate": 1}
        assert res.summary["num_failed"] == 0

    def test_stale_rows_with_changed_axes_are_rerun(self):
        clean = run_campaign(gemm_spec(), executor="serial")
        stale = [dict(r) for r in clean.rows]
        stale[1]["system"] = "h100"     # no longer matches the grid
        res = run_campaign(gemm_spec(), executor="serial",
                           resume_rows=stale)
        assert res.summary["resume"]["stale"] == 1
        assert res.summary["num_failed"] == 0


class TestCrashRecovery:
    def test_sigkill_then_resume_reproduces_clean_run(self, monkeypatch,
                                                      tmp_path):
        """Satellite: SIGKILL a process-executor worker mid-campaign;
        results.jsonl stays parseable, the shared cache self-heals, and
        --resume completes the grid identically to an uninterrupted
        run."""
        cache = str(tmp_path / "hcr.jsonl")
        out = str(tmp_path / "out")
        spec = gemm_spec(sizes=(64, 96, 128, 160))
        monkeypatch.setenv(faults.ENV_PLAN, json.dumps(
            {"faults": [{"site": "evaluate", "op": "kill", "at": 2,
                         "times": 99}]}))
        res = run_campaign(spec, executor="process", max_workers=2,
                           out_dir=out, cache_path=cache)
        assert res.summary["num_failed"] >= 1
        monkeypatch.delenv(faults.ENV_PLAN)
        faults.install(None)

        partial = load_jsonl(os.path.join(out, "results.jsonl"))
        assert 0 < len(partial) == 4    # every job accounted for
        # the cache (possibly torn by the dead writer) must self-heal
        from repro.core.estimators.cache import PersistentCache
        healed = PersistentCache(cache)
        assert healed.stats_dict()["entries"] >= 0    # loads cleanly

        resumed = run_campaign(spec, executor="process", max_workers=2,
                               out_dir=out, cache_path=cache,
                               resume_rows=partial)
        assert resumed.summary["num_failed"] == 0
        clean = run_campaign(spec, executor="serial")
        t = {r["job_id"]: r["step_time_s"] for r in resumed.rows}
        tc = {r["job_id"]: r["step_time_s"] for r in clean.rows}
        assert t == tc

    def test_torn_cache_append_heals_without_losing_predictions(
            self, tmp_path):
        """op 'torn' chops the last record mid-line and skips the index
        step; the next open must recover every intact entry and the
        campaign's predictions must be unaffected."""
        cache = str(tmp_path / "hcr.jsonl")
        faults.install({"faults": [
            {"site": "cache_append", "op": "torn", "at": 1}]})
        res = run_campaign(gemm_spec(), executor="serial",
                          cache_path=cache)
        assert res.summary["num_failed"] == 0
        faults.install(None)
        from repro.core.estimators.cache import PersistentCache
        healed = PersistentCache(cache)
        warm = run_campaign(gemm_spec(), executor="serial",
                            cache_path=cache)
        assert warm.summary["num_failed"] == 0
        t = {r["job_id"]: r["step_time_s"] for r in warm.rows}
        tc = {r["job_id"]: r["step_time_s"] for r in res.rows}
        assert t == tc
        assert healed.stats_dict()["entries"] >= 0


# --------------------------- client transport ---------------------------


class _MiniServer:
    """A raw-socket stand-in daemon with scripted per-connection
    behavior: 'close' (accept then slam shut), 'stall' (accept and never
    answer), 'ok' (answer a canned JSON 200).  Records every connection
    and the raw bytes of 'ok' requests."""

    BODY = b'{"status": "ok"}'

    def __init__(self, script):
        self.script = list(script)
        self.connections = 0
        self.requests: list[bytes] = []
        self._held: list[socket.socket] = []  # stalled conns, kept open
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(8)
        self.url = "http://127.0.0.1:%d" % self.sock.getsockname()[1]
        self._thread = threading.Thread(target=self._serve, daemon=True)
        self._thread.start()

    def _serve(self):
        while self.script:
            mode = self.script.pop(0)
            try:
                conn, _ = self.sock.accept()
            except OSError:
                return
            self.connections += 1
            if mode == "close":
                conn.close()
                continue
            if mode == "stall":
                self._held.append(conn)   # open, never answered
                continue
            raw = conn.recv(65536)
            self.requests.append(raw)
            conn.sendall(b"HTTP/1.1 200 OK\r\n"
                         b"Content-Type: application/json\r\n"
                         b"Content-Length: %d\r\n\r\n%s"
                         % (len(self.BODY), self.BODY))
            conn.close()

    def close(self):
        self.sock.close()
        for conn in self._held:
            conn.close()


class TestClientTransport:
    def test_get_retries_through_transient_reset(self):
        srv = _MiniServer(["close", "ok"])
        try:
            c = ServeClient(srv.url, timeout_s=5, connect_retries=3,
                            backoff_s=0.01)
            assert c.healthz() == {"status": "ok"}
            assert srv.connections == 2
        finally:
            srv.close()

    def test_post_is_not_retried_on_midflight_reset(self):
        srv = _MiniServer(["close", "ok"])
        try:
            c = ServeClient(srv.url, timeout_s=5, connect_retries=3,
                            backoff_s=0.01)
            with pytest.raises(ServeError):
                c.predict("w")
            # one connection only: a reset POST may have half-executed,
            # so the client must NOT blind-retry it
            assert srv.connections == 1
        finally:
            srv.close()

    def test_get_timeout_bounded_and_retried(self):
        srv = _MiniServer(["stall", "ok"])
        try:
            c = ServeClient(srv.url, timeout_s=0.2, connect_retries=2,
                            backoff_s=0.01)
            t0 = time.monotonic()
            assert c.healthz() == {"status": "ok"}
            assert time.monotonic() - t0 < 5.0
        finally:
            srv.close()

    def test_deadline_caps_total_retry_time(self):
        srv = _MiniServer(["stall", "stall", "stall"])
        try:
            c = ServeClient(srv.url, timeout_s=10, connect_retries=5,
                            backoff_s=0.01, deadline_s=0.4)
            t0 = time.monotonic()
            with pytest.raises(ServeError, match="deadline"):
                c.healthz()
            assert time.monotonic() - t0 < 5.0
        finally:
            srv.close()

    def test_timeout_header_advertises_budget(self):
        srv = _MiniServer(["ok"])
        try:
            c = ServeClient(srv.url, timeout_s=7.5)
            c.healthz()
            assert TIMEOUT_HEADER.lower().encode() in \
                srv.requests[0].lower()
            assert b"7.5" in srv.requests[0]
        finally:
            srv.close()


# ------------------------------- fleet -------------------------------


class TestFleetRouting:
    def test_route_index_stable_and_in_range(self):
        cls = ("predict", "g64", "a100", "roofline")
        assert route_index(cls, 4) == route_index(cls, 4)
        for n in (1, 2, 3, 8):
            assert 0 <= route_index(cls, n) < n

    def test_distinct_classes_spread(self):
        idx = {route_index(("predict", f"g{n}", "a100", "roofline"), 8)
               for n in range(64, 64 + 16)}
        assert len(idx) > 1     # hashing, not constant

    def test_request_class_shapes(self):
        assert request_class("/predict", {
            "workload": "g64", "system": "a100",
            "estimator": "roofline"}) == \
            ("predict", "g64", "a100", "roofline")
        assert request_class("/predict", {
            "workload": {"name": "w", "gemm": {}},
            "estimator": {"kind": "systolic"}}) == \
            ("predict", "w", "a100", "systolic")
        assert request_class("/campaign", {
            "spec": {"name": "fig10"}}) == ("campaign", "fig10")
        assert request_class("/campaign", {
            "spec_path": "specs/x.json"}) == ("campaign", "specs/x.json")


class TestBreaker:
    def test_opens_after_threshold_consecutive_deaths(self):
        b = _Breaker(threshold=3, cooldown_s=60)
        cls = ("predict", "w")
        assert not b.record_death(cls)
        assert not b.record_death(cls)
        assert b.record_death(cls)
        assert b.is_open(cls)
        assert b.open_classes() == [["predict", "w"]]

    def test_success_resets_the_count(self):
        b = _Breaker(threshold=2, cooldown_s=60)
        cls = ("predict", "w")
        b.record_death(cls)
        b.record_success(cls)
        assert not b.record_death(cls)      # count restarted
        assert not b.is_open(cls)

    def test_cooldown_expiry_closes(self):
        b = _Breaker(threshold=1, cooldown_s=0.05)
        cls = ("campaign", "s")
        assert b.record_death(cls)
        assert b.is_open(cls)
        time.sleep(0.08)
        assert not b.is_open(cls)


class TestFleetReload:
    def test_reload_replays_degraded_fallback_preloads(self):
        """A fleet that has built its local degraded-mode service must
        replay that service's preloads on /reload too — otherwise
        breaker-open traffic keeps seeing the stale spec contents while
        the reload response claims success."""
        from repro.serve.fleet import FleetSupervisor

        class _StubService:
            def __init__(self):
                self.reloads = 0

            def reload(self):
                self.reloads += 1
                return {"specs": 1, "workloads": ["w"], "plans_built": 2}

        sup = FleetSupervisor(workers=2)
        try:
            svc = _StubService()
            sup._local_service = svc       # as if a breaker had opened
            rep = sup.reload_workers()     # no workers were ever spawned
        finally:
            sup.httpd.server_close()
        assert svc.reloads == 1
        local = [r for r in rep["workers"]
                 if r.get("worker") == "local-fallback"]
        assert local == [{"worker": "local-fallback", "specs": 1,
                          "workloads": ["w"], "plans_built": 2}]
        # the fallback's replay counts as a reloaded service
        assert rep["reloaded"] == 1
        assert sup.stats()["fleet"]["reloads"] == 1


class TestStreamFault:
    def test_midstream_reset_breaks_client_but_not_campaign(self):
        """A connection reset mid-NDJSON-stream surfaces as ServeError
        with rows_seen intact (enough to resume); the server finishes
        the campaign anyway, warming the shared store."""
        from repro.serve.server import PredictionServer, PredictionService
        faults.install({"faults": [
            {"site": "stream", "op": "reset", "at": 2}]})
        service = PredictionService()
        server = PredictionServer(service, port=0).start()
        try:
            client = ServeClient(server.url, connect_retries=0)
            spec = {"name": "t", "workloads": [
                {"name": f"g{n}", "fidelity": "raw",
                 "gemm": {"m": n, "n": n, "k": n, "dtype": "bf16"}}
                for n in (64, 96, 128)],
                "systems": ["a100"],
                "estimators": [{"kind": "roofline"}],
                "slicers": ["linear"]}
            stream = client.campaign(spec=spec, executor="serial")
            with pytest.raises(ServeError, match="stream"):
                list(stream)
            assert stream.rows_seen == 2
            # the campaign itself completed server-side
            deadline = time.monotonic() + 10
            while (service._campaign["served"] < 1
                   and time.monotonic() < deadline):
                time.sleep(0.02)
            assert service._campaign["served"] == 1
            assert service._campaign["rows"] == 3
        finally:
            faults.install(None)
            server.drain(timeout_s=10)


@pytest.mark.slow
class TestFleetIntegration:
    @pytest.fixture()
    def fleet(self, tmp_path):
        from repro.serve.fleet import FleetSupervisor
        sup = FleetSupervisor(workers=2,
                              cache_path=str(tmp_path / "hcr.jsonl"),
                              backoff_s=0.05, boot_timeout_s=60)
        sup.start()
        yield sup
        if not sup.stopped.is_set():
            sup.drain(timeout_s=15)

    def test_predict_routes_and_aggregates(self, fleet):
        client = ServeClient(fleet.url, timeout_s=60)
        client.wait_ready(timeout_s=30)
        body = {"name": "w64", "fidelity": "raw",
                "gemm": {"m": 64, "n": 64, "k": 64, "dtype": "bf16"}}
        row = client.predict(body)
        assert row["step_time_s"] > 0 and "degraded" not in row
        st = client.stats()
        assert st["fleet"]["workers"] == 2
        assert st["fleet"]["restarts"] == 0
        assert st["totals"]["predict_served"] == 1
        assert client.healthz() == {"status": "ok", "workers": 2,
                                    "alive": 2}

    def test_monitor_restarts_killed_worker(self, fleet):
        client = ServeClient(fleet.url, timeout_s=60)
        client.wait_ready(timeout_s=30)
        fleet._workers[0].proc.kill()
        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:
            st = client.stats()["fleet"]
            if st["restarts"] >= 1 and st["generations"][0] == 1:
                break
            time.sleep(0.1)
        else:
            pytest.fail(f"worker never restarted: {st}")
        assert client.healthz()["alive"] == 2
