"""Per-architecture smoke tests (deliverable f): a REDUCED config of the
same family runs one forward/train step on CPU, asserting output shapes
and no NaNs; decode runs one autoregressive step."""
import jax
import jax.numpy as jnp
import pytest

from repro.models import (ARCH_IDS, decode_step, forward, get_config,
                          get_smoke_config, init_cache_specs, model_specs,
                          shape_cells, skip_reason)
from repro.models.params import init_params, param_count

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    batch = {}
    if cfg.frontend == "stub":
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                            jnp.bfloat16)
    else:
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    batch["targets"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    if cfg.mrope_sections:
        pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        batch["mrope_positions"] = jnp.stack([pos, pos, pos])
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestSmoke:
    def test_forward_and_grad(self, arch):
        cfg = get_smoke_config(arch)
        params = init_params(model_specs(cfg), KEY)
        batch = _batch(cfg)
        b, s = 2, 64
        loss, logits = jax.jit(
            lambda p, bt: forward(cfg, p, bt))(params, batch)
        assert logits.shape == (b, s, cfg.vocab_size)
        assert jnp.isfinite(loss), f"{arch}: NaN loss"
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: NaN logits"
        grads = jax.jit(jax.grad(
            lambda p, bt: forward(cfg, p, bt)[0]))(params, batch)
        gsum = jax.tree.reduce(
            lambda a, x: a + jnp.sum(jnp.abs(x.astype(jnp.float32))),
            grads, 0.0)
        assert jnp.isfinite(gsum), f"{arch}: NaN grads"
        assert float(gsum) > 0, f"{arch}: zero grads"

    def test_train_step(self, arch):
        from repro.train.loop import make_train_step
        from repro.train.optimizer import OptimizerConfig, make_optimizer
        cfg = get_smoke_config(arch)
        opt_cfg = OptimizerConfig(warmup_steps=1)
        init_fn, _ = make_optimizer(opt_cfg)
        params = init_params(model_specs(cfg), KEY)
        opt = init_fn(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg))
        batch = _batch(cfg)
        p1, o1, m1 = step(params, opt, batch)
        p2, o2, m2 = step(p1, o1, batch)
        assert jnp.isfinite(m1["loss"]) and jnp.isfinite(m2["loss"])
        assert int(o2["step"]) == 2
        # params actually moved
        delta = jax.tree.reduce(
            lambda a, t: a + float(jnp.sum(jnp.abs(
                t[0].astype(jnp.float32) - t[1].astype(jnp.float32)))),
            jax.tree.map(lambda a, b_: (a, b_), params, p1), 0.0)
        assert delta > 0

    def test_decode_step(self, arch):
        cfg = get_smoke_config(arch)
        if cfg.is_encoder_only:
            pytest.skip("encoder-only: no decode step")
        params = init_params(model_specs(cfg), KEY)
        cache = init_params(init_cache_specs(cfg, 2, 32), KEY)
        tok = ({"tokens": jnp.zeros((2, 1), jnp.int32)}
               if cfg.frontend != "stub"
               else {"embeds": jnp.zeros((2, 1, cfg.d_model), jnp.bfloat16)})
        logits, cache = jax.jit(
            lambda p, c, b: decode_step(cfg, p, c, b))(params, cache, tok)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
        assert int(cache["index"]) == 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    """The FULL configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expected = {
        "mamba2-370m": dict(num_layers=48, d_model=1024, vocab_size=50280),
        "deepseek-67b": dict(num_layers=95, d_model=8192, num_heads=64,
                             num_kv_heads=8, d_ff=22016, vocab_size=102400),
        "stablelm-12b": dict(num_layers=40, d_model=5120, num_heads=32,
                             num_kv_heads=8, d_ff=13824, vocab_size=100352),
        "qwen2.5-32b": dict(num_layers=64, d_model=5120, num_heads=40,
                            num_kv_heads=8, d_ff=27648, vocab_size=152064,
                            qkv_bias=True),
        "gemma2-27b": dict(num_layers=46, d_model=4608, num_heads=32,
                           num_kv_heads=16, d_ff=36864, vocab_size=256000),
        "zamba2-2.7b": dict(num_layers=54, d_model=2560, num_heads=32,
                            num_kv_heads=32, d_ff=10240, vocab_size=32000),
        "deepseek-v3-671b": dict(num_layers=61, d_model=7168, num_heads=128,
                                 vocab_size=129280),
        "mixtral-8x22b": dict(num_layers=56, d_model=6144, num_heads=48,
                              num_kv_heads=8, vocab_size=32768),
        "hubert-xlarge": dict(num_layers=48, d_model=1280, num_heads=16,
                              d_ff=5120, vocab_size=504, causal=False),
        "qwen2-vl-7b": dict(num_layers=28, d_model=3584, num_heads=28,
                            num_kv_heads=4, d_ff=18944, vocab_size=152064),
    }[arch]
    for k, v in expected.items():
        assert getattr(cfg, k) == v, f"{arch}.{k}: {getattr(cfg, k)} != {v}"


def test_param_counts_plausible():
    """Param counts land near the advertised sizes."""
    for arch, lo, hi in [("mamba2-370m", 0.3e9, 0.45e9),
                         ("deepseek-67b", 60e9, 72e9),
                         ("stablelm-12b", 10e9, 14e9),
                         ("qwen2.5-32b", 28e9, 36e9),
                         ("gemma2-27b", 24e9, 31e9),
                         ("mixtral-8x22b", 130e9, 150e9),
                         ("deepseek-v3-671b", 600e9, 720e9),
                         ("zamba2-2.7b", 2.2e9, 3.2e9),
                         ("hubert-xlarge", 0.8e9, 1.4e9),
                         ("qwen2-vl-7b", 6.5e9, 9e9)]:
        total, active = get_config(arch).param_count()
        assert lo <= total <= hi, f"{arch}: {total/1e9:.1f}B not in " \
                                  f"[{lo/1e9:.0f}, {hi/1e9:.0f}]"
        assert active <= total


def test_shape_cell_assignment_rules():
    assert "long_500k" in shape_cells(get_config("mamba2-370m"))
    assert "long_500k" in shape_cells(get_config("zamba2-2.7b"))
    assert "long_500k" in shape_cells(get_config("mixtral-8x22b"))
    assert "long_500k" not in shape_cells(get_config("qwen2.5-32b"))
    assert "long_500k" not in shape_cells(get_config("gemma2-27b"))
    assert "decode_32k" not in shape_cells(get_config("hubert-xlarge"))
    assert skip_reason(get_config("hubert-xlarge"), "decode_32k")
    assert skip_reason(get_config("deepseek-67b"), "long_500k")
    assert skip_reason(get_config("mamba2-370m"), "train_4k") is None


def test_smoke_param_trees_match_full_structure():
    """Smoke and full configs produce the same tree structure per arch."""
    from repro.models.params import tree_paths
    for arch in ARCH_IDS:
        smoke = set(tree_paths(model_specs(get_smoke_config(arch))))
        full = set(tree_paths(model_specs(get_config(arch))))
        assert smoke == full, f"{arch}: smoke/full param trees differ"
