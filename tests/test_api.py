"""The ``repro.api`` facade: Session verbs, scoped registries,
third-party backends through the public API only, the shipped ``table``
estimator end-to-end, and the ``list`` CLI."""
import json
import os
import subprocess
import sys

import pytest

from repro import api
from repro.core.estimators.base import ComputeEstimator

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

GEMM_TEXT = """module @g {
  func.func public @main(%arg0: tensor<64x32xbf16>, %arg1: tensor<32x48xbf16>) -> tensor<64x48xbf16> {
    %0 = stablehlo.dot_general %arg0, %arg1, contracting_dims = [1] x [0], precision = [DEFAULT, DEFAULT] : (tensor<64x32xbf16>, tensor<32x48xbf16>) -> tensor<64x48xbf16>
    return %0 : tensor<64x48xbf16>
  }
}
"""


def _gemm_spec(**overrides):
    d = {
        "name": "api-t",
        "workloads": [{"name": "g", "fidelity": "raw",
                       "gemm": {"m": 64, "n": 48, "k": 32}}],
        "systems": ["a100"],
        "estimators": [{"kind": "roofline"}],
    }
    d.update(overrides)
    return d


# module level so the class pickles by reference into process workers
class FixedEstimator(ComputeEstimator):
    """Third-party-style backend: constant latency per region."""
    toolchain = "fixed"

    def __init__(self, system, latency=1e-6):
        super().__init__(system)
        self.latency = float(latency)

    @classmethod
    def from_spec(cls, options, system, context):
        return cls(system, latency=float(options.get("latency", 1e-6)))

    def get_run_time_estimate(self, region):
        return self.latency

    @property
    def cache_config_key(self):
        return f"lat{self.latency!r}"


MYCHIP = {
    "name": "MyChip-1", "peak_flops": {"bf16": 5e14}, "mem_bw": 2e12,
    "mem_capacity": 3.2e10,
    "interconnect": {"kind": "all_to_all", "link_bw": 1e11},
}


class TestSessionBasics:
    def test_describe_lists_vocabularies(self):
        info = api.Session().describe()
        assert "roofline" in info["estimators"]
        assert "table" in info["estimators"]
        assert "auto" in info["topologies"]
        ids = {s["id"] for s in info["systems"]}
        assert {"a100", "tpu-v3"} <= ids
        a100 = next(s for s in info["systems"] if s["id"] == "a100")
        assert a100["source"].endswith("a100.json")

    def test_workload_plan_predict(self):
        s = api.Session()
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        plan = s.plan(w, slicer="linear")
        assert plan.fidelity == "raw" and plan.compute_regions
        p = s.predict(plan, system="a100")
        assert p.step_time_s > 0
        # parity with the pre-facade entry points
        from repro.core.estimators import RooflineEstimator
        from repro.core.network import AllToAllNode
        from repro.core.pipeline import predict
        from repro.core.systems import get_system
        ref = predict(w.program("raw"), RooflineEstimator(get_system("a100")),
                      AllToAllNode(num_devices=4,
                                   link_bw=get_system("a100")
                                   .interconnect.link_bw),
                      slicer="linear", name="g")
        assert p.step_time_s == pytest.approx(ref.step_time_s)

    def test_predict_accepts_live_objects(self):
        from repro.core.estimators import RooflineEstimator
        from repro.core.network import AllToAllNode
        s = api.Session()
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        sysm = s.get_system("h100")
        p = s.predict(w, system=sysm,
                      estimator=RooflineEstimator(sysm),
                      topology=AllToAllNode(num_devices=2))
        assert p.system == sysm.name

    def test_predict_bad_types_rejected(self):
        s = api.Session()
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        with pytest.raises(TypeError, match="estimator"):
            s.predict(w, estimator=42)
        with pytest.raises(TypeError, match="topology"):
            s.predict(w, topology=42)

    def test_session_cache_store_shared_across_predicts(self):
        s = api.Session()
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        p1 = s.predict(w, system="a100")
        p2 = s.predict(w, system="a100")
        assert p1.cache_stats.misses > 0
        assert p2.cache_stats.misses == 0 and p2.cache_stats.hits > 0

    def test_export_verb(self):
        import jax
        import jax.numpy as jnp
        s = api.Session()
        w = s.export(jax.jit(lambda x: jnp.tanh(x @ x)),
                     jax.ShapeDtypeStruct((16, 16), jnp.float32),
                     name="tiny")
        assert w.stablehlo_text and w.hlo_text
        p = s.predict(w, system="a100")
        assert p.step_time_s > 0

    def test_persistent_cache_path_and_flush(self, tmp_path):
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.jsonl")
        s = api.Session(cache_path=path)
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        p = s.predict(w, system="a100")
        assert p.cache_stats.misses > 0
        s.flush_cache()
        # a fresh session over the same path serves pure hits
        s2 = api.Session(cache_path=path)
        p2 = s2.predict(s2.workload(name="g", stablehlo=GEMM_TEXT),
                        system="a100")
        assert p2.cache_stats.misses == 0 and p2.cache_stats.hits > 0
        assert len(PersistentCache(path)) > 0

    def test_load_spec_helper(self):
        spec = api.load_spec(os.path.join(REPO, "specs",
                                          "fig10_gemm.json"))
        assert spec.name == "fig10-gemm" and spec.num_points == 24

    def test_campaign_accepts_dict_and_path(self, tmp_path):
        s = api.Session()
        res = s.campaign(_gemm_spec())
        assert res.summary["num_failed"] == 0
        path = tmp_path / "spec.json"
        path.write_text(json.dumps(_gemm_spec()))
        res2 = s.campaign(str(path))
        assert [r["step_time_s"] for r in res2.ok_rows] == \
            [r["step_time_s"] for r in res.ok_rows]

    def test_campaign_path_spec_with_in_memory_workload(self, tmp_path):
        """A spec *file* whose workload entry is name-only must accept
        the workload supplied in-memory, same as the dict form."""
        s = api.Session()
        w = s.workload(name="mem", stablehlo=GEMM_TEXT)
        path = tmp_path / "spec.json"
        path.write_text(json.dumps(_gemm_spec(
            workloads=[{"name": "mem", "fidelity": "raw"}])))
        res = s.campaign(str(path), workloads={"mem": w})
        assert res.summary["num_failed"] == 0


class TestThirdPartyBackends:
    """Acceptance: a custom estimator + custom system registered via the
    public API only, driven from a campaign spec — no repro internals
    edited."""

    def test_custom_estimator_and_system_in_campaign(self):
        s = api.Session()
        s.register_estimator("fixed", FixedEstimator)
        s.register_system("mychip", MYCHIP)
        res = s.campaign(_gemm_spec(
            systems=["mychip"],
            estimators=[{"kind": "fixed", "options": {"latency": 3e-6}}]))
        assert res.summary["num_failed"] == 0
        (row,) = res.ok_rows
        assert row["system"] == "mychip"
        assert row["toolchain"] == "fixed"
        assert row["compute_s"] == pytest.approx(3e-6)

    def test_custom_backends_cross_process_boundary(self, tmp_path):
        s = api.Session()
        s.register_estimator("fixed", FixedEstimator)
        s.register_system("mychip", MYCHIP)
        res = s.campaign(
            _gemm_spec(
                systems=["mychip", "a100"],
                estimators=[{"kind": "fixed",
                             "options": {"latency": 3e-6}}]),
            executor="process", max_workers=2)
        assert res.summary["num_failed"] == 0
        assert {r["system"] for r in res.ok_rows} == {"mychip", "a100"}
        for row in res.ok_rows:
            assert row["compute_s"] == pytest.approx(3e-6)

    def test_scoped_kinds_do_not_leak(self):
        s = api.Session()
        s.register_estimator("fixed", FixedEstimator)
        s.register_system("mychip", MYCHIP)
        with pytest.raises(ValueError, match="unknown estimator kind"):
            api.Session().campaign(_gemm_spec(
                estimators=[{"kind": "fixed"}]))
        with pytest.raises(ValueError, match="unknown system"):
            api.Session().campaign(_gemm_spec(systems=["mychip"]))

    def test_custom_topology_kind(self):
        from repro.core.network.topology import AllToAllNode
        s = api.Session()

        @s.register_topology("pair")
        class PairTopology:
            @classmethod
            def from_spec(cls, params, system, context):
                return AllToAllNode(num_devices=2,
                                    link_bw=system.interconnect.link_bw)

        res = s.campaign(_gemm_spec(topologies=[{"kind": "pair"}]))
        assert res.summary["num_failed"] == 0
        assert res.ok_rows[0]["topology"] == "pair"

    def test_spec_system_catalog_field(self, tmp_path):
        path = tmp_path / "mychip.json"
        path.write_text(json.dumps({"id": "mychip", **MYCHIP}))
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps(_gemm_spec(
            systems=["mychip"], system_catalog=["mychip.json"])))
        # no session at all: the spec's own catalog paths suffice
        from repro.campaign.spec import CampaignSpec
        from repro.campaign.runner import run_campaign
        spec = CampaignSpec.from_json(str(spec_path))
        res = run_campaign(spec)
        assert res.summary["num_failed"] == 0
        assert res.ok_rows[0]["system"] == "mychip"


class TestTableEstimator:
    """The shipped proof-of-extensibility backend: record once with any
    estimator, replay from JSON through the same registry path."""

    def _profile(self, s, tmp_path):
        from repro.core.estimators import (RooflineEstimator,
                                           record_profile, save_profile)
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        plan = s.plan(w)
        table = record_profile(plan.compute_regions,
                               RooflineEstimator(s.get_system("a100")))
        assert table
        path = str(tmp_path / "profile.json")
        save_profile(path, table, meta={"system": "a100"})
        return w, plan, table, path

    def test_record_replay_roundtrip(self, tmp_path):
        s = api.Session()
        w, plan, table, path = self._profile(s, tmp_path)
        ref = s.predict(plan, system="a100", estimator="roofline")
        rep = s.predict(plan, system="a100", estimator="table",
                        options={"path": path})
        assert rep.step_time_s == pytest.approx(ref.step_time_s)
        assert rep.estimator == "table"

    def test_table_from_campaign_spec(self, tmp_path):
        s = api.Session()
        _, _, _, path = self._profile(s, tmp_path)
        res = s.campaign(_gemm_spec(
            estimators=[{"kind": "roofline"},
                        {"kind": "table", "options": {"path": path}}]))
        assert res.summary["num_failed"] == 0
        by_est = {r["estimator"]: r["step_time_s"] for r in res.ok_rows}
        # the profile path is a non-builtin option, so the row label
        # carries its digest (two tables with different profiles must
        # not alias to one label)
        from repro.campaign.spec import EstimatorSpec
        label = EstimatorSpec.from_dict(
            {"kind": "table", "options": {"path": path}}).label
        assert label.startswith("table-")
        assert by_est[label] == pytest.approx(by_est["roofline"])

    def test_table_scale_and_default(self, tmp_path):
        from repro.core.estimators import TableEstimator
        s = api.Session()
        _, plan, table, path = self._profile(s, tmp_path)
        scaled = s.predict(plan, system="a100", estimator="table",
                           options={"path": path, "scale": 2.0})
        base = s.predict(plan, system="a100", estimator="table",
                         options={"path": path})
        assert scaled.compute_s == pytest.approx(2 * base.compute_s)
        # uncovered fingerprint: strict raise vs default
        est = TableEstimator(s.get_system("a100"), {})
        region = plan.compute_regions[0]
        with pytest.raises(KeyError, match="no recorded latency"):
            est.get_run_time_estimate(region)
        assert not est.supports(region)
        est_d = TableEstimator(s.get_system("a100"), {}, default=7e-6)
        assert est_d.get_run_time_estimate(region) == 7e-6

    def test_table_default_is_scaled(self, tmp_path):
        """Regression: the fallback default must pick up ``scale`` just
        like recorded entries do (a derated replay table would otherwise
        mix scaled hits with unscaled misses)."""
        from repro.core.estimators import TableEstimator
        s = api.Session()
        _, plan, _, _ = self._profile(s, tmp_path)
        region = plan.compute_regions[0]
        est = TableEstimator(s.get_system("a100"), {}, default=7e-6,
                             scale=3.0)
        assert est.get_run_time_estimate(region) == pytest.approx(21e-6)
        # the cache config key digests both fields, so scaled-default
        # predictions can never alias an unscaled table's cache entries
        plain = TableEstimator(s.get_system("a100"), {}, default=7e-6)
        assert est.cache_config_key != plain.cache_config_key

    def test_table_profile_path_relative_to_spec_file(self, tmp_path):
        """A spec-file table estimator resolves its profile against the
        spec's directory, not the CWD — including across the process
        boundary."""
        s = api.Session()
        _, _, table, _ = self._profile(s, tmp_path)
        from repro.core.estimators import save_profile
        save_profile(str(tmp_path / "prof.json"), table)
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps(_gemm_spec(
            estimators=[{"kind": "table",
                         "options": {"path": "prof.json"}}])))
        assert not os.path.exists("prof.json")  # CWD must not matter
        for executor in ("serial", "process"):
            res = api.Session().campaign(str(spec_path),
                                         executor=executor)
            assert res.summary["num_failed"] == 0, res.rows

    def test_missing_path_option(self):
        s = api.Session()
        w = s.workload(name="g", stablehlo=GEMM_TEXT)
        with pytest.raises(ValueError, match="options.path"):
            s.predict(w, estimator="table")

    def test_profile_format_errors(self, tmp_path):
        from repro.core.estimators import load_profile
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps([1, 2]))
        with pytest.raises(ValueError, match="fingerprint"):
            load_profile(str(bad))
        flat = tmp_path / "flat.json"
        flat.write_text(json.dumps({"fp1": 1e-6}))
        assert load_profile(str(flat)) == {"fp1": 1e-6}

    def test_distinct_profiles_do_not_share_cache_keys(self, tmp_path):
        from repro.core.estimators import TableEstimator
        sysm = api.Session().get_system("a100")
        a = TableEstimator(sysm, {"fp": 1e-6})
        b = TableEstimator(sysm, {"fp": 2e-6})
        assert a.cache_config_key != b.cache_config_key
        assert a.cache_config_key == TableEstimator(
            sysm, {"fp": 1e-6}).cache_config_key


class TestAutoTopologyMismatch:
    def test_torus_num_devices_mismatch_raises(self):
        s = api.Session()
        res = s.campaign(_gemm_spec(
            systems=["tpu-v3"],   # dims (4, 2) -> 8 devices
            topologies=[{"kind": "auto", "params": {"num_devices": 4}}]))
        assert res.summary["num_failed"] == 1
        assert "num_devices=4" in res.rows[0]["error"]
        assert "dims=(4, 2)" in res.rows[0]["error"]

    def test_torus_matching_or_omitted_ok(self):
        s = api.Session()
        for topo in ({"kind": "auto"},
                     {"kind": "auto", "params": {"num_devices": 8}}):
            res = s.campaign(_gemm_spec(systems=["tpu-v3"],
                                        topologies=[topo]))
            assert res.summary["num_failed"] == 0

    def test_a2a_num_devices_still_honored(self):
        s = api.Session()
        res = s.campaign(_gemm_spec(
            systems=["a100"],
            topologies=[{"kind": "auto", "params": {"num_devices": 4}}]))
        assert res.summary["num_failed"] == 0


class TestListCLI:
    def test_list_prints_vocabularies_and_sources(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", "list", "--check"],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=60)
        assert p.returncode == 0, p.stdout + p.stderr
        assert "estimator kinds:" in p.stdout
        assert "table" in p.stdout
        assert "a100" in p.stdout
        assert "specs/systems/a100.json" in p.stdout
        assert "0 failure(s)" in p.stdout

    def test_list_check_rejects_bad_catalog(self, tmp_path):
        (tmp_path / "bad.json").write_text(json.dumps({"id": "bad"}))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", "list", "--check",
             "--systems", str(tmp_path)],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=60)
        assert p.returncode == 1
        assert "INVALID" in p.stdout
