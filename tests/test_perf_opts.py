"""Correctness of the §Perf optimizations: they must be exact (or bf16-
rounding-equivalent) rewrites of the baseline math."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import forward, get_smoke_config, model_specs
from repro.models.params import init_params


class TestPadHeads:
    """pad_heads: per-group padded Q heads masked before W_o (EXACT)."""

    def _embed_padded(self, p0, p1, kv, g_old, g_new):
        def head_map(i):
            return (i // g_old) * g_new + (i % g_old)

        def embed(a, b):
            if a.shape == b.shape:
                return a
            out = b
            ax = [i for i, (x, y) in enumerate(zip(a.shape, b.shape))
                  if x != y][0]
            for i in range(a.shape[ax]):
                src = tuple(slice(None) if d != ax else i
                            for d in range(a.ndim))
                dst = tuple(slice(None) if d != ax else head_map(i)
                            for d in range(a.ndim))
                out = out.at[dst].set(a[src])
            return out
        return jax.tree.map(embed, p0, p1)

    def test_exactness_and_zero_pad_grads(self):
        cfg0 = get_smoke_config("qwen2.5-32b")      # 4 heads, kv=2
        cfg1 = cfg0.scaled(pad_heads=2)             # group 2 -> 3
        p0 = init_params(model_specs(cfg0), jax.random.PRNGKey(0))
        p1 = init_params(model_specs(cfg1), jax.random.PRNGKey(1))
        p1 = self._embed_padded(p0, p1, kv=2, g_old=2, g_new=3)
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                         cfg0.vocab_size),
            "targets": jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                          cfg0.vocab_size),
        }
        l0, g0 = forward(cfg0, p0, batch)
        l1, g1 = forward(cfg1, p1, batch)
        assert float(abs(l0 - l1)) < 1e-6
        np.testing.assert_allclose(np.asarray(g0, np.float32),
                                   np.asarray(g1, np.float32), atol=2e-2)
        grads = jax.grad(lambda p, b: forward(cfg1, p, b)[0])(p1, batch)
        wq_g = grads["layers"]["attn"]["wq"]
        assert float(jnp.abs(wq_g[:, :, [2, 5]]).sum()) == 0.0


class TestChunkedLoss:
    def test_matches_full_loss(self):
        cfg0 = get_smoke_config("stablelm-12b")
        cfg1 = cfg0.scaled(loss_vocab_chunk=100)   # 256 vocab -> 3 chunks
        params = init_params(model_specs(cfg0), jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                         cfg0.vocab_size),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 16), 0,
                                          cfg0.vocab_size),
        }
        l0, _ = forward(cfg0, params, batch)
        l1, logits1 = forward(cfg1, params, batch)
        assert logits1 is None
        assert float(abs(l0 - l1)) < 1e-3

    def test_grads_match(self):
        cfg0 = get_smoke_config("stablelm-12b")
        cfg1 = cfg0.scaled(loss_vocab_chunk=64)
        params = init_params(model_specs(cfg0), jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                                         cfg0.vocab_size),
            "targets": jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0,
                                          cfg0.vocab_size),
        }
        g0 = jax.grad(lambda p: forward(cfg0, p, batch)[0])(params)
        g1 = jax.grad(lambda p: forward(cfg1, p, batch)[0])(params)
        flat0 = jax.tree.leaves(g0)
        flat1 = jax.tree.leaves(g1)
        for a, b in zip(flat0, flat1):
            a = np.asarray(a, np.float32)
            b = np.asarray(b, np.float32)
            # bf16 params: chunked-scan vs single-GEMM accumulation order
            # differs; compare in relative-Frobenius terms
            denom = np.linalg.norm(a) + 1e-9
            assert np.linalg.norm(a - b) / denom < 0.02, \
                np.linalg.norm(a - b) / denom


class TestMoEShardMap:
    """moe_forward_ep ≡ moe_forward on multi-device meshes (subprocess —
    the device count is locked in the main test process)."""

    @pytest.mark.slow
    def test_both_schemes_multi_device(self, tmp_path):
        import subprocess
        import sys
        script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys; sys.path.insert(0, %r)
import jax, jax.numpy as jnp
from dataclasses import replace
from repro.models import get_smoke_config
from repro.models.mlp import moe_forward, moe_forward_ep, moe_specs
from repro.models.params import init_params
from repro.launch.mesh import make_mesh

def check(cfg, mesh_shape):
    p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    mesh = make_mesh(mesh_shape, ("data", "model"))
    with mesh:
        y0 = jax.jit(lambda p, x: moe_forward(cfg, p, x))(p, x)
        y1 = jax.jit(lambda p, x: moe_forward_ep(cfg, p, x))(p, x)
    import numpy as np
    a = np.asarray(y0, np.float32); b = np.asarray(y1, np.float32)
    rel = np.abs(a - b).max() / (np.abs(a).max() + 1e-6)
    assert rel < 0.02, rel

cfg = get_smoke_config("mixtral-8x22b")            # 4 experts
check(cfg, (1, 4))                                  # expert scheme (4e/4)
check(cfg, (2, 2))                                  # expert scheme (4e/2)
cfg2 = cfg.scaled(moe=replace(cfg.moe, num_experts=2, d_ff_expert=64))
check(cfg2, (1, 4))                                 # ffn scheme (2e on 4)
print("OK")
""" % os.path.join(os.path.dirname(__file__), "..", "src")
        path = tmp_path / "ep_check.py"
        path.write_text(script)
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        proc = subprocess.run([sys.executable, str(path)], env=env,
                              capture_output=True, text=True, timeout=600)
        assert proc.returncode == 0, proc.stdout + proc.stderr
        assert "OK" in proc.stdout

    def test_single_device_fallback(self):
        """No mesh -> falls back to the reference path."""
        from repro.models.mlp import moe_forward, moe_forward_ep, moe_specs
        cfg = get_smoke_config("mixtral-8x22b")
        p = init_params(moe_specs(cfg), jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                              jnp.bfloat16)
        y0 = moe_forward(cfg, p, x)
        y1 = moe_forward_ep(cfg, p, x)
        np.testing.assert_allclose(np.asarray(y0, np.float32),
                                   np.asarray(y1, np.float32), atol=1e-5)
