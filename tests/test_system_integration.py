"""System-level integration: a dry-run cell in a subprocess (512 host
devices), dry-run artifact schema, end-to-end perf-predict example."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.join(os.path.dirname(__file__), "..")


@pytest.mark.slow
def test_dryrun_cell_subprocess(tmp_path):
    """One real (arch × shape × mesh) cell compiles on the 16×16 mesh."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-370m", "--shape", "decode_32k",
         "--out", str(tmp_path)],
        cwd=REPO, env=env, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-2000:]
    path = tmp_path / "mamba2-370m__decode_32k__16x16.json"
    rec = json.loads(path.read_text())
    assert rec["status"] == "ok"
    assert rec["roofline"]["dominant"] in ("compute_s", "memory_s",
                                           "collective_s")
    assert rec["parsed_per_chip"]["flops"] > 0


def test_dryrun_artifacts_complete():
    """All 80 cells exist and none failed (the sweep must have been run)."""
    art = os.path.join(REPO, "artifacts", "dryrun")
    if not os.path.isdir(art):
        pytest.skip("dry-run sweep not yet executed")
    from repro.configs.base import SHAPES
    from repro.models import ARCH_IDS
    missing, failed = [], []
    for mesh in ("16x16", "2x16x16"):
        for arch in ARCH_IDS:
            for shape in SHAPES:
                p = os.path.join(art, f"{arch}__{shape}__{mesh}.json")
                if not os.path.exists(p):
                    missing.append((arch, shape, mesh))
                    continue
                rec = json.load(open(p))
                if rec["status"] == "fail":
                    failed.append((arch, shape, mesh))
    assert not missing, f"missing cells: {missing[:5]}"
    assert not failed, f"failed cells: {failed[:5]}"


def test_examples_importable():
    import importlib.util
    for name in ("quickstart", "perf_predict", "train_lm", "serve_decode"):
        path = os.path.join(REPO, "examples", f"{name}.py")
        assert os.path.exists(path), f"missing example {name}"
        spec = importlib.util.spec_from_file_location(name, path)
        assert spec is not None
