"""Property-based tests for the batched PersistentCache operations.

Two invariants the campaign evaluate phase leans on:

* **round-trip equivalence** — any interleaving of ``put_many`` /
  ``get_many`` across two live handles on one shared log is
  observationally identical to the same interleaving expressed as
  single-entry ``append`` / ``refresh``+``get`` operations (same
  lookup results, same final on-disk entries and persisted costs);
* **lock economy** — a batched operation takes at most one flock
  round-trip regardless of batch size (``put_many`` exactly one for a
  non-empty batch; ``get_many`` at most one, and zero when every key is
  already in memory).
"""
import os
import tempfile

import pytest

pytest.importorskip(
    "hypothesis",
    reason="property-based tests need the hypothesis dev dependency "
           "(pip install -e .[dev])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.estimators.cache import PersistentCache  # noqa: E402

KEYS = st.sampled_from([f"k{i}" for i in range(6)])
KEY_SETS = st.lists(KEYS, min_size=1, max_size=4, unique=True)


def value_of(key: str) -> float:
    """Deterministic value per key — the domain invariant the cache's
    last-writer-wins races lean on: an (H, C, R) key always evaluates to
    the same latency, so re-puts are idempotent.  (Floats round-trip
    exactly through the JSON log, so the model compares with ``==``.)"""
    return (int(key[1:]) + 1) * 1.359375


def cost_of(key: str) -> float:
    return (int(key[1:]) + 1) * 0.265625


def records_for(keys: list[str]) -> dict:
    return {k: (value_of(k), cost_of(k)) for k in keys}


RECORDS = KEY_SETS.map(records_for)


@st.composite
def interleavings(draw):
    """Arbitrary op sequences over two handles (a, b) on one log:
    ('put', handle, records) and ('get', handle, keys)."""
    ops = []
    for _ in range(draw(st.integers(min_value=1, max_value=10))):
        handle = draw(st.sampled_from(["a", "b"]))
        if draw(st.booleans()):
            ops.append(("put", handle, draw(RECORDS)))
        else:
            ops.append(("get", handle,
                        draw(st.lists(KEYS, min_size=1, max_size=5))))
    return ops


class TestBatchedOpsRoundTrip:
    @given(ops=interleavings())
    @settings(max_examples=40, deadline=None)
    def test_batched_matches_single_ops_under_interleaving(self, ops):
        """Replay one op sequence through batched ops (put_many/get_many)
        and through single ops (append / refresh+get) on separate logs:
        every lookup and both final stores must agree with the model."""
        with tempfile.TemporaryDirectory() as d:
            batched_path = os.path.join(d, "batched.jsonl")
            single_path = os.path.join(d, "single.jsonl")
            batched = {"a": PersistentCache(batched_path),
                       "b": PersistentCache(batched_path)}
            single = {"a": PersistentCache(single_path),
                      "b": PersistentCache(single_path)}
            model: dict[str, float] = {}
            costs: dict[str, float] = {}
            for kind, handle, payload in ops:
                if kind == "put":
                    batched[handle].put_many(payload)
                    for k, (v, c) in payload.items():
                        single[handle].append(k, v, cost=c)
                        model[k] = v
                        costs[k] = c
                else:
                    got_b = batched[handle].get_many(payload)
                    single[handle].refresh()
                    got_s = {k: single[handle].get(k) for k in payload
                             if k in single[handle]}
                    expect = {k: model[k] for k in payload if k in model}
                    assert got_b == expect
                    assert got_s == expect
            # final on-disk state: a fresh load of either log sees the
            # same entries and the same persisted per-key costs
            fresh_b = PersistentCache(batched_path)
            fresh_s = PersistentCache(single_path)
            assert dict(fresh_b.entries) == dict(fresh_s.entries) == model
            assert {k: fresh_b.cost(k) for k in model} \
                == {k: fresh_s.cost(k) for k in model} == costs

    @given(records=RECORDS)
    @settings(max_examples=25, deadline=None)
    def test_pathless_put_many_matches_setitem(self, records):
        pc = PersistentCache()
        pc.put_many(records)
        assert dict(pc.entries) == {k: v for k, (v, _) in records.items()}
        assert pc.lock_roundtrips == 0  # nothing to lock without a log


class TestLockEconomy:
    @given(batches=st.lists(RECORDS, min_size=1, max_size=5))
    @settings(max_examples=25, deadline=None)
    def test_put_many_is_one_roundtrip_per_batch(self, batches):
        """``lock_roundtrips`` never exceeds one per batch, no matter the
        batch size or how many batches preceded it."""
        with tempfile.TemporaryDirectory() as d:
            pc = PersistentCache(os.path.join(d, "hcr.jsonl"))
            base = pc.lock_roundtrips
            for batch in batches:
                before = pc.lock_roundtrips
                pc.put_many(batch)
                assert pc.lock_roundtrips == before + 1
            assert pc.lock_roundtrips == base + len(batches)
            pc.put_many({})  # empty batch: no lock at all
            assert pc.lock_roundtrips == base + len(batches)

    @given(written=RECORDS, lookups=st.lists(KEYS, min_size=1, max_size=6))
    @settings(max_examples=25, deadline=None)
    def test_get_many_is_at_most_one_roundtrip(self, written, lookups):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "hcr.jsonl")
            writer, reader = PersistentCache(path), PersistentCache(path)
            writer.put_many(written)
            before = reader.lock_roundtrips
            got = reader.get_many(lookups)
            assert reader.lock_roundtrips <= before + 1
            assert got == {k: written[k][0] for k in lookups
                           if k in written}
            # every key now in memory: the next batch takes no lock
            before = reader.lock_roundtrips
            reader.get_many(lookups)
            assert reader.lock_roundtrips == before
