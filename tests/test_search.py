"""The `repro.search` subsystem: Pareto filter units, spec validation,
engine behavior on a tiny inline grid, and the checked-in search specs —
golden frontier snapshots plus the prune-soundness guarantee (the
fidelity ladder must land on exactly the frontier a top-rung brute
force finds, while scoring well under half the grid there)."""
import os
import random

import pytest

from repro import api
from repro.search.pareto import dominates, pareto_filter
from repro.search.report import (build_search_report, check_frontier,
                                 golden_path, load_json,
                                 make_frontier_golden)
from repro.search.spec import SearchSpec

REPO = os.path.join(os.path.dirname(__file__), os.pardir)
SPECS = {
    "gemm": os.path.join(REPO, "specs", "search_gemm.json"),
    "serving": os.path.join(REPO, "specs", "search_serving.json"),
}


class TestDominates:
    def test_strict_domination(self):
        assert dominates((1.0, 1.0), (2.0, 2.0))
        assert not dominates((2.0, 2.0), (1.0, 1.0))

    def test_equal_vectors_never_dominate(self):
        assert not dominates((1.0, 2.0), (1.0, 2.0))
        assert not dominates((1.0, 2.0), (1.0, 2.0), eps=0.5)

    def test_partial_improvement_is_not_domination(self):
        assert not dominates((1.0, 3.0), (2.0, 2.0))

    def test_epsilon_blocks_near_ties(self):
        # b is only 20% worse on both axes: inside eps=0.25 slack
        assert dominates((1.0, 1.0), (1.2, 1.2), eps=0.0)
        assert not dominates((1.0, 1.0), (1.2, 1.2), eps=0.25)
        assert dominates((1.0, 1.0), (1.2, 1.2), eps=0.1)

    def test_arity_mismatch_raises(self):
        with pytest.raises(ValueError, match="arity"):
            dominates((1.0,), (1.0, 2.0))


class TestParetoFilter:
    POINTS = {
        "a": (1.0, 4.0),
        "b": (2.0, 2.0),
        "c": (4.0, 1.0),
        "d": (3.0, 3.0),     # dominated by b
        "e": (2.0, 2.0),     # exact tie with b: both survive at eps=0
    }

    def test_domination_and_ties(self):
        assert pareto_filter(self.POINTS) == ["a", "b", "c", "e"]

    def test_epsilon_widens_the_prune(self):
        pts = {"x": (1.0, 1.0), "y": (1.1, 1.1), "z": (5.0, 5.0)}
        assert pareto_filter(pts, eps=0.0) == ["x"]
        # y is within 25% of x on every axis: ε keeps it alive
        assert pareto_filter(pts, eps=0.25) == ["x", "y"]

    def test_shuffled_input_order_is_irrelevant(self):
        ids = list(self.POINTS)
        want = pareto_filter(self.POINTS)
        rng = random.Random(7)
        for _ in range(10):
            rng.shuffle(ids)
            shuffled = {k: self.POINTS[k] for k in ids}
            assert pareto_filter(shuffled) == want

    def test_single_point_survives(self):
        assert pareto_filter({"only": (3.0, 3.0)}) == ["only"]


class TestSpecValidation:
    BASE = {
        "name": "t",
        "workloads": [{"name": "g", "fidelity": "raw",
                       "gemm": {"m": 256, "n": 256, "k": 256,
                                "dtype": "bf16"}}],
        "systems": ["a100"],
        "objectives": ["step_time_s", "usd_per_step"],
        "ladder": [{"kind": "roofline"}],
    }

    def _spec(self, **over):
        return SearchSpec.from_dict({**self.BASE, **over})

    def test_valid_spec_round_trips(self):
        spec = self._spec()
        again = SearchSpec.from_dict(spec.to_dict())
        assert again.objectives == spec.objectives
        assert again.epsilon == spec.epsilon

    def test_unknown_objective_rejected(self):
        with pytest.raises(ValueError, match="unknown objectives"):
            self._spec(objectives=["step_time_s", "happiness"])

    def test_single_objective_rejected(self):
        with pytest.raises(ValueError, match="two distinct objectives"):
            self._spec(objectives=["step_time_s"])

    def test_negative_epsilon_rejected(self):
        with pytest.raises(ValueError, match="epsilon"):
            self._spec(epsilon=-0.1)

    def test_unknown_constraint_rejected(self):
        with pytest.raises(ValueError, match="unknown constraints"):
            self._spec(constraints={"max_vibes": 1.0})

    def test_non_positive_ceiling_rejected(self):
        with pytest.raises(ValueError, match="positive"):
            self._spec(constraints={"max_step_time_s": 0})

    def test_ceiling_on_unscored_metric_rejected(self):
        # ceilings are enforced on the scored objective vectors; a
        # ceiling on a metric outside the objectives would be silently
        # ignored, so the spec must couple them
        with pytest.raises(ValueError, match="among the objectives"):
            self._spec(constraints={"max_joules_per_step": 1.0})

    def test_empty_ladder_rejected(self):
        with pytest.raises(ValueError, match="ladder"):
            self._spec(ladder=[])

    def test_unknown_spec_key_rejected(self):
        with pytest.raises(ValueError, match="unknown search spec keys"):
            self._spec(objective=["step_time_s"])


TINY = {
    "name": "tiny",
    "workloads": [
        {"name": "gemm-512", "fidelity": "raw",
         "gemm": {"m": 512, "n": 512, "k": 512, "dtype": "bf16"}},
        {"name": "gemm-2048", "fidelity": "raw",
         "gemm": {"m": 2048, "n": 2048, "k": 2048, "dtype": "bf16"}},
    ],
    "systems": ["a100", "h100"],
    "objectives": ["step_time_s", "usd_per_step"],
    "ladder": [{"kind": "roofline"},
               {"kind": "systolic", "options": {"preset": "scalesim"}}],
    "constraints": {"mem_capacity_fit": True},
    "topologies": [{"kind": "a2a", "params": {"num_devices": 1}},
                   {"kind": "a2a", "params": {"num_devices": 4}}],
}


class TestEngineTinyGrid:
    @pytest.fixture(scope="class")
    def run(self):
        return api.Session().search(TINY)

    def test_counters_account_for_every_candidate(self, run):
        c = run.counters
        assert c["candidates"] == 8          # 2 workloads × 2 systems × 2 topo
        assert c["infeasible"] == 0
        live = c["candidates"] - c["infeasible"]
        pruned = (c["pruned_ceiling"] + c["pruned_intra"]
                  + c["pruned_dominated"] + c["final_infeasible"])
        assert c["frontier_size"] <= live - pruned
        assert c["top_rung_evaluations"] < c["candidates"]

    def test_frontier_matches_brute_force(self, run):
        brute = api.Session().search(TINY, brute_force=True)
        assert run.frontier == brute.frontier
        for k in run.frontier:
            assert run.candidates[k]["values"] == \
                brute.candidates[k]["values"]

    def test_provenance_rungs_sorted_and_top_is_final(self, run):
        for k in run.frontier:
            rungs = [e["rung"] for e in run.candidates[k]["rungs"]]
            assert rungs == sorted(rungs)
            assert rungs[-1] == 1            # values come from the top rung
            assert run.candidates[k]["rungs"][-1]["values"] == \
                run.candidates[k]["values"]

    def test_determinism(self, run):
        again = api.Session().search(TINY)
        assert make_frontier_golden(build_search_report(again)) == \
            make_frontier_golden(build_search_report(run))

    def test_impossible_ceiling_empties_the_frontier(self):
        spec = dict(TINY, constraints={"max_step_time_s": 1e-12})
        res = api.Session().search(spec)
        assert res.frontier == []
        c = res.counters
        assert c["pruned_ceiling"] + c["final_infeasible"] > 0

    def test_mem_capacity_infeasibility(self):
        spec = dict(TINY)
        spec["workloads"] = [
            {"name": "gemm-huge", "fidelity": "raw",
             "gemm": {"m": 131072, "n": 131072, "k": 131072,
                      "dtype": "f32"}}]
        res = api.Session().search(spec)
        assert all(not r["feasible"] for r in res.candidates.values())
        assert all("mem_capacity_fit" in r["reason"]
                   for r in res.candidates.values())
        assert res.frontier == []

    def test_warm_session_reuses_everything(self):
        session = api.Session()
        session.search(TINY)
        res = session.search(TINY)
        assert res.counters["cache_misses"] == 0
        assert res.counters["cache_hits"] > 0


class TestCheckedInSpecs:
    @pytest.fixture(scope="class", params=sorted(SPECS))
    def runs(self, request):
        path = SPECS[request.param]
        session = api.Session()
        ladder = session.search(path)
        brute = session.search(path, brute_force=True)
        return path, ladder, brute

    def test_golden_frontier_snapshot(self, runs):
        path, ladder, _ = runs
        report = build_search_report(ladder)
        golden = load_json(golden_path(path, report["search"]))
        assert golden is not None, \
            f"golden missing — run `python -m repro.search run {path} " \
            f"--update-golden`"
        assert check_frontier(golden, report) == []

    def test_prune_soundness_vs_brute_force(self, runs):
        """No analytically-pruned candidate may be Pareto-optimal at the
        top fidelity: ladder and brute-force frontiers must agree."""
        _, ladder, brute = runs
        assert ladder.frontier == brute.frontier
        pruned = {k for k, r in ladder.candidates.items()
                  if r.get("pruned")}
        assert pruned.isdisjoint(brute.frontier)

    def test_top_rung_economy(self, runs):
        _, ladder, _ = runs
        c = ladder.counters
        assert 0 < c["top_rung_fraction"] < 0.5
        assert c["top_rung_evaluations"] < c["candidates"]

    def test_cost_columns_present(self, runs):
        _, ladder, _ = runs
        report = build_search_report(ladder)
        for p in report["frontier"]:
            assert "usd_per_step" in p["values"]
            assert "perf_per_usd" in p["extras"]
