"""Plan-based campaign execution: parse/slice exactly once per
(workload, fidelity, slicer) under every executor, deterministic
locality scheduling with zero duplicate cold misses, batched cache ops
with per-region-identical CacheStats, and bit-identical parity with the
pre-plan per-job/per-region path on the checked-in spec grids."""
import os

import pytest

from repro.campaign import CampaignSpec, run_campaign
from repro.campaign.plans import PlanStore
from repro.campaign.runner import _build_plans, _schedule_chains, load_jsonl
from repro.core.estimators.cache import CachedEstimator, PersistentCache
from repro.core.pipeline import PredictionJob, Workload, build_plan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPECS = os.path.join(REPO, "specs")


def _gemm_spec(**overrides):
    d = {
        "name": "plan-t",
        "workloads": [
            {"name": "gemm-256", "fidelity": "raw",
             "gemm": {"m": 256, "n": 256, "k": 256, "dtype": "bf16"}},
            {"name": "gemm-512", "fidelity": "raw",
             "gemm": {"m": 512, "n": 512, "k": 512, "dtype": "bf16"}},
        ],
        "systems": ["a100", "h100"],
        "estimators": [{"kind": "roofline"}],
        "slicers": ["linear", "dep"],
        "topologies": [{"kind": "a2a", "params": {"num_devices": 1}},
                       {"kind": "a2a", "params": {"num_devices": 4}}],
    }
    d.update(overrides)
    return CampaignSpec.from_dict(d)


def _stacked_text(shapes) -> str:
    """Independent dot_generals split by optimization_barriers — one
    compute region per GEMM under the linear slicer (no jax needed)."""
    from repro.campaign.builders import synthesize_gemm_stack
    return synthesize_gemm_stack(shapes)


def _counters():
    from repro.core.ir import parser
    from repro.core.slicing import depaware, linear
    return (parser.PARSE_CALLS,
            linear.SPLIT_CALLS + depaware.SPLIT_CALLS)


# ------------------------------- plan reuse --------------------------------


class TestPlanReuse:
    @pytest.mark.parametrize("executor", ["serial", "thread", "process"])
    def test_parse_and_slice_once_per_key(self, executor):
        """16 grid points over 2 workloads × 2 slicers must cost exactly
        2 parses and 4 slicer runs — in the parent process, under every
        executor (process workers receive pickled plans, never text)."""
        parse0, slice0 = _counters()
        res = run_campaign(_gemm_spec(), executor=executor, max_workers=4)
        parse1, slice1 = _counters()
        assert res.summary["num_failed"] == 0
        assert res.summary["num_ok"] == 16
        assert res.plans["parse_calls"] == parse1 - parse0 == 2
        assert res.plans["plans_built"] == slice1 - slice0 == 4
        assert res.plans["plan_keys"] == 4

    def test_two_slicers_share_one_parse(self):
        store = PlanStore({"w": {"raw": _stacked_text([(64, 64, 64)]),
                                 "optimized": None}})
        a = store.get("w", "raw", "linear")
        b = store.get("w", "raw", "dep")
        assert store.parse_count == 1 and store.plans_built == 2
        assert a.program is b.program
        # repeated gets return the same plan object, no rebuild
        assert store.get("w", "raw", "linear") is a
        assert store.plans_built == 2

    def test_effective_fidelity_resolves_to_plan_key(self):
        store = PlanStore({"w": {"raw": _stacked_text([(64, 64, 64)]),
                                 "optimized": None}})
        plan = store.get("w", "optimized", "linear")  # falls back to raw
        assert plan.fidelity == "raw"
        assert store.get("w", "raw", "linear") is plan

    def test_add_texts_keeps_identical_names_hot(self):
        """A long-lived store (the serve daemon, a multi-campaign
        session): re-registering a name with identical texts must keep
        its parsed program and plans — that is the whole warm-reuse
        point."""
        texts = {"w": {"raw": _stacked_text([(64, 64, 64)]),
                       "optimized": None}}
        store = PlanStore(texts)
        plan = store.get("w", "raw", "linear")
        store.add_texts({"w": dict(texts["w"])})
        assert store.get("w", "raw", "linear") is plan
        assert store.parse_count == 1 and store.plans_built == 1

    def test_add_texts_invalidates_changed_names(self):
        """Binding a name to different text must drop everything cached
        under it — a reused workload name can never serve a stale plan."""
        store = PlanStore({"w": {"raw": _stacked_text([(64, 64, 64)]),
                                 "optimized": None},
                           "keep": {"raw": _stacked_text([(48, 48, 48)]),
                                    "optimized": None}})
        old = store.get("w", "raw", "linear")
        kept = store.get("keep", "raw", "linear")
        old_fp = store.fingerprint_set(("w", "raw", "linear"))
        store.add_texts({"w": {"raw": _stacked_text([(96, 96, 96)]),
                               "optimized": None}})
        new = store.get("w", "raw", "linear")
        assert new is not old
        assert store.fingerprint_set(("w", "raw", "linear")) != old_fp
        assert store.get("keep", "raw", "linear") is kept  # untouched
        assert store.parse_count == 3

    def test_add_texts_is_how_warm_campaigns_share_plans(self):
        """run_campaign(plan_store=...) twice over one warm store: the
        second run parses and slices nothing."""
        store = PlanStore()
        res1 = run_campaign(_gemm_spec(), plan_store=store)
        res2 = run_campaign(_gemm_spec(), plan_store=store)
        assert res1.plans["parse_calls"] == 2
        assert res2.plans["parse_calls"] == 0
        assert res2.plans["plans_built"] == 0
        assert res2.summary["num_ok"] == 16
        assert [r["step_time_s"] for r in res2.rows] == \
            [r["step_time_s"] for r in res1.rows]

    def test_plan_files_round_trip_workers(self, tmp_path):
        """The process-worker path: plans cross the boundary as pickled
        files keyed by plan key — no workload text involved."""
        from repro.campaign import runner
        spec = _gemm_spec()
        jobs = spec.expand()
        store = PlanStore({w.name: {"raw": None, "optimized": None}
                           for w in spec.workloads})
        from repro.campaign.builders import build_workload
        for w in spec.workloads:
            store.texts[w.name]["raw"] = build_workload(w).stablehlo_text
        plan_keys, errors = _build_plans(jobs, store)
        assert not errors
        paths = store.dump(str(tmp_path))
        runner._worker_init(paths, {}, None)
        row, new = runner._worker_run(jobs[0], plan_keys[jobs[0].job_id])
        assert "error" not in row and row["step_time_s"] > 0
        assert new  # fresh entries computed against the snapshot store

    def test_plan_build_failure_becomes_error_rows(self):
        spec = _gemm_spec(workloads=[
            {"name": "bad", "stablehlo_path": "unused", "fidelity": "raw"}])
        res = run_campaign(spec, workloads={"bad": Workload(name="bad")},
                           executor="serial")
        assert res.summary["num_failed"] == len(res.rows) == 8
        assert all("no raw text" in r["error"] for r in res.rows)


# ------------------------------- scheduling --------------------------------


class TestScheduling:
    def _chains(self, spec, workloads=None):
        jobs = spec.expand()
        from repro.campaign.runner import _workload_texts
        store = PlanStore(_workload_texts(spec, workloads))
        plan_keys, errors = _build_plans(jobs, store)
        assert not errors
        return _schedule_chains(jobs, plan_keys, store, "locality"), store

    def test_locality_schedule_deterministic(self):
        ids = []
        for _ in range(2):
            chains, _ = self._chains(_gemm_spec())
            ids.append([[j.job_id for j in c] for c in chains])
        assert ids[0] == ids[1]

    def test_chains_group_exact_cache_keysets(self):
        """A chain = identical (H, C, R) keyset: same fingerprints +
        system + estimator.  The linear and dep slicings of a one-region
        GEMM share fingerprints, so they share a chain — 2 topologies ×
        2 slicers = 4 jobs per chain, 4 chains for the 16-job grid."""
        chains, _ = self._chains(_gemm_spec())
        assert sorted(len(c) for c in chains) == [4, 4, 4, 4]
        for c in chains:
            assert len({(j.workload, j.system) for j in c}) == 1

    def test_fingerprint_heavy_plans_first(self):
        spec = _gemm_spec(workloads=[
            {"name": "stack", "stablehlo_path": "mem", "fidelity": "raw"},
            {"name": "gemm-256", "fidelity": "raw",
             "gemm": {"m": 256, "n": 256, "k": 256, "dtype": "bf16"}}])
        stack = Workload(name="stack", stablehlo_text=_stacked_text(
            [(64, 64, 64), (96, 96, 96), (128, 128, 128)]))
        chains, store = self._chains(spec, workloads={"stack": stack})
        heavy = [c[0].workload for c in chains[:2]]
        assert heavy == ["stack", "stack"]  # one chain per system, first

    @pytest.mark.parametrize("executor", ["thread", "process"])
    def test_zero_duplicate_cold_misses(self, executor, tmp_path):
        """Leader-first chains: a parallel executor must pay exactly the
        serial run's miss count — every sibling is a pure hit."""
        serial = run_campaign(_gemm_spec(), executor="serial")
        par = run_campaign(
            _gemm_spec(), executor=executor, max_workers=4,
            cache_path=str(tmp_path / f"{executor}.jsonl"))
        assert serial.cache["misses"] == 4  # 2 workloads × 2 systems
        assert par.cache["misses"] == serial.cache["misses"]
        assert par.cache["hits"] == serial.cache["hits"]

    def test_grid_schedule_streams_in_grid_order(self, tmp_path):
        res = run_campaign(_gemm_spec(), executor="serial",
                           schedule="grid", out_dir=str(tmp_path))
        streamed = load_jsonl(res.jsonl_path)
        assert [r["job_id"] for r in streamed] == list(range(16))
        assert res.plans["schedule"] == "grid"

    def test_unknown_schedule_rejected(self):
        with pytest.raises(ValueError, match="schedule"):
            run_campaign(_gemm_spec(), executor="serial", schedule="chaos")


# ----------------------------- batched cache -------------------------------


class TestBatchedCacheOps:
    #: duplicate middle shape: the batch must treat the second occurrence
    #: as a hit on the first's in-batch miss, exactly like sequential ops
    SHAPES = [(64, 64, 64), (96, 96, 96), (64, 64, 64), (128, 128, 128)]

    def _job(self, store, batched: bool) -> PredictionJob:
        from repro.campaign.builders import build_estimator, build_topology
        from repro.campaign.spec import EstimatorSpec, TopologySpec
        from repro.core.systems import get_system

        program_text = _stacked_text(self.SHAPES)
        from repro.core.ir.parser import parse
        plan = build_plan(parse(program_text), slicer="linear", name="stack")
        system = get_system("a100")
        return PredictionJob(
            estimator=build_estimator(EstimatorSpec(), system),
            topology=build_topology(
                TopologySpec("a2a", (("num_devices", 4),)), system),
            plan=plan, name="stack", cache_store=store, batch_cache=batched)

    @staticmethod
    def _stats_tuple(stats):
        return (stats.hits, stats.misses, stats.saved_seconds > 0,
                sorted(stats.per_key_cost))

    def test_batched_stats_identical_to_per_region(self, tmp_path):
        preds, stats, stores = {}, {}, {}
        for batched in (False, True):
            store = PersistentCache(
                str(tmp_path / f"{batched}.jsonl"))
            job = self._job(store, batched)
            preds[batched] = job.run()
            stats[batched] = job.cached.stats
            stores[batched] = store
        assert preds[True].step_time_s == preds[False].step_time_s
        assert self._stats_tuple(stats[True]) \
            == self._stats_tuple(stats[False])
        # 4 regions, 3 distinct fingerprints: 3 misses + 1 in-batch hit
        assert stats[True].misses == 3 and stats[True].hits == 1
        assert dict(stores[True].entries) == dict(stores[False].entries)
        # batching collapses store I/O: one put_many vs one append/miss
        assert stores[True].lock_roundtrips < stores[False].lock_roundtrips

    def test_batched_second_run_all_hits_with_saved_costs(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        self._job(PersistentCache(path), True).run()
        job = self._job(PersistentCache(path), True)
        job.run()
        s = job.cached.stats
        assert s.misses == 0 and s.hits == 4
        assert s.saved_seconds > 0  # persisted per-key costs credited

    def test_mid_batch_failure_flushes_computed_entries(self, tmp_path):
        """An estimator exception mid-batch must not discard the entries
        already computed in that batch: they flush to the shared log
        exactly as the per-region write-through path persisted them."""
        from repro.core.estimators.analytical import RooflineEstimator
        from repro.core.ir.parser import parse
        from repro.core.systems import get_system

        plan = build_plan(parse(_stacked_text(
            [(64, 64, 64), (96, 96, 96), (128, 128, 128)])),
            slicer="linear", name="stack")

        class Flaky(RooflineEstimator):
            calls = 0

            def get_run_time_estimate(self, region):
                Flaky.calls += 1
                if Flaky.calls == 3:
                    raise RuntimeError("boom")
                return super().get_run_time_estimate(region)

        path = str(tmp_path / "hcr.jsonl")
        cached = CachedEstimator(Flaky(get_system("a100")),
                                 store=PersistentCache(path))
        with pytest.raises(RuntimeError, match="boom"):
            cached.get_run_time_estimates(plan.compute_regions)
        assert cached.stats.misses == 2
        assert len(PersistentCache(path)) == 2  # survivors reached the log

    def test_put_many_is_one_lock_roundtrip(self, tmp_path):
        pc = PersistentCache(str(tmp_path / "hcr.jsonl"))
        base = pc.lock_roundtrips
        pc.put_many({f"k{i}": (float(i), 0.01) for i in range(10)})
        assert pc.lock_roundtrips == base + 1
        fresh = PersistentCache(pc.path)
        assert len(fresh) == 10 and fresh.cost("k3") == 0.01

    def test_get_many_tails_log_at_most_once(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        a, b = PersistentCache(path), PersistentCache(path)
        a.put_many({"k1": 1.0, "k2": 2.0})
        base = b.lock_roundtrips
        got = b.get_many(["k1", "k2", "k3"])
        assert got == {"k1": 1.0, "k2": 2.0}
        assert b.lock_roundtrips == base + 1
        # everything in memory now: the next batch lookup takes no lock
        assert b.get_many(["k1", "k2"]) == {"k1": 1.0, "k2": 2.0}
        assert b.lock_roundtrips == base + 1

    def test_refresh_stat_throttle_skips_lock(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        a = PersistentCache(path)
        a.append("k", 1.0)
        base = a.lock_roundtrips
        for _ in range(5):          # unchanged file: stat-only fast path
            assert a.refresh() == 0
        assert a.lock_roundtrips == base
        b = PersistentCache(path)   # external writer forces a real read
        b.append("k2", 2.0)
        assert a.refresh() == 1
        assert a.lock_roundtrips == base + 1 and "k2" in a


# ------------------------- spec parity (acceptance) ------------------------


def _reference_rows(spec: CampaignSpec, texts: dict) -> dict:
    """The pre-plan execution model: one parse + one slice per job, one
    cache operation per region (``batch_cache=False``).  Campaign rows
    must reproduce these predictions bit-identically."""
    from repro.campaign.builders import (build_estimator, build_system,
                                         build_topology)
    from repro.core.ir.parser import parse

    rows = {}
    for job in spec.expand():
        wtexts = texts[job.workload]
        fidelity = job.fidelity
        if fidelity == "optimized" and not wtexts.get("optimized"):
            fidelity = "raw"
        program = parse(wtexts[fidelity])
        system = build_system(job.system)
        estimator = build_estimator(job.estimator, system,
                                    system_name=job.system, program=program)
        p = PredictionJob(
            program=program, estimator=estimator,
            topology=build_topology(job.topology, system),
            slicer=job.slicer, overlap=job.overlap,
            straggler_factor=job.straggler_factor,
            compression=job.compression, name=job.workload,
            system_name=system.name, batch_cache=False).run()
        rows[job.job_id] = p
    return rows


PARITY_FIELDS = ("step_time_s", "compute_s", "comm_s", "exposed_comm_s",
                 "num_segments", "num_comm")


def _assert_parity(spec: CampaignSpec, workloads=None,
                   executors=("serial", "thread")):
    from repro.campaign.runner import _workload_texts
    texts = _workload_texts(spec, workloads)
    ref = _reference_rows(spec, texts)
    for executor in executors:
        res = run_campaign(spec, workloads=workloads, executor=executor,
                           max_workers=4)
        assert res.summary["num_failed"] == 0, res.summary["failures"]
        assert len(res.rows) == len(ref)
        for row in res.rows:
            p = ref[row["job_id"]]
            for f in PARITY_FIELDS:
                assert row[f] == getattr(p, f), (executor, row["job_id"], f)


@pytest.fixture(scope="module")
def tiny_llama_workload():
    """One tiny train-step export whose text stands in for every LM
    workload name in the fig6/fig11 grids (parity needs the real spec
    *axes*; full-size 2k-seq exports would take minutes on CPU)."""
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.pipeline import export_workload
    from repro.models.registry import get_smoke_config
    from repro.train.loop import train_step_exports

    cfg = get_smoke_config("llama3-100m")
    jitted, abs_args = train_step_exports(cfg, 32, 2, None)
    return export_workload(jitted, *abs_args, name="tiny-llama")


@pytest.fixture(scope="module")
def tiny_resnet_workload():
    jax = pytest.importorskip("jax")  # noqa: F841
    from repro.core.pipeline import export_workload
    from repro.models.resnet import ResNetConfig, resnet_train_exports

    jitted, abs_args = resnet_train_exports(ResNetConfig(depth=18),
                                            batch=2, img=32, mesh=None)
    return export_workload(jitted, *abs_args, name="tiny-resnet")


class TestSpecParity:
    """Plan-based predictions are bit-identical to the pre-plan path on
    every checked-in spec grid (jax-heavy grids run their real axes over
    light stand-in exports)."""

    def test_fig10_gemm_spec_full_parity(self):
        spec = CampaignSpec.from_json(os.path.join(SPECS, "fig10_gemm.json"))
        _assert_parity(spec, executors=("serial", "thread", "process"))

    def test_fig6_gpu_spec_parity(self, tiny_llama_workload):
        spec = CampaignSpec.from_json(os.path.join(SPECS, "fig6_gpu.json"))
        provided = {w.name: tiny_llama_workload for w in spec.workloads}
        _assert_parity(spec, workloads=provided)

    def test_fig11_tpu_spec_parity(self, tiny_llama_workload):
        spec = CampaignSpec.from_json(os.path.join(SPECS, "fig11_tpu.json"))
        provided = {w.name: tiny_llama_workload for w in spec.workloads}
        _assert_parity(spec, workloads=provided)

    def test_fig9_scaleout_spec_parity(self, tiny_llama_workload):
        """The zipped (workload ⊗ fabric) fig9 grid through the plan
        path matches the per-job/per-region reference execution —
        ``_reference_rows`` iterates ``spec.expand()``, so the paired
        expansion itself is under parity too."""
        spec = CampaignSpec.from_json(
            os.path.join(SPECS, "fig9_scaleout.json"))
        assert spec.zip_axes  # the paired-axis grid, not a cross product
        provided = {w.name: tiny_llama_workload for w in spec.workloads}
        _assert_parity(spec, workloads=provided)

    def test_fig7_resnet_spec_parity(self, tiny_resnet_workload):
        from tests.test_ir_parser import CANNED_HLO
        spec = CampaignSpec.from_json(
            os.path.join(SPECS, "fig7_resnet.json"))
        provided = {w.name: tiny_resnet_workload for w in spec.workloads}
        # one name carries a collective-bearing optimized HLO so the
        # parity surface includes COMM segments end to end
        provided["resnet101"] = Workload(name="resnet101",
                                         hlo_text=CANNED_HLO)
        _assert_parity(spec, workloads=provided)
