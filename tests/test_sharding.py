"""Logical-axis rule resolution, divisibility fallback, mesh construction."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (ACT_RULES_SEQ_SHARDED, ShardingRules,
                                        logical_to_spec)
from repro.launch.mesh import make_mesh


@pytest.fixture(scope="module")
def mesh():
    # single-device container: a (1,1) mesh exercises the resolution code
    return make_mesh((1, 1), ("data", "model"))


class TestRuleResolution:
    def test_basic_mapping(self, mesh):
        rules = ShardingRules()
        spec = logical_to_spec(("embed", "mlp"), rules.param_rules, mesh,
                               (1024, 4096))
        assert spec == P("data", "model")

    def test_non_divisible_dim_dropped(self, mesh):
        big = make_mesh((1, 1), ("data", "model"))
        rules = ShardingRules()
        # 40 heads on a 16-way model axis: the (1,1) mesh divides anything,
        # so emulate with explicit divisibility check on a fake mesh below
        spec = logical_to_spec(("heads",), rules.act_rules, big, (40,))
        assert spec in (P("model"), P())

    def test_seq_sharded_rules(self, mesh):
        spec = logical_to_spec(("batch", "cache_seq"),
                               ACT_RULES_SEQ_SHARDED, mesh, (1, 524288))
        # batch=1 cannot take an axis of size>1; cache_seq goes to data
        assert spec in (P(None, "data"), P("pod", "data"), P())

    def test_no_double_axis_use(self, mesh):
        rules = ShardingRules()
        spec = logical_to_spec(("heads", "mlp"), rules.act_rules, mesh,
                               (16, 4096))
        flat = [s for s in spec if s is not None]
        names = []
        for s in flat:
            names.extend(s if isinstance(s, tuple) else (s,))
        assert len(names) == len(set(names))

    def test_overrides(self):
        rules = ShardingRules().with_overrides(params={"embed": None})
        assert rules.param_rules["embed"] is None
        assert ShardingRules().param_rules["embed"] == "data"


class TestDivisibility:
    """Fake meshes with >1 axis size need >1 devices; emulate the pure
    resolution logic through a stub mesh object."""

    class _FakeMesh:
        axis_names = ("data", "model")

        class _Dev:
            shape = (16, 16)
        devices = _Dev()

    def test_drop_non_dividing(self):
        rules = ShardingRules()
        spec = logical_to_spec(("heads",), rules.act_rules, self._FakeMesh(),
                               (40,))
        assert spec == P()      # 40 % 16 != 0 -> replicated

    def test_keep_dividing(self):
        rules = ShardingRules()
        spec = logical_to_spec(("heads",), rules.act_rules, self._FakeMesh(),
                               (64,))
        assert spec == P("model")

    def test_tuple_rule_prefix_fallback(self):
        class _Mesh3:
            axis_names = ("pod", "data", "model")

            class _Dev:
                shape = (2, 16, 16)
            devices = _Dev()

        rules = ShardingRules()
        # batch 32 divides pod*data=32
        spec = logical_to_spec(("batch",), rules.act_rules, _Mesh3(), (32,))
        assert spec == P(("pod", "data"))
        # batch 2 only divides pod
        spec = logical_to_spec(("batch",), rules.act_rules, _Mesh3(), (2,))
        assert spec == P("pod")
        # batch 1 divides nothing
        spec = logical_to_spec(("batch",), rules.act_rules, _Mesh3(), (1,))
        assert spec == P()


class TestProductionMeshShape:
    def test_shapes_declared(self):
        import inspect

        from repro.launch import mesh as mesh_mod
        src = inspect.getsource(mesh_mod.make_production_mesh)
        assert "(2, 16, 16)" in src and "(16, 16)" in src
        assert '"pod", "data", "model"' in src
