import os
import sys

# tests run with the default single CPU device — only the dry-run process
# forces 512 host devices (see src/repro/launch/dryrun.py)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
