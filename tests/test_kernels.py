"""Per-kernel correctness: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


class TestFlashAttention:
    @pytest.mark.parametrize("b,hq,hkv,sq,skv,d", [
        (2, 4, 2, 256, 256, 64),
        (1, 4, 4, 128, 256, 64),
        (2, 2, 2, 256, 256, 32),
        (1, 8, 2, 128, 128, 128),
    ])
    def test_matches_ref_causal(self, b, hq, hkv, sq, skv, d):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (b, hq, sq, d), jnp.float32)
        k = jax.random.normal(ks[1], (b, hkv, skv, d), jnp.float32)
        v = jax.random.normal(ks[2], (b, hkv, skv, d), jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        kr = jnp.repeat(k, hq // hkv, axis=1)
        vr = jnp.repeat(v, hq // hkv, axis=1)
        ref = attention_ref(q, kr, vr, causal=True)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("window,cap,causal", [
        (128, 0.0, True), (0, 50.0, True), (64, 30.0, True), (0, 0.0, False),
    ])
    def test_masking_variants(self, window, cap, causal):
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 2, 256, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 256, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 256, 64), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, window=window,
                              logit_cap=cap)
        ref = attention_ref(q, k, v, causal=causal, window=window,
                            logit_cap=cap)
        np.testing.assert_allclose(out, ref, atol=2e-5, rtol=2e-5)

    def test_bf16(self):
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (1, 2, 128, 64), jnp.bfloat16)
        k = jax.random.normal(ks[1], (1, 2, 128, 64), jnp.bfloat16)
        v = jax.random.normal(ks[2], (1, 2, 128, 64), jnp.bfloat16)
        out = flash_attention(q, k, v)
        ref = attention_ref(q, k, v)
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32), atol=3e-2)

    def test_block_shape_independence(self):
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 2, 512, 64), jnp.float32)
        k = jax.random.normal(ks[1], (1, 2, 512, 64), jnp.float32)
        v = jax.random.normal(ks[2], (1, 2, 512, 64), jnp.float32)
        a = flash_attention(q, k, v, block_q=128, block_k=128)
        b = flash_attention(q, k, v, block_q=256, block_k=64)
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)


class TestSSDScan:
    @pytest.mark.parametrize("b,s,h,p,g,n,chunk", [
        (2, 128, 4, 32, 1, 16, 32),
        (1, 256, 8, 64, 2, 32, 64),
        (1, 64, 2, 16, 1, 8, 16),
    ])
    def test_matches_sequential_ref(self, b, s, h, p, g, n, chunk):
        ks = jax.random.split(jax.random.PRNGKey(0), 5)
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        bi = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
        ci = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
        y, st = ssd_scan(x, dt, a, bi, ci, chunk=chunk)
        yr, sr = ssd_ref(x, dt, a, bi, ci)
        np.testing.assert_allclose(y, yr, atol=2e-3, rtol=2e-3)
        np.testing.assert_allclose(st, sr, atol=2e-3, rtol=2e-3)

    def test_initial_state_continuation(self):
        """Scanning two halves with state carry == scanning the whole."""
        ks = jax.random.split(jax.random.PRNGKey(1), 5)
        b, s, h, p, g, n = 1, 128, 2, 16, 1, 8
        x = jax.random.normal(ks[0], (b, s, h, p), jnp.float32)
        dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
        a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
        bi = jax.random.normal(ks[3], (b, s, g, n), jnp.float32)
        ci = jax.random.normal(ks[4], (b, s, g, n), jnp.float32)
        y_full, st_full = ssd_scan(x, dt, a, bi, ci, chunk=32)
        half = s // 2
        y1, st1 = ssd_scan(x[:, :half], dt[:, :half], a, bi[:, :half],
                           ci[:, :half], chunk=32)
        y2, st2 = ssd_scan(x[:, half:], dt[:, half:], a, bi[:, half:],
                           ci[:, half:], chunk=32, initial_state=st1)
        np.testing.assert_allclose(
            jnp.concatenate([y1, y2], axis=1), y_full, atol=2e-3)
        np.testing.assert_allclose(st2, st_full, atol=2e-3)


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 128, 512), (2, 64, 1024),
                                       (128, 768), (1, 1, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, shape, dtype):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, shape, dtype)
        w = jax.random.normal(key, shape[-1:], dtype)
        out = rmsnorm(x, w)
        ref = rmsnorm_ref(x, w)
        atol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(out.astype(np.float32),
                                   ref.astype(np.float32), atol=atol)
