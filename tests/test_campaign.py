"""Campaign engine: grid expansion, executor equivalence, output
round-trips, the CLI, and cross-run persistent-cache reuse."""
import json
import os
import subprocess
import sys

import pytest

from repro.campaign import CampaignSpec, run_campaign
from repro.campaign.runner import load_jsonl
from repro.campaign.spec import EstimatorSpec, TopologySpec, WorkloadSpec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------ grid expansion -----------------------------


def _spec_dict(**overrides):
    d = {
        "name": "t",
        "workloads": [{"name": "toy", "stablehlo_path": "unused.mlir"}],
        "systems": ["a100", "h100"],
        "estimators": [{"kind": "roofline"},
                       {"kind": "roofline", "fidelity": "raw",
                        "options": {"mode": "per-op",
                                    "include_overheads": True}}],
        "slicers": ["linear", "dep"],
    }
    d.update(overrides)
    return d


class TestGridExpansion:
    def test_cross_product_size_and_ids(self):
        spec = CampaignSpec.from_dict(_spec_dict())
        jobs = spec.expand()
        assert spec.num_points == len(jobs) == 2 * 2 * 2
        assert [j.job_id for j in jobs] == list(range(8))

    def test_axis_order_deterministic(self):
        spec = CampaignSpec.from_dict(_spec_dict())
        a = [j.to_row() for j in spec.expand()]
        b = [j.to_row() for j in spec.expand()]
        assert a == b

    def test_estimator_fidelity_overrides_workload(self):
        spec = CampaignSpec.from_dict(_spec_dict())
        fids = {(j.estimator.label, j.fidelity) for j in spec.expand()}
        assert ("roofline", "optimized") in fids
        assert ("roofline-per-op-ovh@raw", "raw") in fids

    def test_knob_axes_expand(self):
        spec = CampaignSpec.from_dict(_spec_dict(
            overlap=[False, True], straggler_factor=[1.0, 2.0]))
        assert spec.num_points == 8 * 4
        stragglers = {j.straggler_factor for j in spec.expand()}
        assert stragglers == {1.0, 2.0}

    def test_unknown_keys_rejected(self):
        with pytest.raises(ValueError, match="unknown campaign spec keys"):
            CampaignSpec.from_dict(_spec_dict(typo_axis=[1]))

    def test_workload_needs_a_source(self):
        with pytest.raises(ValueError, match="need stablehlo_path"):
            CampaignSpec.from_dict(_spec_dict(workloads=[{"name": "x"}]))

    def test_specs_are_picklable_primitives(self):
        import pickle
        spec = CampaignSpec.from_dict(_spec_dict())
        for job in spec.expand():
            assert pickle.loads(pickle.dumps(job)) == job

    def test_roundtrip_through_json(self, tmp_path):
        spec = CampaignSpec.from_dict(_spec_dict())
        p = tmp_path / "spec.json"
        p.write_text(json.dumps(spec.to_dict()))
        spec2 = CampaignSpec.from_json(str(p))
        assert spec2.expand() == spec.expand()


# ------------------------- execution (shared fixture) ----------------------


@pytest.fixture(scope="module")
def toy_workload():
    import jax
    import jax.numpy as jnp

    from repro.core.pipeline import export_workload

    def f(w, x):
        for i in range(6):
            x = jax.lax.optimization_barrier(jnp.tanh(x @ w[i]))
        return x
    w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    return export_workload(jax.jit(f), w, x, name="toy",
                           compile_workload=False)


def _run(spec_dict, workload, **kw):
    spec = CampaignSpec.from_dict(spec_dict)
    return run_campaign(spec, workloads={"toy": workload}, **kw)


class TestExecution:
    def test_serial_thread_process_agree(self, toy_workload):
        d = _spec_dict()
        d["estimators"] = [{"kind": "roofline"}]  # raw fidelity needs no hlo
        d["workloads"][0]["fidelity"] = "raw"
        results = {ex: _run(d, toy_workload, executor=ex)
                   for ex in ("serial", "thread", "process")}
        times = {ex: {r["job_id"]: r["step_time_s"] for r in res.ok_rows}
                 for ex, res in results.items()}
        assert results["serial"].summary["num_failed"] == 0
        assert times["serial"] == times["thread"] == times["process"]

    def test_failed_job_reported_not_fatal(self, toy_workload):
        d = _spec_dict(systems=["a100", "no-such-system"])
        d["workloads"][0]["fidelity"] = "raw"
        res = _run(d, toy_workload, executor="serial")
        assert res.summary["num_failed"] == res.summary["num_ok"] > 0
        assert all("error" in r for r in res.rows
                   if r["system"] == "no-such-system")

    def test_jsonl_csv_roundtrip(self, toy_workload, tmp_path):
        d = _spec_dict()
        d["workloads"][0]["fidelity"] = "raw"
        res = _run(d, toy_workload, executor="serial", out_dir=str(tmp_path))
        streamed = load_jsonl(res.jsonl_path)
        assert sorted(r["job_id"] for r in streamed) == list(range(8))
        assert {json.dumps(r, sort_keys=True) for r in streamed} \
            == {json.dumps(r, sort_keys=True) for r in res.rows}
        import csv
        with open(res.csv_path) as f:
            csv_rows = list(csv.DictReader(f))
        assert len(csv_rows) == 8
        assert float(csv_rows[0]["step_time_s"]) == pytest.approx(
            res.rows[0]["step_time_s"])
        summary = json.loads((tmp_path / "summary.json").read_text())
        assert summary["num_ok"] == 8
        assert "system_ranks" in summary and "rank_agreement" in summary

    def test_estimator_variants_do_not_collide_in_shared_store(
            self, toy_workload):
        # both estimators cost the SAME raw program while sharing one
        # cache store — config must be part of the (H,C,R) key or the
        # second variant would serve the first's latencies
        d = _spec_dict(systems=["a100"], slicers=["linear"])
        d["workloads"][0]["fidelity"] = "raw"
        d["estimators"] = [{"kind": "roofline"},
                           {"kind": "roofline",
                            "options": {"mode": "per-op",
                                        "include_overheads": True}}]
        res = _run(d, toy_workload, executor="serial")
        t = {r["estimator"]: r["step_time_s"] for r in res.ok_rows}
        assert t["roofline"] != t["roofline-per-op-ovh"]

    def test_row_reports_effective_fidelity(self, toy_workload):
        # toy workload has no optimized HLO: the default 'optimized'
        # request falls back to raw, and rows must say so
        d = _spec_dict(systems=["a100"], slicers=["linear"])
        d["estimators"] = [{"kind": "roofline"}]
        res = _run(d, toy_workload, executor="serial")
        assert all(r["fidelity"] == "raw" for r in res.ok_rows)

    def test_summary_ranks_match_rows(self, toy_workload):
        d = _spec_dict(slicers=["linear"])
        d["estimators"] = [{"kind": "roofline"}]
        d["workloads"][0]["fidelity"] = "raw"
        res = _run(d, toy_workload, executor="serial")
        by_sys = {r["system"]: r["step_time_s"] for r in res.ok_rows}
        expected = sorted(by_sys, key=by_sys.get)
        assert res.summary["system_ranks"]["toy"]["roofline"] == expected


# --------------------------- persistent (H,C,R) cache ----------------------


class TestPersistentCache:
    def test_second_run_hits_and_is_faster(self, toy_workload, tmp_path):
        """The across-run extension of the paper's §III-B(c) caching
        result: an identical campaign against a warm cache re-pays zero
        estimator cost."""
        d = _spec_dict(systems=["a100", "h100"], slicers=["linear", "dep"])
        # profiling (host-executed, runs=1) makes estimator cost real, so
        # the wall-time drop is measurable, not noise
        d["estimators"] = [{"kind": "profiling", "fidelity": "raw",
                            "options": {"runs": 1}}]
        cache = str(tmp_path / "hcr.json")
        r1 = _run(d, toy_workload, executor="serial", cache_path=cache)
        assert r1.summary["num_failed"] == 0
        assert r1.cache["misses"] > 0 and r1.cache["new_entries"] > 0
        assert os.path.exists(cache)

        r2 = _run(d, toy_workload, executor="serial", cache_path=cache)
        assert r2.summary["num_failed"] == 0
        assert r2.cache["loaded_entries"] == r1.cache["new_entries"]
        assert r2.cache["hits"] > 0
        assert r2.cache["misses"] == 0
        assert r2.cache["hit_rate"] == 1.0
        assert r2.wall_s < r1.wall_s
        # identical predictions from cached latencies
        t1 = {r["job_id"]: r["step_time_s"] for r in r1.ok_rows}
        t2 = {r["job_id"]: r["step_time_s"] for r in r2.ok_rows}
        assert t1 == t2

    def test_version_mismatch_invalidates(self, tmp_path):
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.json")
        pc = PersistentCache()
        pc.merge({"a100|roofline|deadbeef": 1.5})
        pc.save(path)
        data = json.loads(open(path).read())
        data["fingerprint"] = -1
        with open(path, "w") as f:
            json.dump(data, f)
        stale = PersistentCache(path)
        assert len(stale) == 0 and stale.loaded_entries == 0

    def test_legacy_unversioned_file_discarded(self, tmp_path):
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.json")
        with open(path, "w") as f:
            json.dump({"a100|roofline|deadbeef": 1.5}, f)
        assert len(PersistentCache(path)) == 0


# ----------------------------------- CLI -----------------------------------


class TestCLI:
    def test_cli_campaign_with_warm_rerun(self, toy_workload, tmp_path):
        """Acceptance path: >= 12 grid points through `python -m
        repro.campaign`, JSONL + CSV out, persistent hits on rerun."""
        ir_path = tmp_path / "toy.mlir"
        ir_path.write_text(toy_workload.stablehlo_text)
        spec = {
            "name": "cli",
            "workloads": [{"name": "toy", "fidelity": "raw",
                           "stablehlo_path": str(ir_path)}],
            "systems": ["a100", "h100", "b200"],
            "estimators": [{"kind": "roofline"},
                           {"kind": "roofline",
                            "options": {"mode": "per-op"}}],
            "slicers": ["linear", "dep"],
        }
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps(spec))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        cmd = [sys.executable, "-m", "repro.campaign", str(spec_path),
               "--out", str(tmp_path / "out"), "--executor", "serial",
               "--cache", str(tmp_path / "hcr.json"), "--quiet"]

        p1 = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                            text=True, timeout=300)
        assert p1.returncode == 0, p1.stdout[-2000:] + p1.stderr[-2000:]
        rows = load_jsonl(str(tmp_path / "out" / "results.jsonl"))
        assert len(rows) == 12  # 1 workload × 3 systems × 2 est × 2 slicers
        assert os.path.exists(tmp_path / "out" / "results.csv")
        s1 = json.loads((tmp_path / "out" / "summary.json").read_text())
        assert s1["num_ok"] == 12
        assert s1["cache"]["new_entries"] > 0

        p2 = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                            text=True, timeout=300)
        assert p2.returncode == 0, p2.stdout[-2000:] + p2.stderr[-2000:]
        s2 = json.loads((tmp_path / "out" / "summary.json").read_text())
        assert s2["cache"]["loaded_entries"] > 0
        assert s2["cache"]["hits"] > 0 and s2["cache"]["misses"] == 0
        assert "hits" in p2.stdout  # the CLI reports the cache line

    def test_cli_dry_run(self, toy_workload, tmp_path):
        ir_path = tmp_path / "toy.mlir"
        ir_path.write_text(toy_workload.stablehlo_text)
        spec = _spec_dict()
        spec["workloads"][0]["stablehlo_path"] = str(ir_path)
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps(spec))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", str(spec_path),
             "--dry-run"],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
        assert p.returncode == 0, p.stdout + p.stderr
        assert "8 grid points" in p.stdout
