"""Campaign engine: grid expansion, executor equivalence, output
round-trips, the CLI, train-mode/GEMM workload export, the shared
append-log cache (live cross-process visibility, persisted per-key
costs), and cross-run persistent-cache reuse."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.campaign import CampaignSpec, run_campaign
from repro.campaign.runner import load_jsonl
from repro.campaign.spec import EstimatorSpec, TopologySpec, WorkloadSpec

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


# ------------------------------ grid expansion -----------------------------


def _spec_dict(**overrides):
    d = {
        "name": "t",
        "workloads": [{"name": "toy", "stablehlo_path": "unused.mlir"}],
        "systems": ["a100", "h100"],
        "estimators": [{"kind": "roofline"},
                       {"kind": "roofline", "fidelity": "raw",
                        "options": {"mode": "per-op",
                                    "include_overheads": True}}],
        "slicers": ["linear", "dep"],
    }
    d.update(overrides)
    return d


class TestGridExpansion:
    def test_cross_product_size_and_ids(self):
        spec = CampaignSpec.from_dict(_spec_dict())
        jobs = spec.expand()
        assert spec.num_points == len(jobs) == 2 * 2 * 2
        assert [j.job_id for j in jobs] == list(range(8))

    def test_axis_order_deterministic(self):
        spec = CampaignSpec.from_dict(_spec_dict())
        a = [j.to_row() for j in spec.expand()]
        b = [j.to_row() for j in spec.expand()]
        assert a == b

    def test_estimator_fidelity_overrides_workload(self):
        spec = CampaignSpec.from_dict(_spec_dict())
        fids = {(j.estimator.label, j.fidelity) for j in spec.expand()}
        assert ("roofline", "optimized") in fids
        assert ("roofline-per-op-ovh@raw", "raw") in fids

    def test_custom_option_labels_never_alias(self):
        """Two estimator entries of one (possibly plugin) kind that
        differ only in non-builtin options must get distinct labels —
        aliasing would silently merge their rows in every label-keyed
        consumer (summaries, reports, golden snapshots)."""
        from repro.campaign.spec import EstimatorSpec
        a = EstimatorSpec.from_dict(
            {"kind": "table", "options": {"path": "profiles/a100.json"}})
        b = EstimatorSpec.from_dict(
            {"kind": "table", "options": {"path": "profiles/h100.json"}})
        same_as_a = EstimatorSpec.from_dict(
            {"kind": "table", "options": {"path": "profiles/a100.json"}})
        assert a.label != b.label
        assert a.label == same_as_a.label          # stable digest
        assert a.label.startswith("table-")
        # builtin options keep their historical readable labels — golden
        # snapshots key rows on these exact strings
        assert EstimatorSpec.from_dict(
            {"kind": "roofline",
             "options": {"mode": "per-op",
                         "include_overheads": True}}).label \
            == "roofline-per-op-ovh"
        assert EstimatorSpec.from_dict(
            {"kind": "systolic",
             "options": {"preset": "onnxim"}}).label == "systolic-onnxim"
        assert EstimatorSpec.from_dict(
            {"kind": "profiling", "options": {"runs": 3}}).label \
            == "profiling-runs3"
        # mixed: builtin bits stay readable, extras still disambiguate
        m1 = EstimatorSpec.from_dict(
            {"kind": "systolic",
             "options": {"preset": "onnxim", "lanes": 4}})
        m2 = EstimatorSpec.from_dict(
            {"kind": "systolic",
             "options": {"preset": "onnxim", "lanes": 8}})
        assert m1.label != m2.label
        assert all(lbl.startswith("systolic-onnxim-")
                   for lbl in (m1.label, m2.label))

    def test_knob_axes_expand(self):
        spec = CampaignSpec.from_dict(_spec_dict(
            overlap=[False, True], straggler_factor=[1.0, 2.0]))
        assert spec.num_points == 8 * 4
        stragglers = {j.straggler_factor for j in spec.expand()}
        assert stragglers == {1.0, 2.0}

    def test_unknown_keys_rejected(self):
        with pytest.raises(ValueError, match="unknown campaign spec keys"):
            CampaignSpec.from_dict(_spec_dict(typo_axis=[1]))

    def test_workload_needs_a_source(self):
        with pytest.raises(ValueError, match="need stablehlo_path"):
            CampaignSpec.from_dict(_spec_dict(workloads=[{"name": "x"}]))

    def test_specs_are_picklable_primitives(self):
        import pickle
        spec = CampaignSpec.from_dict(_spec_dict())
        for job in spec.expand():
            assert pickle.loads(pickle.dumps(job)) == job

    def test_roundtrip_through_json(self, tmp_path):
        spec = CampaignSpec.from_dict(_spec_dict())
        p = tmp_path / "spec.json"
        p.write_text(json.dumps(spec.to_dict()))
        spec2 = CampaignSpec.from_json(str(p))
        assert spec2.expand() == spec.expand()


def _zip_spec_dict(**overrides):
    """Two workloads, each to be paired with its own fabric (the Fig 9
    shape: a scale-out sweep where a cross product would mispair)."""
    d = _spec_dict(
        workloads=[
            {"name": "w16", "stablehlo_path": "unused.mlir", "batch": 32,
             "mesh": [16, 1]},
            {"name": "w128", "stablehlo_path": "unused.mlir", "batch": 128,
             "mesh": [128, 1]},
        ],
        topologies=[
            {"kind": "a2a", "params": {"num_devices": 16}},
            {"kind": "a2a", "params": {"num_devices": 128}},
        ],
        zip=[["workloads", "topologies"]])
    d.update(overrides)
    return d


class TestZippedAxes:
    def test_zip_pairs_elementwise(self):
        spec = CampaignSpec.from_dict(_zip_spec_dict())
        jobs = spec.expand()
        # 2 zipped (workload ⊗ topology) × 2 systems × 2 est × 2 slicers
        assert spec.num_points == len(jobs) == 16
        pairs = {(j.workload, j.topology.label) for j in jobs}
        assert pairs == {("w16", "a2a16"), ("w128", "a2a128")}

    def test_zip_keeps_per_workload_overrides(self):
        """The paired axis rides with each workload's own mesh/batch —
        the per-scale overrides the Fig 9 grid needs."""
        spec = CampaignSpec.from_dict(_zip_spec_dict())
        by_name = {w.name: w for w in spec.workloads}
        assert by_name["w16"].batch == 32 and by_name["w16"].mesh == (16, 1)
        assert by_name["w128"].batch == 128 \
            and by_name["w128"].mesh == (128, 1)

    def test_unzipped_expansion_order_unchanged(self):
        """With no zip groups the block expansion must enumerate exactly
        the legacy cross product (golden job_ids depend on it)."""
        import itertools
        spec = CampaignSpec.from_dict(_spec_dict())
        legacy = list(itertools.product(
            spec.workloads, spec.systems, spec.estimators, spec.slicers,
            spec.topologies, spec.overlap, spec.straggler_factor,
            spec.compression))
        jobs = spec.expand()
        assert len(jobs) == len(legacy)
        for job, (w, system, est, slicer, topo, ovl, strag, comp) in zip(
                jobs, legacy):
            assert (job.workload, job.system, job.estimator, job.slicer,
                    job.topology, job.overlap, job.straggler_factor,
                    job.compression) \
                == (w.name, system, est, slicer, topo, ovl, strag, comp)

    def test_zip_roundtrips_through_json(self, tmp_path):
        spec = CampaignSpec.from_dict(_zip_spec_dict())
        assert spec.to_dict()["zip"] == [["workloads", "topologies"]]
        p = tmp_path / "spec.json"
        p.write_text(json.dumps(spec.to_dict()))
        assert CampaignSpec.from_json(str(p)).expand() == spec.expand()

    def test_three_axis_zip_and_outer_product(self):
        d = _zip_spec_dict(
            systems=["a100", "h100"],
            straggler_factor=[1.0, 1.5],
            zip=[["workloads", "topologies", "straggler_factor"]])
        spec = CampaignSpec.from_dict(d)
        jobs = spec.expand()
        assert len(jobs) == 2 * 2 * 2 * 2  # zip × systems × est × slicers
        trip = {(j.workload, j.topology.label, j.straggler_factor)
                for j in jobs}
        assert trip == {("w16", "a2a16", 1.0), ("w128", "a2a128", 1.5)}

    def test_zip_unequal_lengths_rejected(self):
        d = _zip_spec_dict(topologies=[{"kind": "a2a"}])
        with pytest.raises(ValueError, match="unequal lengths"):
            CampaignSpec.from_dict(d)

    def test_zip_unknown_axis_rejected(self):
        d = _zip_spec_dict(zip=[["workloads", "fabrics"]])
        with pytest.raises(ValueError, match="unknown axis 'fabrics'"):
            CampaignSpec.from_dict(d)

    def test_zip_axis_claimed_twice_rejected(self):
        with pytest.raises(ValueError, match="more than one zip group"):
            CampaignSpec.from_dict(_zip_spec_dict(
                zip=[["workloads", "topologies"],
                     ["topologies", "systems"]]))
        with pytest.raises(ValueError, match="twice in one group"):
            CampaignSpec.from_dict(_zip_spec_dict(
                zip=[["workloads", "workloads"]]))

    def test_zip_single_axis_group_rejected(self):
        with pytest.raises(ValueError, match="at least two axes"):
            CampaignSpec.from_dict(_zip_spec_dict(zip=[["workloads"]]))


# ------------------------- execution (shared fixture) ----------------------


@pytest.fixture(scope="module")
def toy_workload():
    import jax
    import jax.numpy as jnp

    from repro.core.pipeline import export_workload

    def f(w, x):
        for i in range(6):
            x = jax.lax.optimization_barrier(jnp.tanh(x @ w[i]))
        return x
    w = jax.ShapeDtypeStruct((6, 64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 64), jnp.float32)
    return export_workload(jax.jit(f), w, x, name="toy",
                           compile_workload=False)


def _run(spec_dict, workload, **kw):
    spec = CampaignSpec.from_dict(spec_dict)
    return run_campaign(spec, workloads={"toy": workload}, **kw)


class TestExecution:
    def test_serial_thread_process_agree(self, toy_workload):
        d = _spec_dict()
        d["estimators"] = [{"kind": "roofline"}]  # raw fidelity needs no hlo
        d["workloads"][0]["fidelity"] = "raw"
        results = {ex: _run(d, toy_workload, executor=ex)
                   for ex in ("serial", "thread", "process")}
        times = {ex: {r["job_id"]: r["step_time_s"] for r in res.ok_rows}
                 for ex, res in results.items()}
        assert results["serial"].summary["num_failed"] == 0
        assert times["serial"] == times["thread"] == times["process"]

    def test_failed_job_reported_not_fatal(self, toy_workload):
        from repro.core.pipeline import Workload
        d = _spec_dict(workloads=[
            {"name": "toy", "stablehlo_path": "unused", "fidelity": "raw"},
            {"name": "bad", "stablehlo_path": "unused", "fidelity": "raw"}])
        spec = CampaignSpec.from_dict(d)
        res = run_campaign(
            spec, executor="serial",
            workloads={"toy": toy_workload,
                       "bad": Workload(name="bad")})  # no IR text -> fails
        assert res.summary["num_failed"] == res.summary["num_ok"] > 0
        assert all("error" in r for r in res.rows
                   if r["workload"] == "bad")

    def test_axis_vocabulary_typos_rejected(self):
        """The validate surface must catch axis typos that would only
        fail at run time (every job erroring)."""
        for bad, match in [
                (dict(systems=["a100x"]), "unknown system"),
                (dict(estimators=[{"kind": "systolicc"}]),
                 "unknown estimator kind"),
                (dict(slicers=["linearr"]), "unknown slicer"),
                (dict(topologies=[{"kind": "ring"}]),
                 "unknown topology kind")]:
            with pytest.raises(ValueError, match=match):
                CampaignSpec.from_dict(_spec_dict(**bad))

    def test_jsonl_csv_roundtrip(self, toy_workload, tmp_path):
        d = _spec_dict()
        d["workloads"][0]["fidelity"] = "raw"
        res = _run(d, toy_workload, executor="serial", out_dir=str(tmp_path))
        streamed = load_jsonl(res.jsonl_path)
        assert sorted(r["job_id"] for r in streamed) == list(range(8))
        assert {json.dumps(r, sort_keys=True) for r in streamed} \
            == {json.dumps(r, sort_keys=True) for r in res.rows}
        import csv
        with open(res.csv_path) as f:
            csv_rows = list(csv.DictReader(f))
        assert len(csv_rows) == 8
        assert float(csv_rows[0]["step_time_s"]) == pytest.approx(
            res.rows[0]["step_time_s"])
        summary = json.loads((tmp_path / "summary.json").read_text())
        assert summary["num_ok"] == 8
        assert "system_ranks" in summary and "rank_agreement" in summary

    def test_estimator_variants_do_not_collide_in_shared_store(
            self, toy_workload):
        # both estimators cost the SAME raw program while sharing one
        # cache store — config must be part of the (H,C,R) key or the
        # second variant would serve the first's latencies
        d = _spec_dict(systems=["a100"], slicers=["linear"])
        d["workloads"][0]["fidelity"] = "raw"
        d["estimators"] = [{"kind": "roofline"},
                           {"kind": "roofline",
                            "options": {"mode": "per-op",
                                        "include_overheads": True}}]
        res = _run(d, toy_workload, executor="serial")
        t = {r["estimator"]: r["step_time_s"] for r in res.ok_rows}
        assert t["roofline"] != t["roofline-per-op-ovh"]

    def test_row_reports_effective_fidelity(self, toy_workload):
        # toy workload has no optimized HLO: the default 'optimized'
        # request falls back to raw, and rows must say so
        d = _spec_dict(systems=["a100"], slicers=["linear"])
        d["estimators"] = [{"kind": "roofline"}]
        res = _run(d, toy_workload, executor="serial")
        assert all(r["fidelity"] == "raw" for r in res.ok_rows)

    def test_summary_ranks_match_rows(self, toy_workload):
        d = _spec_dict(slicers=["linear"])
        d["estimators"] = [{"kind": "roofline"}]
        d["workloads"][0]["fidelity"] = "raw"
        res = _run(d, toy_workload, executor="serial")
        by_sys = {r["system"]: r["step_time_s"] for r in res.ok_rows}
        expected = sorted(by_sys, key=by_sys.get)
        assert res.summary["system_ranks"]["toy"]["roofline"] == expected


# --------------------------- persistent (H,C,R) cache ----------------------


class TestSummaryFormatting:
    def test_format_table_tolerates_missing_axes(self):
        """Regression: ``_point`` only carries the axes present in a row
        (server resume payloads ship reduced grids), so the best/worst
        lines must render with placeholders instead of raising KeyError."""
        from repro.campaign.summary import format_table, summarize
        rows = [{"job_id": 0, "workload": "g", "system": "a100",
                 "estimator": "roofline", "step_time_s": 1e-3},
                {"job_id": 1, "workload": "g", "system": "h100-paper",
                 "estimator": "roofline", "step_time_s": 2e-3}]
        summary = summarize("reduced", rows)
        text = format_table(summary)
        assert "best" in text and "worst" in text
        assert "—" in text            # placeholder for the absent slicer
        assert "h100-paper" in text

    def test_format_table_full_axes_unchanged(self):
        from repro.campaign.summary import format_table, summarize
        rows = [{"job_id": 0, "workload": "g", "system": "a100",
                 "estimator": "roofline", "slicer": "linear",
                 "topology": "a2a", "step_time_s": 1e-3}]
        text = format_table(summarize("full", rows))
        assert "g × a100 × roofline × linear" in text


class TestPersistentCache:
    def test_second_run_hits_and_is_faster(self, toy_workload, tmp_path):
        """The across-run extension of the paper's §III-B(c) caching
        result: an identical campaign against a warm cache re-pays zero
        estimator cost."""
        d = _spec_dict(systems=["a100", "h100"], slicers=["linear", "dep"])
        # profiling (host-executed, runs=1) makes estimator cost real, so
        # the wall-time drop is measurable, not noise
        d["estimators"] = [{"kind": "profiling", "fidelity": "raw",
                            "options": {"runs": 1}}]
        cache = str(tmp_path / "hcr.json")
        r1 = _run(d, toy_workload, executor="serial", cache_path=cache)
        assert r1.summary["num_failed"] == 0
        assert r1.cache["misses"] > 0 and r1.cache["new_entries"] > 0
        assert os.path.exists(cache)

        r2 = _run(d, toy_workload, executor="serial", cache_path=cache)
        assert r2.summary["num_failed"] == 0
        assert r2.cache["loaded_entries"] == r1.cache["new_entries"]
        assert r2.cache["hits"] > 0
        assert r2.cache["misses"] == 0
        assert r2.cache["hit_rate"] == 1.0
        assert r2.wall_s < r1.wall_s
        # identical predictions from cached latencies
        t1 = {r["job_id"]: r["step_time_s"] for r in r1.ok_rows}
        t2 = {r["job_id"]: r["step_time_s"] for r in r2.ok_rows}
        assert t1 == t2

    def test_version_mismatch_invalidates(self, tmp_path):
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.json")
        pc = PersistentCache()
        pc.merge({"a100|roofline|deadbeef": 1.5})
        pc.save(path)
        lines = open(path).read().splitlines()
        header = json.loads(lines[0])  # line 1 of the append log
        header["fingerprint"] = -1
        with open(path, "w") as f:
            f.write("\n".join([json.dumps(header)] + lines[1:]) + "\n")
        stale = PersistentCache(path)
        assert len(stale) == 0 and stale.loaded_entries == 0

    def test_legacy_unversioned_file_discarded(self, tmp_path):
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.json")
        with open(path, "w") as f:
            json.dump({"a100|roofline|deadbeef": 1.5}, f)
        assert len(PersistentCache(path)) == 0

    def test_cross_run_time_saving_from_persisted_costs(
            self, toy_workload, tmp_path):
        """Per-key evaluation costs persist with the entries, so a rerun
        that pays zero estimator cost reports ~100 % time saving — the
        across-run extension of the paper's §III-B(c) metric."""
        d = _spec_dict(systems=["a100"], slicers=["linear", "dep"])
        d["estimators"] = [{"kind": "profiling", "fidelity": "raw",
                            "options": {"runs": 1}}]
        cache = str(tmp_path / "hcr.jsonl")
        r1 = _run(d, toy_workload, executor="serial", cache_path=cache)
        assert r1.cache["miss_cost_seconds"] > 0
        r2 = _run(d, toy_workload, executor="serial", cache_path=cache)
        assert r2.cache["misses"] == 0
        assert r2.cache["saved_seconds"] > 0
        assert r2.cache["time_saving_fraction"] == pytest.approx(1.0)
        # run1's within-run saving can't exceed run2's cross-run saving
        assert (r2.cache["time_saving_fraction"]
                >= r1.cache["time_saving_fraction"])


class TestSharedStoreAcrossProcesses:
    """The shared append-log store: two *live* processes pointed at one
    cache path must observe each other's entries mid-run."""

    WRITER = textwrap.dedent("""
        import sys, time
        from repro.core.estimators.cache import PersistentCache
        path, mine, theirs, order = sys.argv[1:5]
        pc = PersistentCache(path)
        if order == "first":
            pc.append(mine, 1.25, cost=0.5)
        deadline = time.time() + 60
        while theirs not in pc:
            if time.time() > deadline:
                sys.exit(2)
            time.sleep(0.02)
            pc.refresh()
        if order == "second":
            pc.append(mine, 2.5, cost=0.25)
        assert pc[theirs] > 0 and pc.cost(theirs) > 0
        """)

    def test_two_live_processes_exchange_entries(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        procs = [
            subprocess.Popen(
                [sys.executable, "-c", self.WRITER, path, mine, theirs, order],
                env=env)
            for mine, theirs, order in (("k1", "k2", "first"),
                                        ("k2", "k1", "second"))]
        for p in procs:
            assert p.wait(timeout=120) == 0
        from repro.core.estimators.cache import PersistentCache
        pc = PersistentCache(path)
        assert pc["k1"] == 1.25 and pc["k2"] == 2.5
        assert pc.cost("k1") == 0.5 and pc.cost("k2") == 0.25

    def test_process_pool_campaign_shares_live_store(
            self, toy_workload, tmp_path):
        """Process-executor workers open the path-backed store directly;
        entries any worker computes land in the log and a second run of
        the campaign replays them as pure hits."""
        d = _spec_dict(slicers=["linear", "dep"])
        d["workloads"][0]["fidelity"] = "raw"
        d["estimators"] = [{"kind": "roofline"}]
        cache = str(tmp_path / "hcr.jsonl")
        r1 = _run(d, toy_workload, executor="process", max_workers=2,
                  cache_path=cache)
        assert r1.summary["num_failed"] == 0
        assert os.path.exists(cache)
        r2 = _run(d, toy_workload, executor="process", max_workers=2,
                  cache_path=cache)
        assert r2.summary["num_failed"] == 0
        assert r2.cache["misses"] == 0 and r2.cache["hits"] > 0

    def test_append_interleaves_with_concurrent_writers(self, tmp_path):
        """append() absorbs lines other writers landed first, so no
        entry is lost regardless of interleaving."""
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.jsonl")
        a, b = PersistentCache(path), PersistentCache(path)
        a.append("ka", 1.0, cost=0.1)
        b.append("kb", 2.0, cost=0.2)    # b hasn't seen ka yet
        a.append("ka2", 3.0)
        assert "ka" in b and "kb" in a and "kb" in b
        b.refresh()
        assert "ka2" in b
        fresh = PersistentCache(path)
        assert set(fresh.entries) == {"ka", "kb", "ka2"}

    def test_refresh_detects_compaction_after_regrowth(self, tmp_path):
        """A compacted log that regrows past a reader's old offset must
        still be detected (generation id, not file size) — otherwise the
        reader tails from a stale mid-record position and silently
        misses entries."""
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.jsonl")
        a, b = PersistentCache(path), PersistentCache(path)
        a.append("k1", 1.0, cost=0.01)
        b.refresh()                       # b's offset: after k1
        a.save()                          # compaction -> new generation
        for i in range(20):               # regrow well past b's offset
            a.append(f"n{i}", float(i), cost=0.01)
        b.refresh()
        assert "k1" in b
        assert all(f"n{i}" in b for i in range(20))
        assert b.cost("n0") == 0.01

    def test_append_never_writes_into_foreign_file(self, tmp_path):
        """A stale/foreign cache file is discarded on load — and appends
        must not scribble records into it either."""
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.jsonl")
        legacy = json.dumps({"a100|roofline|deadbeef": 1.5})
        with open(path, "w") as f:
            f.write(legacy + "\n")
        pc = PersistentCache(path)
        assert len(pc) == 0
        pc.refresh()
        pc.append("k", 1.0, cost=0.1)
        assert pc["k"] == 1.0                     # in memory regardless
        assert open(path).read() == legacy + "\n"  # file untouched

    def test_save_compacts_and_other_handles_recover(self, tmp_path):
        from repro.core.estimators.cache import PersistentCache
        path = str(tmp_path / "hcr.jsonl")
        a, b = PersistentCache(path), PersistentCache(path)
        for i in range(5):
            a.append(f"k{i}", float(i), cost=0.01)
        a.append("k0", 0.0, cost=0.01)   # duplicate line in the log
        b.refresh()                      # b absorbs the full 6-line log
        a.save()                         # compaction dedups -> file shrinks
        # the file is now shorter than b's absorbed offset — b must
        # detect the truncation and re-read, not silently stall
        b.refresh()
        b.append("kb", 9.0)
        final = PersistentCache(path)
        assert set(final.entries) == {f"k{i}" for i in range(5)} | {"kb"}


# ----------------------- train-mode / GEMM workload export -----------------


class TestWorkloadExport:
    def test_gemm_campaign_matches_direct_systolic_latency(self):
        """fig10's port: a synthesized single-dot_general workload costed
        through the full pipeline must reproduce the pre-port
        ``SystolicEstimator.gemm_latency`` loop at emitted precision."""
        from repro.core.estimators import PRESETS, SystolicEstimator
        from repro.core.systems import TPU_V3_CORE

        n = 1024
        spec = CampaignSpec.from_dict({
            "name": "gemm-parity",
            "workloads": [{"name": f"gemm-{n}", "fidelity": "raw",
                           "gemm": {"m": n, "n": n, "k": n,
                                    "dtype": "bf16"}}],
            "systems": ["tpu-v3"],
            "estimators": [{"kind": "systolic", "options": {"preset": p}}
                           for p in PRESETS],
            "slicers": ["linear"],
            "topologies": [{"kind": "a2a", "params": {"num_devices": 1}}],
        })
        res = run_campaign(spec, executor="serial")
        assert res.summary["num_failed"] == 0, res.summary["failures"]
        assert len(res.ok_rows) == len(PRESETS)
        for r in res.ok_rows:
            preset = r["estimator"].split("-", 1)[1]
            ref = SystolicEstimator(TPU_V3_CORE, preset).gemm_latency(
                n, n, n, dtype="bf16")
            assert r["step_time_s"] == pytest.approx(ref, rel=1e-12)
            assert round(r["step_time_s"] * 1e6, 1) == round(ref * 1e6, 1)

    def test_train_mode_parity_with_hand_rolled_fig7_loop(self):
        """mode="train" export through the campaign engine must predict
        bit-identically to the hand-rolled fig7-style loop over the same
        shared ``resnet_train_exports`` step."""
        from repro.core.estimators import RooflineEstimator
        from repro.core.network import AllToAllNode
        from repro.core.pipeline import export_workload, predict
        from repro.core.systems import get_system
        from repro.models.resnet import ResNetConfig, resnet_train_exports

        cfg = ResNetConfig(depth=18)
        jitted, abs_args = resnet_train_exports(cfg, batch=2, img=32,
                                                mesh=None)
        w = export_workload(jitted, *abs_args, name="resnet18")
        p = predict(w.program("optimized"),
                    RooflineEstimator(get_system("a100")),
                    AllToAllNode(num_devices=4, link_bw=100e9),
                    slicer="linear", name="resnet18")

        spec = CampaignSpec.from_dict({
            "name": "train-parity",
            "workloads": [{"name": "resnet18", "arch": "resnet18",
                           "mode": "train", "batch": 2, "img": 32}],
            "systems": ["a100"],
            "estimators": [{"kind": "roofline"}],
            "slicers": ["linear"],
            "topologies": [{"kind": "a2a",
                            "params": {"num_devices": 4,
                                       "link_bw": 100e9}}],
        })
        res = run_campaign(spec, executor="serial")
        assert res.summary["num_failed"] == 0, res.summary["failures"]
        r = res.ok_rows[0]
        assert r["step_time_s"] == p.step_time_s          # bit-identical
        assert r["comm_s"] == p.comm_s
        assert r["num_segments"] == p.num_segments
        # (single-device export: gradient collectives only appear with a
        # sharded mesh — see the mesh'd fig7/fig11 specs)

    def test_train_mode_validates_in_spec(self):
        spec = CampaignSpec.from_dict(_spec_dict(workloads=[
            {"name": "t", "arch": "llama3-100m", "mode": "train",
             "mesh": [2, 1], "seq": 64, "batch": 2}]))
        assert spec.workloads[0].mesh == (2, 1)
        with pytest.raises(ValueError, match="mode"):
            CampaignSpec.from_dict(_spec_dict(workloads=[
                {"name": "t", "arch": "llama3-100m", "mode": "serve"}]))
        with pytest.raises(ValueError, match="mesh"):
            CampaignSpec.from_dict(_spec_dict(workloads=[
                {"name": "t", "arch": "llama3-100m", "mesh": [8]}]))
        with pytest.raises(ValueError, match="gemm"):
            CampaignSpec.from_dict(_spec_dict(workloads=[
                {"name": "t", "gemm": {"m": 8}}]))
        # ambiguous sources would be silently resolved by precedence —
        # reject them instead
        with pytest.raises(ValueError, match="exactly one source"):
            CampaignSpec.from_dict(_spec_dict(workloads=[
                {"name": "t", "arch": "llama3-100m",
                 "gemm": {"m": 8, "n": 8, "k": 8}}]))

    def test_resnet_export_threads_optimizer_config(self):
        """The spec's optimizer choice must reach the resnet train step
        (adafactor state is factored, adamw carries m/v moments)."""
        from repro.models.resnet import ResNetConfig, resnet_train_exports
        from repro.train.optimizer import OptimizerConfig

        cfg = ResNetConfig(depth=18)
        _, (_, opt_adamw, _, _) = resnet_train_exports(cfg, 2, 32)
        assert set(opt_adamw) == {"step", "m", "v"}
        _, (_, opt_afac, _, _) = resnet_train_exports(
            cfg, 2, 32, opt_cfg=OptimizerConfig(name="adafactor"))
        assert set(opt_afac) == {"step", "v"}


# ----------------------------------- CLI -----------------------------------


class TestCLI:
    def test_cli_campaign_with_warm_rerun(self, toy_workload, tmp_path):
        """Acceptance path: >= 12 grid points through `python -m
        repro.campaign`, JSONL + CSV out, persistent hits on rerun."""
        ir_path = tmp_path / "toy.mlir"
        ir_path.write_text(toy_workload.stablehlo_text)
        spec = {
            "name": "cli",
            "workloads": [{"name": "toy", "fidelity": "raw",
                           "stablehlo_path": str(ir_path)}],
            "systems": ["a100", "h100", "b200"],
            "estimators": [{"kind": "roofline"},
                           {"kind": "roofline",
                            "options": {"mode": "per-op"}}],
            "slicers": ["linear", "dep"],
        }
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps(spec))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        cmd = [sys.executable, "-m", "repro.campaign", str(spec_path),
               "--out", str(tmp_path / "out"), "--executor", "serial",
               "--cache", str(tmp_path / "hcr.json"), "--quiet"]

        p1 = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                            text=True, timeout=300)
        assert p1.returncode == 0, p1.stdout[-2000:] + p1.stderr[-2000:]
        rows = load_jsonl(str(tmp_path / "out" / "results.jsonl"))
        assert len(rows) == 12  # 1 workload × 3 systems × 2 est × 2 slicers
        assert os.path.exists(tmp_path / "out" / "results.csv")
        s1 = json.loads((tmp_path / "out" / "summary.json").read_text())
        assert s1["num_ok"] == 12
        assert s1["cache"]["new_entries"] > 0

        p2 = subprocess.run(cmd, cwd=REPO, env=env, capture_output=True,
                            text=True, timeout=300)
        assert p2.returncode == 0, p2.stdout[-2000:] + p2.stderr[-2000:]
        s2 = json.loads((tmp_path / "out" / "summary.json").read_text())
        assert s2["cache"]["loaded_entries"] > 0
        assert s2["cache"]["hits"] > 0 and s2["cache"]["misses"] == 0
        assert "hits" in p2.stdout  # the CLI reports the cache line

    def test_cli_validate_checked_in_specs(self):
        """The acceptance path for `python -m repro.campaign validate`:
        every checked-in spec (incl. the paper_full suite) validates and
        expands without Python glue."""
        import glob
        # bench_baselines.json is tools/bench_check.py data, not a grid
        specs = [s for s in sorted(glob.glob(
                     os.path.join(REPO, "specs", "*.json")))
                 if not s.endswith("bench_baselines.json")]
        assert any(s.endswith("paper_full.json") for s in specs)
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", "validate", *specs],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
        assert p.returncode == 0, p.stdout + p.stderr
        assert "INVALID" not in p.stdout
        for s in specs:
            assert f"ok {s}" in p.stdout

    def test_cli_validate_rejects_bad_spec(self, tmp_path):
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps({"name": "x", "workloads": [
            {"name": "w"}]}))  # no source
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", "validate", str(bad)],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
        assert p.returncode == 1
        assert "INVALID" in p.stdout

    def test_cli_validate_rejects_bad_zip_groups(self, tmp_path):
        """The validate surface catches both zip failure modes with a
        clear message: paired axes of unequal lengths (the silent
        mispairing hazard) and unknown axis names (typos)."""
        base = {"name": "z", "workloads": [
            {"name": "a", "stablehlo_path": "a.mlir"},
            {"name": "b", "stablehlo_path": "b.mlir"}]}
        unequal = tmp_path / "unequal.json"
        unequal.write_text(json.dumps(
            {**base, "topologies": [{"kind": "a2a"}],
             "zip": [["workloads", "topologies"]]}))
        typo = tmp_path / "typo.json"
        typo.write_text(json.dumps(
            {**base, "zip": [["workloads", "fabrics"]]}))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", "validate",
             str(unequal), str(typo)],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
        assert p.returncode == 1
        assert f"INVALID {unequal}" in p.stdout
        assert "unequal lengths" in p.stdout \
            and "workloads=2, topologies=1" in p.stdout
        assert f"INVALID {typo}" in p.stdout
        assert "unknown axis 'fabrics'" in p.stdout

    def test_cli_dry_run(self, toy_workload, tmp_path):
        ir_path = tmp_path / "toy.mlir"
        ir_path.write_text(toy_workload.stablehlo_text)
        spec = _spec_dict()
        spec["workloads"][0]["stablehlo_path"] = str(ir_path)
        spec_path = tmp_path / "spec.json"
        spec_path.write_text(json.dumps(spec))
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(REPO, "src")
        p = subprocess.run(
            [sys.executable, "-m", "repro.campaign", str(spec_path),
             "--dry-run"],
            cwd=REPO, env=env, capture_output=True, text=True, timeout=120)
        assert p.returncode == 0, p.stdout + p.stderr
        assert "8 grid points" in p.stdout
