"""Differential harness: legacy regex front ends vs streaming front ends.

The streaming parsers (:mod:`repro.core.ir.streaming`) exist purely for
speed — every prediction the pipeline makes must be bit-identical to what
the legacy parsers (:mod:`repro.core.ir.parser`) produce.  This suite
enforces node-for-node :class:`Program` equality (everything except uid
numbering, via :func:`repro.core.ir.assert_programs_equal`) over:

* every checked-in workload text — the fig10 GEMM spec materialized
  through :func:`build_workload`, the synthetic GEMM / sharded-training
  stacks, and canned HLO/MLIR modules covering while loops, collectives,
  and multi-result ops;
* live jax exports (raw StableHLO-MLIR and compiled post-SPMD HLO) of a
  scanned+grad model — the texts the paper's figures are built from;
* randomized well-formed op lines from seeded generators (always run)
  and hypothesis strategies (when the dev dependency is installed),
  including whitespace/comment perturbations;
* the tokenizer round-trip property: joining token lines reproduces the
  comment-stripped input text.

It also hosts the ``_parse_replica_groups`` equivalence suite: the
streaming gated helper must agree with the legacy helper on all three
textual forms (HLO iota, HLO explicit, MLIR dense) and on arbitrary junk.
"""
import json
import random

import jax
import jax.numpy as jnp
import pytest

from repro.campaign.builders import (
    build_workload,
    synthesize_gemm_stack,
    synthesize_sharded_stack,
)
from repro.campaign.spec import WorkloadSpec
from repro.core.ir import assert_programs_equal, program_diff
from repro.core.ir.parser import (
    _HloParser,
    _MlirParser,
    _parse_replica_groups,
    parse_hlo,
    parse_stablehlo,
)
from repro.core.ir.streaming import (
    _replica_groups,
    parse_hlo_streaming,
    parse_stablehlo_streaming,
)
from repro.core.ir.tokenize import HloTokens, MlirTokens, strip_comments

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS,
    reason="property-based tests need the hypothesis dev dependency "
           "(pip install -e .[dev])")


def both_mlir(text: str):
    """Parse ``text`` through both MLIR front ends, assert equality."""
    legacy = _MlirParser(text).parse()
    streaming = parse_stablehlo_streaming(text)
    assert_programs_equal(legacy, streaming)
    return legacy, streaming


def both_hlo(text: str):
    legacy = _HloParser(text).parse()
    streaming = parse_hlo_streaming(text)
    assert_programs_equal(legacy, streaming)
    return legacy, streaming


SHAPES = [(256 * (1 + i % 4), 256 * (1 + (i // 4) % 4), 512)
          for i in range(24)]

CANNED_HLO = """\
HloModule jit_toy, num_partitions=8

%add.1 (x.2: f32[], y.3: f32[]) -> f32[] {
  %x.2 = f32[] parameter(0)
  %y.3 = f32[] parameter(1)
  ROOT %add.4 = f32[] add(%x.2, %y.3)
}

%cond.10 (p.11: (s32[], f32[64,64])) -> pred[] {
  %p.11 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.12 = s32[] get-tuple-element(%p.11), index=0
  %c.13 = s32[] constant(12)
  ROOT %cmp.14 = pred[] compare(%gte.12, %c.13), direction=LT
}

%body.20 (p.21: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p.21 = (s32[], f32[64,64]{1,0}) parameter(0)
  %gte.22 = f32[64,64]{1,0} get-tuple-element(%p.21), index=1
  %dot.23 = f32[64,64]{1,0} dot(%gte.22, %gte.22), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar.24 = f32[64,64]{1,0} all-reduce(%dot.23), channel_id=1, replica_groups=[2,4]<=[8], use_global_device_ids=true, to_apply=%add.1
  %gte.25 = s32[] get-tuple-element(%p.21), index=0
  %c.26 = s32[] constant(1)
  %add.27 = s32[] add(%gte.25, %c.26)
  ROOT %tuple.28 = (s32[], f32[64,64]{1,0}) tuple(%add.27, %ar.24)
}

ENTRY %main.40 (arg.41: f32[64,64]) -> f32[64,64] {
  %arg.41 = f32[64,64]{1,0} parameter(0)
  %c.42 = s32[] constant(0)
  %tuple.43 = (s32[], f32[64,64]{1,0}) tuple(%c.42, %arg.41)
  %while.44 = (s32[], f32[64,64]{1,0}) while(%tuple.43), condition=%cond.10, body=%body.20
  ROOT %gte.45 = f32[64,64]{1,0} get-tuple-element(%while.44), index=1
}
"""


class TestCheckedInWorkloads:
    """Every checked-in workload text parses identically through both
    front ends."""

    def test_fig10_spec_gemms(self):
        with open("specs/fig10_gemm.json") as f:
            spec = json.load(f)
        for wd in spec["workloads"]:
            w = build_workload(WorkloadSpec.from_dict(wd))
            both_mlir(w.stablehlo_text)

    def test_gemm_stack(self):
        both_mlir(synthesize_gemm_stack(SHAPES))

    @pytest.mark.parametrize("kwargs", [
        {},
        {"steps": 4},
        {"steps": 3, "microbatches": 2},
        {"groups": 4},
    ])
    def test_sharded_stack(self, kwargs):
        legacy, _ = both_mlir(synthesize_sharded_stack(SHAPES, **kwargs))
        assert any(op.op == "all_reduce" for op in legacy.walk())

    def test_canned_hlo(self):
        legacy, _ = both_hlo(CANNED_HLO)
        whiles = [op for op in legacy.walk() if op.op == "while"]
        assert whiles and whiles[0].trip_count == 12

    def test_public_entrypoints_dispatch_to_streaming(self):
        text = synthesize_gemm_stack(SHAPES[:4])
        assert_programs_equal(parse_stablehlo(text),
                              parse_stablehlo(text, frontend="legacy"))
        assert_programs_equal(parse_hlo(CANNED_HLO),
                              parse_hlo(CANNED_HLO, frontend="legacy"))


class TestJaxExports:
    """Live lowered/compiled texts — the real thing the paper parses."""

    @pytest.fixture(scope="class")
    def export(self):
        def f(w, x):
            def body(h, wi):
                return jnp.tanh(h @ wi), None
            h, _ = jax.lax.scan(body, x, w)
            return jnp.sum(h.astype(jnp.float32) ** 2)
        w = jax.ShapeDtypeStruct((5, 64, 64), jnp.bfloat16)
        x = jax.ShapeDtypeStruct((32, 64), jnp.bfloat16)
        return jax.jit(jax.grad(f, argnums=0)).lower(w, x)

    def test_raw_mlir(self, export):
        both_mlir(export.as_text())

    def test_compiled_hlo(self, export):
        both_hlo(export.compile().as_text())


class TestTokenizerRoundTrip:
    """Joining token lines reproduces the comment-stripped input."""

    @pytest.mark.parametrize("text", [
        synthesize_gemm_stack(SHAPES[:4]),
        synthesize_sharded_stack(SHAPES[:4], steps=2),
        "module @m { /* multi\nline */ func.func @main() { return } }",
    ])
    def test_mlir(self, text):
        stripped = strip_comments(text)
        toks = MlirTokens(stripped)
        assert "\n".join(toks.lines) == "\n".join(stripped.splitlines())

    def test_hlo(self):
        stripped = strip_comments(CANNED_HLO)
        toks = HloTokens(stripped)
        assert "\n".join(toks.lines) == "\n".join(stripped.splitlines())


# ---------------------------------------------------------------------------
# randomized well-formed op lines (seeded generators, always run)
# ---------------------------------------------------------------------------

_MNEMONICS = ["stablehlo.add", "stablehlo.multiply", "stablehlo.tanh",
              "stablehlo.negate", "stablehlo.exponential",
              "stablehlo.transpose", "stablehlo.reshape"]
_DTYPES = ["f32", "bf16", "f16", "i32"]


def _rand_type(rng: random.Random) -> str:
    rank = rng.randint(0, 3)
    dims = "x".join(str(rng.choice([1, 8, 64, 512])) for _ in range(rank))
    dt = rng.choice(_DTYPES)
    return f"tensor<{dims}x{dt}>" if dims else f"tensor<{dt}>"


def _rand_replica_groups(rng: random.Random) -> str:
    form = rng.randint(0, 2)
    n = rng.choice([2, 4, 8])
    if form == 0:        # HLO iota
        return f"replica_groups=[{n},{8 // n}]<=[8]"
    if form == 1:        # HLO explicit
        ids = list(range(8))
        groups = [ids[i::n] for i in range(n)]
        body = ",".join("{" + ",".join(map(str, g)) + "}" for g in groups)
        return "replica_groups={" + body + "}"
    ids = list(range(8))  # MLIR dense
    groups = [ids[i::n] for i in range(n)]
    sp = " " if rng.random() < 0.5 else ""
    body = ", ".join("[" + ", ".join(map(str, g)) + "]" for g in groups)
    return (f"replica_groups{sp}={sp}dense<[{body}]>{sp}:{sp}"
            f"tensor<{n}x{8 // n}xi64>")


def _rand_mlir_module(rng: random.Random) -> str:
    """A small well-formed MLIR module of randomized op lines."""
    lines = ["module @fuzz {",
             "  func.func public @main(%arg0: tensor<8x8xf32>) "
             "-> tensor<8x8xf32> {"]
    prev = "%arg0"
    for v in range(rng.randint(1, 12)):
        ty = "tensor<8x8xf32>"
        kind = rng.random()
        if kind < 0.6:
            mnem = rng.choice(_MNEMONICS[:5])
            lines.append(f"    %{v} = {mnem} {prev}, {prev} : {ty}")
        elif kind < 0.8:
            lines.append(
                f"    %{v} = stablehlo.dot_general {prev}, {prev}, "
                f"contracting_dims = [1] x [0], "
                f"precision = [DEFAULT, DEFAULT] : ({ty}, {ty}) -> {ty}")
        else:
            rg = _rand_replica_groups(rng)
            lines.append(
                f'    %{v} = "stablehlo.all_reduce"({prev}) '
                f"<{{channel_handle = #stablehlo.channel_handle<handle = "
                f"{v + 1}, type = 1>, {rg}, use_global_device_ids}}> ({{")
            lines.append(f"    ^bb0(%l{v}: tensor<f32>, %r{v}: tensor<f32>):")
            lines.append(f"      %s{v} = stablehlo.add %l{v}, %r{v} "
                         ": tensor<f32>")
            lines.append(f"      stablehlo.return %s{v} : tensor<f32>")
            lines.append(f"    }}) : ({ty}) -> {ty}")
        prev = f"%{v}"
    lines += [f"    return {prev} : tensor<8x8xf32>", "  }", "}"]
    text = "\n".join(lines) + "\n"
    if rng.random() < 0.3:   # comment perturbation
        text = text.replace("module @fuzz {",
                            "module @fuzz { /* fuzz\ncomment */", 1)
    return text


def _rand_hlo_module(rng: random.Random) -> str:
    lines = ["HloModule fuzz, num_partitions=8", "",
             "ENTRY %main.1 (p.2: f32[8,8]) -> f32[8,8] {",
             "  %p.2 = f32[8,8]{1,0} parameter(0)"]
    prev, v = "%p.2", 3
    for _ in range(rng.randint(1, 10)):
        kind = rng.random()
        if kind < 0.5:
            opc = rng.choice(["add", "multiply", "tanh", "negate"])
            lines.append(f"  %x.{v} = f32[8,8]{{1,0}} {opc}({prev}, {prev})")
        elif kind < 0.75:
            lines.append(
                f"  %x.{v} = f32[8,8]{{1,0}} dot({prev}, {prev}), "
                "lhs_contracting_dims={1}, rhs_contracting_dims={0}")
        else:
            rg = rng.choice([f"replica_groups=[{n},{8 // n}]<=[8]"
                             for n in (2, 4, 8)]
                            + ["replica_groups={{0,1,2,3},{4,5,6,7}}"])
            lines.append(
                f"  %x.{v} = f32[8,8]{{1,0}} all-reduce({prev}), "
                f"channel_id={v}, {rg}, use_global_device_ids=true")
        prev = f"%x.{v}"
        v += 1
    lines += [f"  ROOT %r.{v} = f32[8,8]{{1,0}} copy({prev})", "}"]
    return "\n".join(lines) + "\n"


class TestRandomizedDifferential:
    def test_mlir_sweep(self):
        rng = random.Random(0xC0FFEE)
        for _ in range(60):
            text = _rand_mlir_module(rng)
            legacy = _MlirParser(text).parse()
            streaming = parse_stablehlo_streaming(text)
            diff = program_diff(legacy, streaming)
            assert not diff, f"{diff}\n--- text ---\n{text}"

    def test_hlo_sweep(self):
        rng = random.Random(0xBEEF)
        for _ in range(60):
            text = _rand_hlo_module(rng)
            legacy = _HloParser(text).parse()
            streaming = parse_hlo_streaming(text)
            diff = program_diff(legacy, streaming)
            assert not diff, f"{diff}\n--- text ---\n{text}"

    def test_mlir_tokenizer_roundtrip_sweep(self):
        rng = random.Random(7)
        for _ in range(40):
            stripped = strip_comments(_rand_mlir_module(rng))
            toks = MlirTokens(stripped)
            assert "\n".join(toks.lines) == "\n".join(stripped.splitlines())


# ---------------------------------------------------------------------------
# _parse_replica_groups: legacy vs streaming gated helper (satellite suite)
# ---------------------------------------------------------------------------

class TestReplicaGroupsEquivalence:
    CASES = [
        "replica_groups=[2,4]<=[8]",
        "replica_groups=[8,1]<=[8]",
        "replica_groups={{0,1,2,3},{4,5,6,7}}",
        "replica_groups={{0},{1}}",
        "replica_groups={{}}",
        "replica_groups = dense<[[0, 1], [2, 3]]> : tensor<2x2xi64>",
        "replica_groups = dense<[[0, 1, 2, 3, 4, 5, 6, 7]]> : tensor<1x8xi64>",
        "replica_groups = dense<> : tensor<0x0xi64>",
        "replica_groups=dense<[[0]]>:tensor<1x1xi64>",
        "no groups here at all",
        "replica_groups=",
        "devices=[8,1]<=[8]",          # sharding, not replica_groups
        'mhlo.sharding = "{devices=[8,1]<=[8]}"',
    ]

    @pytest.mark.parametrize("text", CASES)
    def test_canned_forms(self, text):
        assert _replica_groups(text) == _parse_replica_groups(text)

    def test_embedded_in_op_lines(self):
        rng = random.Random(11)
        for _ in range(100):
            rg = _rand_replica_groups(rng)
            line = (f'  %1 = "stablehlo.all_reduce"(%0) <{{{rg}}}> '
                    ": (tensor<8xf32>) -> tensor<8xf32>")
            assert _replica_groups(line) == _parse_replica_groups(line)

    @needs_hypothesis
    def test_property_iota(self):
        @settings(max_examples=200, deadline=None)
        @given(g=st.integers(0, 64), s=st.integers(0, 64),
               n=st.integers(0, 4096))
        def check(g, s, n):
            text = f"replica_groups=[{g},{s}]<=[{n}]"
            assert _replica_groups(text) == _parse_replica_groups(text)
        check()

    @needs_hypothesis
    def test_property_explicit(self):
        @settings(max_examples=200, deadline=None)
        @given(groups=st.lists(
            st.lists(st.integers(0, 63), max_size=8), min_size=1,
            max_size=8),
            ws=st.sampled_from(["", " ", "  "]))
        def check(groups, ws):
            body = ("," + ws).join(
                "{" + ",".join(map(str, g)) + "}" for g in groups)
            text = "replica_groups={" + body + "}"
            assert _replica_groups(text) == _parse_replica_groups(text)
        check()

    @needs_hypothesis
    def test_property_dense(self):
        @settings(max_examples=200, deadline=None)
        @given(g=st.integers(0, 64), s=st.integers(0, 64),
               ws=st.sampled_from(["", " ", "  "]))
        def check(g, s, ws):
            ids = ", ".join(
                "[" + ", ".join(str(i * s + j) for j in range(s)) + "]"
                for i in range(g))
            text = (f"replica_groups{ws}={ws}dense<[{ids}]>{ws}:{ws}"
                    f"tensor<{g}x{s}xi64>")
            assert _replica_groups(text) == _parse_replica_groups(text)
        check()

    @needs_hypothesis
    def test_property_junk(self):
        @settings(max_examples=300, deadline=None)
        @given(st.text(
            alphabet="replica_groups=dense<>[]{}x,i64 \t0123456789",
            max_size=120))
        def check(text):
            assert _replica_groups(text) == _parse_replica_groups(text)
        check()


@needs_hypothesis
class TestHypothesisDifferential:
    """Hypothesis-driven whole-module differential properties."""

    def test_mlir_modules(self):
        @settings(max_examples=60, deadline=None)
        @given(seed=st.integers(0, 2**32 - 1))
        def check(seed):
            text = _rand_mlir_module(random.Random(seed))
            assert not program_diff(_MlirParser(text).parse(),
                                    parse_stablehlo_streaming(text))
        check()

    def test_hlo_modules(self):
        @settings(max_examples=60, deadline=None)
        @given(seed=st.integers(0, 2**32 - 1))
        def check(seed):
            text = _rand_hlo_module(random.Random(seed))
            assert not program_diff(_HloParser(text).parse(),
                                    parse_hlo_streaming(text))
        check()
