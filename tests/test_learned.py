"""Learned cross-system fidelity tier: fit / transfer / uncertainty,
campaign + serve reachability, and the checked-in golden grid."""
import json
import os

import pytest

from repro.campaign.builders import _synthesize_gemm
from repro.campaign.spec import WorkloadSpec
from repro.core.catalog import default_registry
from repro.core.estimators import (LearnedEstimator, MixedEstimator,
                                   RooflineEstimator, fit_model, load_model,
                                   record_profile, region_family, save_model)
from repro.core.estimators.learned import MODEL_VERSION
from repro.core.pipeline import build_plan

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPEC = os.path.join(REPO, "specs", "learned_fidelity.json")

TRAIN_SIZES = (256, 512, 1024, 2048, 4096)
#: sizes where both catalog systems are compute-bound, so the linear
#: model's transfer should track the roofline closely
COMPUTE_BOUND = (2048, 4096)


def _gemm_region(m: int):
    w = _synthesize_gemm(WorkloadSpec(
        name=f"g{m}", fidelity="raw",
        gemm={"m": m, "n": m, "k": m, "dtype": "bf16"}))
    plan = build_plan(w.program("raw"), name=w.name, fidelity="raw")
    assert len(plan.compute_regions) == 1
    return plan.compute_regions[0]


@pytest.fixture(scope="module")
def systems():
    reg = default_registry()
    return reg.get("a100"), reg.get("h100-paper")


@pytest.fixture(scope="module")
def fitted(systems):
    """Model fitted from a roofline-recorded profile on a100."""
    a100, _ = systems
    regions = [_gemm_region(m) for m in TRAIN_SIZES]
    profile = record_profile(regions, RooflineEstimator(a100))
    model = fit_model(regions, profile, a100,
                      meta={"source_system": "a100"})
    return model, regions


class TestFit:
    def test_family_and_entry_counts(self, fitted):
        model, regions = fitted
        assert set(model.families) == {"matmul"}
        assert model.families["matmul"].n_samples == len(TRAIN_SIZES)
        assert model.meta["entries_fitted"] == len(TRAIN_SIZES)
        assert all(region_family(r) == "matmul" for r in regions)

    def test_parity_on_source_system(self, fitted, systems):
        """In-envelope predictions on the recording system track the
        recorder within the model's own residual spread."""
        model, _ = fitted
        a100, _ = systems
        learned = LearnedEstimator(a100, model)
        roof = RooflineEstimator(a100)
        errs = []
        for m in TRAIN_SIZES[1:]:
            r = _gemm_region(m)
            t_l = learned.get_run_time_estimate(r)
            t_r = roof.get_run_time_estimate(r)
            errs.append(abs(t_l - t_r) / t_r)
        assert sum(errs) / len(errs) < 0.10      # MAPE
        assert max(errs) < 2 * model.families["matmul"].rel_residual_std

    def test_transfer_to_second_system(self, fitted, systems):
        """Predictions transfer to a system the profile never ran on by
        rescaling features with the target's catalog constants."""
        model, _ = fitted
        _, h100 = systems
        learned = LearnedEstimator(h100, model)
        roof = RooflineEstimator(h100)
        errs = []
        for m in COMPUTE_BOUND:
            r = _gemm_region(m)
            t_l = learned.get_run_time_estimate(r)
            t_r = roof.get_run_time_estimate(r)
            errs.append(abs(t_l - t_r) / t_r)
            # every cross-system prediction is flagged as extrapolation
            assert learned.predict_with_uncertainty(r)["extrapolated"]
        assert sum(errs) / len(errs) < 0.05      # MAPE vs direct analytical

    def test_fit_rejects_unmatched_profile(self, fitted, systems):
        a100, _ = systems
        _, regions = fitted
        with pytest.raises(ValueError, match="no profile entry"):
            fit_model(regions, {"not-a-fp": 1e-6}, a100)


class TestUncertainty:
    def test_interval_brackets_prediction(self, fitted, systems):
        model, _ = fitted
        a100, _ = systems
        p = LearnedEstimator(a100, model).predict_with_uncertainty(
            _gemm_region(1024))
        assert 0 <= p["low"] <= p["seconds"] <= p["high"]
        assert p["family"] == "matmul"
        assert not p["extrapolated"]

    def test_widens_outside_fitted_envelope(self, fitted, systems):
        model, _ = fitted
        a100, _ = systems
        est = LearnedEstimator(a100, model)
        inside = est.predict_with_uncertainty(_gemm_region(1024))
        outside = est.predict_with_uncertainty(_gemm_region(8192))
        assert outside["extrapolated"] and not inside["extrapolated"]
        assert outside["rel_half_width"] > inside["rel_half_width"]

    def test_widens_on_cross_system_transfer(self, fitted, systems):
        model, _ = fitted
        a100, h100 = systems
        r = _gemm_region(1024)
        same = LearnedEstimator(a100, model).predict_with_uncertainty(r)
        moved = LearnedEstimator(h100, model).predict_with_uncertainty(r)
        assert moved["extrapolated"] and not same["extrapolated"]
        assert moved["rel_half_width"] > same["rel_half_width"]

    def test_quality_row_fields(self, fitted, systems):
        model, _ = fitted
        _, h100 = systems
        q = LearnedEstimator(h100, model).prediction_quality(
            [_gemm_region(1024), _gemm_region(8192)])
        assert q["extrapolated"] is True
        assert q["extrapolated_regions"] == 2
        assert q["uncertainty_s"] > 0
        assert q["uncertainty_rel"] > 0


class TestModelIO:
    def test_roundtrip_preserves_predictions(self, fitted, systems,
                                             tmp_path):
        model, _ = fitted
        a100, _ = systems
        path = str(tmp_path / "m.json")
        save_model(path, model)
        reloaded = load_model(path)
        r = _gemm_region(1024)
        assert LearnedEstimator(a100, reloaded).get_run_time_estimate(r) \
            == LearnedEstimator(a100, model).get_run_time_estimate(r)
        assert reloaded.digest() == model.digest()

    def test_version_gate(self, tmp_path):
        path = tmp_path / "old.json"
        path.write_text(json.dumps(
            {"version": MODEL_VERSION + 1, "families": {}}))
        with pytest.raises(ValueError, match="version"):
            load_model(str(path))
        bad = tmp_path / "bad.json"
        bad.write_text("[]")
        with pytest.raises(ValueError, match="families"):
            load_model(str(bad))

    def test_distinct_models_distinct_cache_keys(self, fitted, systems):
        model, regions = fitted
        a100, _ = systems
        profile = {r.fingerprint: 2 * RooflineEstimator(
            a100).get_run_time_estimate(r) for r in regions}
        other = fit_model(regions, profile, a100)
        k1 = LearnedEstimator(a100, model).cache_config_key
        k2 = LearnedEstimator(a100, other).cache_config_key
        assert k1.startswith("learned-") and k1 != k2
        assert LearnedEstimator(a100, model).cache_config_key == k1


class TestComposition:
    def test_supports_false_for_unknown_family(self, fitted, systems):
        model, _ = fitted
        a100, _ = systems
        import jax
        import jax.numpy as jnp

        from repro.core.ir import parse
        from repro.core.slicing import linear_split
        txt = jax.jit(lambda x: jnp.cumsum(jnp.sin(x))).lower(
            jax.ShapeDtypeStruct((4096,), jnp.float32)).as_text()
        region = linear_split(parse(txt))[0].region
        assert region_family(region) != "matmul"
        est = LearnedEstimator(a100, model)
        assert not est.supports(region)
        with pytest.raises(KeyError, match="op family"):
            est.get_run_time_estimate(region)
        mixed = MixedEstimator(est, RooflineEstimator(a100))
        assert mixed.get_run_time_estimate(region) > 0

    def test_portable_across_process_boundary(self):
        from repro.core.registry import ESTIMATORS
        assert isinstance(ESTIMATORS.get("learned"), type)
        assert ESTIMATORS.portability_errors() == []


class TestLearnedCampaign:
    @pytest.mark.parametrize("executor", ["serial", "process"])
    def test_checked_in_grid_matches_golden(self, executor):
        """The shipped learned-fidelity grid runs clean on both the
        in-process and the process-pool executor, reproduces its golden
        snapshot, and every learned row carries uncertainty fields."""
        from repro.campaign import CampaignSpec, run_campaign
        from repro.campaign.report import (check_rows, golden_path,
                                           load_json)
        spec = CampaignSpec.from_json(SPEC)
        res = run_campaign(spec, executor=executor)
        assert res.summary["num_failed"] == 0
        golden = load_json(golden_path(SPEC, "learned-fidelity"))
        assert golden is not None
        assert check_rows(golden, res.rows)["failures"] == []
        learned_rows = [r for r in res.ok_rows
                        if r["estimator"].startswith("learned-")]
        assert learned_rows
        for r in learned_rows:
            assert r["uncertainty_s"] >= 0
            assert 0 <= r["uncertainty_rel"]
            assert isinstance(r["extrapolated"], bool)
            # transferred and out-of-envelope points are flagged
            expect = (r["system"] != "a100"
                      or r["workload"] == "gemm-8192")
            assert r["extrapolated"] is expect

    def test_mape_report_row(self):
        """`report` scores the learned tier's MAPE against the recorded
        reference — the paper's cross-fidelity accuracy table."""
        from repro.campaign import CampaignSpec, run_campaign
        from repro.campaign.report import (build_report, load_json,
                                           reference_path)
        spec = CampaignSpec.from_json(SPEC)
        res = run_campaign(spec)
        ref = load_json(reference_path(SPEC, "learned-fidelity"))
        report = build_report("learned-fidelity", res.rows, reference=ref)
        mape = report["accuracy"]["mape_pct"]
        learned_label = next(k for k in mape if k.startswith("learned-"))
        assert mape["roofline"]["overall"] == pytest.approx(0.0)
        assert 0 < mape[learned_label]["overall"] < 15.0
        assert report["rank_preservation"]["all_trends_preserved"]

    def test_serve_preload_and_campaign(self):
        """The warm daemon preloads the learned grid's plans and serves
        the campaign with uncertainty fields intact."""
        from repro.serve.server import PredictionService
        service = PredictionService()
        info = service.preload(SPEC)
        assert info["plans_built"] == 4
        rows = []
        result = service.campaign({"spec_path": SPEC,
                                   "executor": "serial"},
                                  on_row=rows.append)
        assert result.summary["num_failed"] == 0
        assert any("uncertainty_s" in r for r in rows)

    def test_checked_in_model_regenerates_identically(self, systems):
        """tools/fit_learned_model.py output is deterministic — the
        checked-in model is exactly what a re-fit produces."""
        a100, _ = systems
        regions = [_gemm_region(m) for m in TRAIN_SIZES]
        profile = record_profile(regions, RooflineEstimator(a100))
        model = fit_model(regions, profile, a100, meta={
            "source_system": "a100", "recorded_with": "roofline",
            "workloads": [f"gemm-{m}" for m in TRAIN_SIZES]})
        shipped = load_model(os.path.join(
            REPO, "specs", "models", "learned-gemm-a100.json"))
        assert model.digest() == shipped.digest()
