"""Registry + system-catalog contract: open vocabularies, error paths,
catalog round-trips, entry-point plugin discovery, and the back-compat
shims over both."""
import json
import os
import sys

import pytest

import repro.core.registry as registry_mod
from repro.core.catalog import (SystemRegistry, default_registry,
                                validate_system_dict)
from repro.core.registry import (ESTIMATORS, TOPOLOGIES, BuildContext,
                                 Registry, discover_plugins, plugin_status,
                                 register_estimator, register_topology)
from repro.core.systems import Interconnect, System


def _backend(kind_label="x"):
    class Backend:
        @classmethod
        def from_spec(cls, options, system, context):
            return cls()
    Backend.__name__ = f"Backend_{kind_label}"
    return Backend


class TestRegistry:
    def test_register_and_get(self):
        reg = Registry("estimator")
        cls = _backend()
        reg.register("mine", cls)
        assert "mine" in reg
        assert reg.get("mine") is cls
        assert "mine" in reg.kinds()

    def test_decorator_form(self):
        reg = Registry("estimator")

        @reg.register("deco")
        class Deco:
            @classmethod
            def from_spec(cls, options, system, context):
                return cls()

        assert reg.get("deco") is Deco

    def test_duplicate_kind_is_error(self):
        reg = Registry("estimator")
        reg.register("mine", _backend())
        with pytest.raises(ValueError, match="already registered"):
            reg.register("mine", _backend())

    def test_duplicate_builtin_kind_is_error(self):
        scope = ESTIMATORS.scope()
        with pytest.raises(ValueError, match="already registered"):
            scope.register("roofline", _backend())

    def test_replace_overrides(self):
        reg = Registry("estimator")
        reg.register("mine", _backend())
        new = _backend("new")
        reg.register("mine", new, replace=True)
        assert reg.get("mine") is new

    def test_backend_without_from_spec_rejected(self):
        reg = Registry("estimator")
        with pytest.raises(TypeError, match="from_spec"):
            reg.register("bad", object)

    def test_unknown_kind_suggestion(self):
        with pytest.raises(ValueError, match="did you mean 'roofline'"):
            ESTIMATORS.get("rooflien")
        msg = TOPOLOGIES.unknown_message("torsu")
        assert "unknown topology kind 'torsu'" in msg
        assert "did you mean 'torus'" in msg

    def test_builtin_kinds_resolve_lazily(self):
        # names are known without importing backends; get() resolves
        for kind in ("roofline", "systolic", "mixed", "profiling",
                     "table"):
            assert kind in ESTIMATORS
            assert kind in ESTIMATORS.kinds()
            assert hasattr(ESTIMATORS.get(kind), "from_spec")
        for kind in ("auto", "a2a", "dragonfly", "torus", "multipod"):
            assert kind in TOPOLOGIES
            assert hasattr(TOPOLOGIES.get(kind), "from_spec")

    def test_scope_falls_back_and_stays_local(self):
        scope = ESTIMATORS.scope()
        cls = _backend()
        scope.register("scoped-kind", cls)
        assert scope.get("scoped-kind") is cls
        assert scope.get("roofline") is ESTIMATORS.get("roofline")
        assert "scoped-kind" not in ESTIMATORS          # global untouched
        assert "scoped-kind" in scope.kinds()
        assert scope.local_entries() == {"scoped-kind": cls}

    def test_global_decorators_route_to_globals(self):
        cls = _backend()
        try:
            register_estimator("tmp-global-est", cls)
            assert ESTIMATORS.get("tmp-global-est") is cls
        finally:
            ESTIMATORS._entries.pop("tmp-global-est", None)
        cls2 = _backend()
        try:
            register_topology("tmp-global-topo", cls2)
            assert TOPOLOGIES.get("tmp-global-topo") is cls2
        finally:
            TOPOLOGIES._entries.pop("tmp-global-topo", None)


_PLUGIN_SRC = '''\
"""Synthetic repro backend distribution (test fixture)."""
from repro.core.registry import register_estimator, register_topology


@register_estimator("ep-sim")
class EpSimEstimator:
    @classmethod
    def from_spec(cls, options, system, context):
        return cls()


@register_topology("ep-topo")
class EpTopology:
    @classmethod
    def from_spec(cls, params, system, context):
        return cls()
'''

_BROKEN_SRC = 'raise ImportError("synthetic broken plugin")\n'


def _make_dist(root, dist: str, module: str, ep_name: str, source: str):
    """A minimal installed distribution: module + .dist-info with an
    ``entry_points.txt`` in the ``repro.backends`` group — everything
    ``importlib.metadata`` needs to surface the entry point."""
    (root / f"{module}.py").write_text(source)
    info = root / f"{dist}-0.1.dist-info"
    info.mkdir()
    (info / "METADATA").write_text(
        f"Metadata-Version: 2.1\nName: {dist}\nVersion: 0.1\n")
    (info / "entry_points.txt").write_text(
        f"[repro.backends]\n{ep_name} = {module}\n")
    (info / "RECORD").write_text("")


@pytest.fixture
def plugin_state(monkeypatch):
    """Fresh discovery state; global registries restored afterwards."""
    monkeypatch.setattr(registry_mod, "_plugins_scanned", False)
    monkeypatch.setattr(registry_mod, "_plugin_modules", {})
    monkeypatch.setattr(registry_mod, "_plugin_errors", {})
    yield
    ESTIMATORS._entries.pop("ep-sim", None)
    TOPOLOGIES._entries.pop("ep-topo", None)
    for mod in ("repro_ep_plug", "repro_ep_broken"):
        sys.modules.pop(mod, None)


class TestPluginDiscovery:
    def test_installed_plugin_autoregisters(self, tmp_path, monkeypatch,
                                            plugin_state):
        _make_dist(tmp_path, "repro_ep_plug", "repro_ep_plug",
                   "ep-plug", _PLUGIN_SRC)
        monkeypatch.syspath_prepend(str(tmp_path))
        # no explicit import anywhere: the kind lookup alone finds it
        assert hasattr(ESTIMATORS.get("ep-sim"), "from_spec")
        assert "ep-topo" in TOPOLOGIES
        assert "ep-sim" in ESTIMATORS.kinds()
        status = plugin_status()
        assert status["loaded"] == {"ep-plug": "repro_ep_plug"}
        assert status["errors"] == {}

    def test_discovery_runs_once_per_process(self, tmp_path, monkeypatch,
                                             plugin_state):
        _make_dist(tmp_path, "repro_ep_plug", "repro_ep_plug",
                   "ep-plug", _PLUGIN_SRC)
        monkeypatch.syspath_prepend(str(tmp_path))
        assert discover_plugins() == {"ep-plug": "repro_ep_plug"}
        import importlib.metadata as md

        def bomb(*a, **k):
            raise AssertionError("entry points rescanned")
        monkeypatch.setattr(md, "entry_points", bomb)
        assert discover_plugins() == {"ep-plug": "repro_ep_plug"}
        assert "ep-sim" in ESTIMATORS          # cached, no rescan

    def test_broken_plugin_warns_but_others_load(self, tmp_path,
                                                 monkeypatch, plugin_state):
        _make_dist(tmp_path, "repro_ep_plug", "repro_ep_plug",
                   "ep-plug", _PLUGIN_SRC)
        _make_dist(tmp_path, "repro_ep_broken", "repro_ep_broken",
                   "ep-broken", _BROKEN_SRC)
        monkeypatch.syspath_prepend(str(tmp_path))
        with pytest.warns(RuntimeWarning, match="failed to load"):
            loaded = discover_plugins()
        assert loaded == {"ep-plug": "repro_ep_plug"}
        assert "ImportError" in plugin_status()["errors"]["ep-broken"]
        assert ESTIMATORS.get("ep-sim")        # good plugin unaffected

    def test_unknown_kind_message_includes_plugin_kinds(self, tmp_path,
                                                        monkeypatch,
                                                        plugin_state):
        _make_dist(tmp_path, "repro_ep_plug", "repro_ep_plug",
                   "ep-plug", _PLUGIN_SRC)
        monkeypatch.syspath_prepend(str(tmp_path))
        with pytest.raises(ValueError, match="did you mean 'ep-sim'"):
            ESTIMATORS.get("ep-simm")

    def test_no_plugins_installed_is_quiet(self, plugin_state):
        assert discover_plugins() == {}
        assert plugin_status()["scanned"] is True
        with pytest.raises(ValueError, match="unknown estimator"):
            ESTIMATORS.get("nope-kind")


class TestSpecKindsShim:
    def test_spec_module_tuples_are_live(self):
        from repro.campaign import spec
        assert spec.ESTIMATOR_KINDS == ESTIMATORS.kinds()
        assert spec.TOPOLOGY_KINDS == TOPOLOGIES.kinds()
        assert "roofline" in spec.ESTIMATOR_KINDS
        assert "auto" in spec.TOPOLOGY_KINDS
        # from-import form keeps working
        from repro.campaign.spec import ESTIMATOR_KINDS
        assert "table" in ESTIMATOR_KINDS


class TestSystemCatalog:
    def test_roundtrip_every_shipped_system(self):
        from repro.core.systems import SYSTEMS
        assert len(SYSTEMS) >= 10
        for sid, s in SYSTEMS.items():
            rt = System.from_dict(json.loads(json.dumps(s.to_dict())))
            assert rt == s, sid

    def test_backcompat_imports_agree_with_catalog(self):
        from repro.core.systems import (A100, SYSTEMS, TPU_V3_CORE,
                                        get_system)
        reg = default_registry()
        assert A100 == reg.get("a100") == SYSTEMS["a100"]
        assert TPU_V3_CORE == reg.get("tpu-v3")
        assert get_system("h100") == reg.get("h100")
        assert set(SYSTEMS) == set(reg.names())
        with pytest.raises(AttributeError):
            from repro.core import systems
            systems.NOT_A_SYSTEM  # noqa: B018

    def test_catalog_sources_are_files(self):
        reg = default_registry()
        for sid in reg.names():
            assert reg.source(sid).endswith(f"{sid}.json")
            assert os.path.exists(reg.source(sid))

    def test_interconnect_tuple_params_roundtrip(self):
        ic = Interconnect("torus2d", link_bw=1e9, params={"dims": (4, 2)})
        rt = Interconnect.from_dict(json.loads(json.dumps(ic.to_dict())))
        assert rt == ic
        assert rt.params["dims"] == (4, 2)

    def test_register_and_shadow(self, tmp_path):
        reg = default_registry().scope()
        a100 = default_registry().get("a100")
        custom = System.from_dict(dict(a100.to_dict(), name="Custom"))
        reg.register("mychip", custom)
        assert reg.get("mychip").name == "Custom"
        assert reg.source("mychip") == "<api>"
        with pytest.raises(ValueError, match="already registered"):
            reg.register("mychip", custom)
        # shadowing a parent entry is allowed (user catalog overrides)
        reg.register("a100", custom)
        assert reg.get("a100").name == "Custom"
        assert default_registry().get("a100").name == a100.name

    def test_host_reserved_and_resolvable(self):
        reg = default_registry()
        assert "host" in reg
        with pytest.raises(ValueError, match="reserved"):
            reg.scope().register("host", reg.get("a100"))

    def test_unknown_system_suggestion(self):
        with pytest.raises(KeyError, match="did you mean 'a100'"):
            default_registry().get("a100x")

    def test_load_catalog_file_and_dir(self, tmp_path):
        rec = dict(default_registry().get("a100").to_dict(),
                   name="FileChip")
        path = tmp_path / "filechip.json"
        path.write_text(json.dumps({"id": "filechip", **rec}))
        reg = default_registry().scope()
        assert reg.load_path(str(tmp_path)) == ["filechip"]
        assert reg.get("filechip").name == "FileChip"
        assert reg.source("filechip") == str(path)

    def test_schema_validation_errors(self, tmp_path):
        good = {"id": "x", **default_registry().get("a100").to_dict()}
        validate_system_dict(good)
        for mutate, match in [
                (lambda d: d.pop("peak_flops"), "missing"),
                (lambda d: d.update(peak_flops={}), "peak_flops"),
                (lambda d: d.update(mem_bw=-1), "mem_bw"),
                (lambda d: d.update(bogus=1), "unknown system fields"),
                (lambda d: d.update(interconnect={"kind": "x"}),
                 "interconnect"),
                (lambda d: d["interconnect"].update(bogus=3),
                 "unknown interconnect fields")]:
            d = json.loads(json.dumps(good))
            mutate(d)
            with pytest.raises(ValueError, match=match):
                validate_system_dict(d)
        bad = tmp_path / "bad.json"
        bad.write_text("{nope")
        with pytest.raises(ValueError, match="not valid JSON"):
            SystemRegistry().load_file(str(bad))


class TestBuildContext:
    def test_resolve_path(self, tmp_path):
        ctx = BuildContext(base_dir=str(tmp_path))
        assert ctx.resolve_path("p.json") == str(tmp_path / "p.json")
        assert ctx.resolve_path("/abs/p.json") == "/abs/p.json"
        assert BuildContext().resolve_path("p.json") == "p.json"
