"""Offset-index sidecar tests for :class:`PersistentCache`.

The sidecar (``<path>.idx``) buys O(1) point lookups into the JSONL
append log.  These tests pin its safety story: the log is the single
source of truth (a torn/foreign/stale sidecar is rebuilt, never
trusted), coherence across two live handles costs no extra lock traffic
(the log's flock guards both files), and a warm hit touches no disk at
all — ``scan_bytes`` stays 0, the deterministic counter the bench suite
asserts.
"""
import json
import os

from repro.core.estimators.cache import PersistentCache


def _entries(n, base=0):
    return {f"k{base + i}": (float(base + i), 0.001) for i in range(n)}


class TestIndexBasics:
    def test_put_many_creates_sidecar(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(8))
        assert os.path.exists(path + ".idx")
        with open(path + ".idx") as f:
            lines = [json.loads(line) for line in f]
        header, body = lines[0], lines[1:]
        assert header["schema"] == 2
        keys = {r["k"] for r in body if "k" in r}
        assert keys == set(_entries(8))
        # last line is a coverage marker spanning the whole log
        assert body[-1]["c"] == os.path.getsize(path)

    def test_index_offsets_point_at_records(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(5))
        with open(path) as f:
            for key, off in pc._idx.items():
                f.seek(off)
                rec = json.loads(f.readline())
                assert rec["k"] == key

    def test_append_after_first_batch_extends_index(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(3))
        pc.put_many(_entries(3, base=10))
        fresh = PersistentCache(path, lazy=True)
        assert set(fresh._idx) == set(_entries(3)) | set(_entries(3, base=10))

    def test_stats_dict_exposes_counters(self, tmp_path):
        pc = PersistentCache(str(tmp_path / "hcr.jsonl"))
        pc.put_many(_entries(2))
        d = pc.stats_dict()
        assert {"scan_bytes", "point_reads", "index_keys"} <= set(d)
        assert d["index_keys"] == 2


class TestPointLookups:
    def test_lazy_load_reads_no_records(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        PersistentCache(path).put_many(_entries(50))
        lazy = PersistentCache(path, lazy=True)
        assert len(lazy.entries) == 0
        assert lazy.scan_bytes == 0
        assert len(lazy._idx) == 50

    def test_lazy_get_many_is_point_reads_not_tail(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        PersistentCache(path).put_many(_entries(100))
        log_size = os.path.getsize(path)
        lazy = PersistentCache(path, lazy=True)
        got = lazy.get_many(["k3", "k97"])
        assert got == {"k3": 3.0, "k97": 97.0}
        assert lazy.point_reads == 2
        # read two record lines, not the 100-record log
        assert 0 < lazy.scan_bytes < log_size / 10

    def test_warm_hit_scan_bytes_zero(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(10))
        pc.scan_bytes = 0
        base_locks = pc.lock_roundtrips
        for _ in range(5):
            assert pc.get_many(list(_entries(10))) \
                == {k: v for k, (v, _) in _entries(10).items()}
        assert pc.scan_bytes == 0          # no disk I/O on warm hits
        assert pc.point_reads == 0
        assert pc.lock_roundtrips == base_locks

    def test_absent_key_after_full_sync_takes_no_lock(self, tmp_path):
        # fully synced + unchanged file: absent in memory == absent on
        # disk, so even a miss lookup is stat-only
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(3))
        base = pc.lock_roundtrips
        assert pc.get_many(["nope"]) == {}
        assert pc.lock_roundtrips == base

    def test_point_read_one_lock_roundtrip_per_batch(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        PersistentCache(path).put_many(_entries(20))
        lazy = PersistentCache(path, lazy=True)
        base = lazy.lock_roundtrips
        lazy.get_many([f"k{i}" for i in range(20)])
        assert lazy.lock_roundtrips == base + 1


class TestTwoLiveProcesses:
    def test_writer_then_reader_coherence(self, tmp_path):
        """A appends, B resolves A's fresh keys by point-read — the
        mid-campaign coherence story, now without tailing the whole log."""
        path = str(tmp_path / "hcr.jsonl")
        a = PersistentCache(path)
        b = PersistentCache(path)
        a.put_many(_entries(4))
        got = b.get_many(["k1", "k3"])
        assert got == {"k1": 1.0, "k3": 3.0}
        assert b.point_reads == 2
        # and the reverse direction: B writes, A point-reads
        b.put_many(_entries(2, base=50))
        assert a.get_many(["k51"]) == {"k51": 51.0}
        assert a.point_reads >= 1

    def test_interleaved_writers_index_stays_complete(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        a = PersistentCache(path)
        b = PersistentCache(path)
        a.put_many(_entries(3))
        b.put_many(_entries(3, base=10))
        a.put_many(_entries(3, base=20))
        fresh = PersistentCache(path, lazy=True)
        want = set(_entries(3)) | set(_entries(3, base=10)) \
            | set(_entries(3, base=20))
        assert set(fresh._idx) == want
        assert fresh.get_many(sorted(want)) \
            == {k: float(k[1:]) for k in want}

    def test_compaction_invalidates_other_handles_index(self, tmp_path):
        """save() rewrites the log with a fresh generation; a live handle
        holding pre-compaction byte offsets must drop them rather than
        seek into the rewritten file."""
        path = str(tmp_path / "hcr.jsonl")
        a = PersistentCache(path)
        b = PersistentCache(path, lazy=True)
        a.put_many(_entries(6))
        b.get_many(["k0"])             # b now holds gen-1 offsets
        a.save()                       # compaction: fresh gen, new offsets
        a.put_many(_entries(2, base=30))
        got = b.get_many(["k31", "k5"])
        assert got == {"k31": 31.0, "k5": 5.0}


class TestCrashRecovery:
    def test_truncated_sidecar_rebuilt_from_log(self, tmp_path):
        """A sidecar torn mid-line (crashed writer) loses nothing: the
        uncovered suffix is tailed on reads, and the next put_many
        regenerates the index from the log."""
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(10))
        with open(path + ".idx") as f:
            full = f.read()
        with open(path + ".idx", "w") as f:
            f.write(full[: len(full) // 2])   # torn: no coverage marker
        fresh = PersistentCache(path, lazy=True)
        # every key still resolves (index hit or uncovered-suffix tail)
        assert fresh.get_many(list(_entries(10))) \
            == {k: v for k, (v, _) in _entries(10).items()}
        # the next write heals the sidecar completely
        writer = PersistentCache(path, lazy=True)
        writer.put_many(_entries(1, base=99))
        healed = PersistentCache(path, lazy=True)
        assert set(healed._idx) == set(_entries(10)) | {"k99"}

    def test_deleted_sidecar_rebuilt(self, tmp_path):
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(5))
        os.unlink(path + ".idx")
        # reads fall back to tailing the log — nothing lost
        lazy = PersistentCache(path, lazy=True)
        assert lazy.get_many(["k2"]) == {"k2": 2.0}
        # explicit repair
        n = lazy.rebuild_index()
        assert n == 5 and os.path.exists(path + ".idx")
        again = PersistentCache(path, lazy=True)
        assert again.get_many(["k4"]) == {"k4": 4.0}
        assert again.point_reads == 1

    def test_foreign_sidecar_never_trusted(self, tmp_path):
        """A sidecar from another log generation (stale copy, wrong file)
        must be ignored and replaced, not followed into wrong offsets."""
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(4))
        with open(path + ".idx", "w") as f:
            f.write(json.dumps({"schema": 2, "fingerprint": 1,
                                "gen": "not-the-real-gen"}) + "\n")
            f.write(json.dumps({"k": "k0", "o": 999999}) + "\n")
            f.write(json.dumps({"c": 999999}) + "\n")
        lazy = PersistentCache(path, lazy=True)
        assert lazy.get_many(list(_entries(4))) \
            == {k: v for k, (v, _) in _entries(4).items()}
        writer = PersistentCache(path, lazy=True)
        writer.put_many(_entries(1, base=77))
        healed = PersistentCache(path, lazy=True)
        assert set(healed._idx) == set(_entries(4)) | {"k77"}

    def test_torn_log_tail_still_indexable(self, tmp_path):
        """A crashed *log* writer leaves a torn last record; rebuild and
        lookups skip it exactly like the absorb path does."""
        path = str(tmp_path / "hcr.jsonl")
        pc = PersistentCache(path)
        pc.put_many(_entries(3))
        with open(path, "a") as f:
            f.write('{"k": "torn')           # no newline, no close quote
        lazy = PersistentCache(path, lazy=True)
        assert lazy.get_many(["k1"]) == {"k1": 1.0}
        assert lazy.rebuild_index() == 3
