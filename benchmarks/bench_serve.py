"""Prediction-as-a-service performance: cold process vs warm daemon.

Boots one ``repro.serve`` daemon (in-process, real localhost HTTP —
exactly the CLI daemon's serving stack), preloads the Fig 10 GEMM spec,
and measures what the warm session amortizes:

  * cold-boot baseline — a fresh subprocess paying full interpreter
    startup + imports + workload synthesis + parse for ONE prediction
    (what every query cost before the daemon existed);
  * warm-request latency + req/s — the same prediction as an HTTP
    round trip against resident plans and a warm (H, C, R) store;
  * coalescing — a concurrent burst of identical cold queries must
    record exactly ONE cold miss (the chain-leader singleflight), with
    ``/stats`` proving ``duplicate_cold_misses == 0``;
  * campaign-over-HTTP — replaying the spec twice: the warm second run
    re-parses nothing and misses nothing.

Emits ``BENCH_serve.json`` at the repo root (the perf-trajectory
artifact; ``tools/bench_check.py`` gates its deterministic counters —
never the wall-clock numbers) plus the usual CSV under
``artifacts/bench/``.
"""
import json
import os
import statistics
import subprocess
import sys
import threading
import time

from benchmarks.common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPEC = os.path.join(REPO, "specs", "fig10_gemm.json")

COLD_RUNS = 3
WARM_REQUESTS = 50
BURST_SIZE = 16

#: the one prediction both sides of the cold/warm comparison make
POINT = dict(system="tpu-v3",
             estimator={"kind": "systolic", "options": {"preset": "onnxim"}})

_COLD_SCRIPT = """
from repro import api
from repro.campaign.builders import build_workload
from repro.campaign.spec import WorkloadSpec

session = api.Session()
w = build_workload(WorkloadSpec(
    name="gemm-1024", fidelity="raw",
    gemm={"m": 1024, "n": 1024, "k": 1024, "dtype": "bf16"}))
p = session.predict(w, system="tpu-v3", estimator="systolic",
                    options={"preset": "onnxim"}, fidelity="raw")
print(p.to_row()["step_time_s"])
"""


def _cold_boot() -> dict:
    """Median wall seconds for a fresh process to make one prediction."""
    times = []
    for _ in range(COLD_RUNS):
        t0 = time.perf_counter()
        proc = subprocess.run([sys.executable, "-c", _COLD_SCRIPT],
                              cwd=REPO, capture_output=True, text=True)
        times.append(time.perf_counter() - t0)
        assert proc.returncode == 0, proc.stderr
    return {"runs": COLD_RUNS, "median_s": round(statistics.median(times), 4),
            "times_s": [round(t, 4) for t in times]}


def _warm_requests(client) -> dict:
    """Median HTTP round-trip latency + throughput on resident plans."""
    client.predict("gemm-1024", **POINT)      # ensure the key is warm
    times = []
    t_all0 = time.perf_counter()
    for _ in range(WARM_REQUESTS):
        t0 = time.perf_counter()
        client.predict("gemm-1024", **POINT)
        times.append(time.perf_counter() - t0)
    wall = time.perf_counter() - t_all0
    times.sort()
    return {
        "requests": WARM_REQUESTS,
        "median_s": round(statistics.median(times), 6),
        "p90_s": round(times[int(0.9 * len(times))], 6),
        "req_per_s": round(WARM_REQUESTS / wall, 1),
    }


def _coalescing_burst(client, service) -> dict:
    """A concurrent burst of identical COLD queries (a workload the
    daemon has never seen) → exactly one cold miss between them."""
    burst_workload = {"name": "gemm-burst", "fidelity": "raw",
                      "gemm": {"m": 3333, "n": 3333, "k": 3333,
                               "dtype": "bf16"}}
    before = service.stats()["predict"]
    errs: list[Exception] = []

    def hit():
        try:
            client.predict(burst_workload, **POINT)
        except Exception as e:  # noqa: BLE001 — report via the list
            errs.append(e)

    threads = [threading.Thread(target=hit) for _ in range(BURST_SIZE)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs, errs
    after = service.stats()["predict"]
    return {
        "burst_size": BURST_SIZE,
        "burst_cold_misses": after["cache_misses"] - before["cache_misses"],
        "duplicate_cold_misses": after["duplicate_cold_misses"],
        # how many requests actually waited on the in-flight leader —
        # timing-dependent (fast evaluations finish before the burst
        # lands), recorded but never gated
        "coalesced_requests": after["coalesced"] - before["coalesced"],
    }


def _campaign_http(client) -> dict:
    """The spec replayed twice over HTTP: run 2 is fully warm."""
    runs = []
    for _ in range(2):
        stream = client.campaign(spec_path=SPEC, executor="thread")
        rows, summary = stream.collect()
        assert summary["num_failed"] == 0, summary
        runs.append({"rows": len(rows),
                     "cache_misses": summary["cache"]["misses"],
                     "cache_hits": summary["cache"]["hits"],
                     "parse_calls": summary["plans"]["parse_calls"]})
    return {"first": runs[0], "second_warm": runs[1]}


def main() -> None:
    from repro.serve.client import ServeClient
    from repro.serve.server import PredictionServer, PredictionService

    cold = _cold_boot()

    t0 = time.perf_counter()
    service = PredictionService()
    preload = service.preload(SPEC)
    server = PredictionServer(service, port=0).start()
    boot_s = time.perf_counter() - t0
    try:
        client = ServeClient(server.url)
        warm = _warm_requests(client)
        burst = _coalescing_burst(client, service)
        campaign = _campaign_http(client)
    finally:
        server.drain(timeout_s=10.0)

    report = {
        "bench": "serve",
        "daemon_boot_s": round(boot_s, 4),
        "cold_boot": cold,
        "warm": warm,
        "speedup_cold_over_warm": round(
            cold["median_s"] / max(warm["median_s"], 1e-9), 1),
        "preload": {"workloads": len(preload["workloads"]),
                    "plans_built": preload["plans_built"]},
        "coalescing": burst,
        "campaign_http": campaign,
    }
    path = os.path.join(REPO, "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")

    emit([
        {"name": "serve-cold-boot", "us_per_call": cold["median_s"] * 1e6},
        {"name": "serve-warm-request", "us_per_call": warm["median_s"] * 1e6,
         "req_per_s": warm["req_per_s"],
         "speedup": report["speedup_cold_over_warm"]},
        {"name": "serve-coalescing", "us_per_call": "", **burst},
        {"name": "serve-campaign-warm", "us_per_call": "",
         **campaign["second_warm"]},
    ], "bench_serve")

    # the ISSUE's acceptance bar + the invariants the gate relies on
    assert report["speedup_cold_over_warm"] >= 50, report
    assert burst["burst_cold_misses"] == 1, report
    assert burst["duplicate_cold_misses"] == 0, report
    assert campaign["second_warm"]["cache_misses"] == 0, report
    assert campaign["second_warm"]["parse_calls"] == 0, report


if __name__ == "__main__":
    main()
