import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""Paper §III-B(c) caching experiment: the (H × C × R) latency cache.

Paper numbers: 89.7 % average evaluation-time reduction on Llama-3
(stacked identical transformer blocks -> massive fingerprint reuse) and
26.8 % on ResNet (stage shapes differ, less reuse).  We measure the same
metric — fraction of profiling-estimator wall time avoided by the cache —
on one Llama-3 and one ResNet export, and additionally report hit rates."""
from benchmarks.common import build_llama_step, emit  # noqa: E402


def _profile_time(prog, use_cache: bool) -> tuple[float, object]:
    """One campaign job, cache on/off — the unit the campaign engine runs."""
    import time
    from repro.core.estimators import ProfilingEstimator
    from repro.core.network import AllToAllNode
    from repro.core.pipeline import PredictionJob

    job = PredictionJob(
        program=prog, estimator=ProfilingEstimator(program=prog, runs=2),
        topology=AllToAllNode(num_devices=4, link_bw=10e9),
        slicer="dep", use_cache=use_cache, name="cache-exp")
    t0 = time.perf_counter()
    p = job.run()
    return time.perf_counter() - t0, p.cache_stats


def main() -> None:
    import jax
    from repro.core.pipeline import export_workload
    from repro.launch.mesh import make_mesh

    rows = []
    mesh = make_mesh((4, 1), ("data", "model"))

    # Llama-3: 12 identical blocks, python-unrolled with explicit
    # optimization_barrier region boundaries (the paper's per-layer
    # compute regions) -> near-total fingerprint reuse
    cfg, jitted, abs_args, _ = build_llama_step(
        "llama3-100m", seq=512, batch=4, mesh=mesh, train=False,
        cfg_overrides={"scan_layers": False, "layer_barriers": True,
                       "remat": "none"})
    with mesh:
        w = export_workload(jitted, *abs_args, name="llama3-100m",
                            compile_workload=False)
    prog = w.program("raw")
    t_cached, stats = _profile_time(prog, use_cache=True)
    t_uncached, _ = _profile_time(prog, use_cache=False)
    rows.append({
        "name": "caching-llama3",
        "us_per_call": t_cached * 1e6,
        "cached_s": round(t_cached, 2),
        "uncached_s": round(t_uncached, 2),
        "saving_pct": round((1 - t_cached / t_uncached) * 100, 1),
        "hit_rate_pct": round(stats.hit_rate * 100, 1),
        "paper_reference_pct": 89.7,
    })

    # ResNet-50 (stage shapes differ -> partial reuse)
    from benchmarks.fig7_resnet import _build
    jitted, abs_args, _ = _build(50, batch=8, img=64, mesh=mesh,
                                 barriers=True)
    with mesh:
        w = export_workload(jitted, *abs_args, name="resnet50",
                            compile_workload=False)
    prog = w.program("raw")
    t_cached, stats = _profile_time(prog, use_cache=True)
    t_uncached, _ = _profile_time(prog, use_cache=False)
    rows.append({
        "name": "caching-resnet50",
        "us_per_call": t_cached * 1e6,
        "cached_s": round(t_cached, 2),
        "uncached_s": round(t_uncached, 2),
        "saving_pct": round((1 - t_cached / t_uncached) * 100, 1),
        "hit_rate_pct": round(stats.hit_rate * 100, 1),
        "paper_reference_pct": 26.8,
    })
    emit(rows, "caching_exp")


if __name__ == "__main__":
    main()
