import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""Paper Fig 7: training-step latency for ResNet variants on a 4-GPU A100
system (data parallel).  Host-validated structural claims at reduced batch
(the estimator-ordering property is batch-independent), full-batch (256 per
device, FP16 — paper Table III) A100 predictions from the same export."""
import sys

sys.path.insert(0, os.path.dirname(__file__) + "/..")
from benchmarks.common import emit, mape, measure  # noqa: E402


def _build(depth: int, batch: int, img: int, mesh, barriers: bool = False):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.distributed.sharding import act_sharding, param_sharding
    from repro.models.params import abstract_params, init_params
    from repro.models.resnet import ResNetConfig, resnet_forward, resnet_specs
    from repro.train.optimizer import OptimizerConfig, adamw_update, adamw_init

    cfg = ResNetConfig(depth=depth, block_barriers=barriers)
    specs = resnet_specs(cfg)
    opt_cfg = OptimizerConfig(name="adamw")

    def step(params, opt, images, labels):
        loss, grads = jax.value_and_grad(
            lambda p: resnet_forward(cfg, p, images, labels)[0])(params)
        params, opt, _ = adamw_update(params, grads, opt, opt_cfg)
        return params, opt, loss

    jitted = jax.jit(step, donate_argnums=(0, 1))
    params_abs = abstract_params(specs, mesh)
    img_sh = act_sharding(("batch", "seq", "seq", "embed"), mesh, None,
                          (batch, img, img, 3))
    lbl_sh = act_sharding(("batch",), mesh, None, (batch,))
    imgs = jax.ShapeDtypeStruct((batch, img, img, 3), jnp.float16,
                                sharding=img_sh)
    lbls = jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=lbl_sh)
    from repro.launch.dryrun import _opt_state_abstract
    opt_abs = _opt_state_abstract(specs, "adamw", mesh, None)

    def concrete(key):
        params = init_params(specs, key)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                              params, params_abs)
        opt = adamw_init(params, opt_cfg)
        rng = np.random.default_rng(0)
        return (params, opt,
                jax.device_put(jnp.asarray(
                    rng.standard_normal((batch, img, img, 3),
                                        dtype=np.float32).astype(np.float16)),
                    img_sh),
                jax.device_put(jnp.asarray(
                    rng.integers(0, 1000, batch, dtype=np.int32)), lbl_sh))

    return jitted, (params_abs, opt_abs, imgs, lbls), concrete


def main() -> None:
    import jax
    from repro.core.estimators import ProfilingEstimator, RooflineEstimator
    from repro.core.network import AllToAllNode
    from repro.core.pipeline import export_workload, predict
    from repro.core.systems import A100, host_system
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((4, 1), ("data", "model"))
    host = host_system()
    host_topo = AllToAllNode(num_devices=4,
                             link_bw=host.interconnect.link_bw)
    a100_topo = AllToAllNode(num_devices=4, link_bw=100e9)
    rows = []

    # host-validated (small batch / image so ground truth runs in seconds)
    for depth in (18, 50):
        jitted, abs_args, concrete = _build(depth, batch=8, img=64,
                                            mesh=mesh)
        with mesh:
            w = export_workload(jitted, *abs_args, name=f"resnet{depth}")
            measured = measure(jitted, concrete(jax.random.PRNGKey(0)),
                               runs=3)
        prog_opt = w.program("optimized")
        prog_raw = w.program("raw")
        p_ana = predict(prog_opt, RooflineEstimator(host), host_topo,
                        slicer="linear", name=f"resnet{depth}")
        prof = ProfilingEstimator(program=prog_raw, runs=3)
        p_prof = predict(prog_raw, prof, host_topo, slicer="linear",
                         name=f"resnet{depth}")
        prof_total = p_prof.step_time_s + p_ana.comm_s
        rows.append({
            "name": f"fig7-host-resnet{depth}",
            "us_per_call": measured * 1e6,
            "measured_ms": round(measured * 1e3, 1),
            "analytical_ms": round(p_ana.step_time_s * 1e3, 1),
            "profiling_ms": round(prof_total * 1e3, 1),
            "analytical_mape": round(mape(p_ana.step_time_s, measured), 1),
            "profiling_mape": round(mape(prof_total, measured), 1),
            "reference_bracketed":
                p_ana.step_time_s < measured < prof_total,
        })

    # full-scale A100 predictions (paper config: 256/device, fp16, 224px)
    for depth in (18, 34, 50, 101):
        jitted, abs_args, _ = _build(depth, batch=64, img=224, mesh=mesh)
        with mesh:
            w = export_workload(jitted, *abs_args, name=f"resnet{depth}")
        prog_opt = w.program("optimized")
        p_ana = predict(prog_opt, RooflineEstimator(A100), a100_topo,
                        slicer="linear", name=f"resnet{depth}")
        rows.append({
            "name": f"fig7-a100-resnet{depth}",
            "us_per_call": p_ana.step_time_s * 1e6,
            "analytical_ms": round(p_ana.step_time_s * 1e3, 2),
            "comm_ms": round(p_ana.comm_s * 1e3, 2),
            "segments": p_ana.num_segments,
        })
    emit(rows, "fig7_resnet")


if __name__ == "__main__":
    main()
