import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""Paper Fig 7: training-step latency for ResNet variants on a 4-GPU A100
system (data parallel).  Host-validated structural claims at reduced batch
(the estimator-ordering property is batch-independent), full-batch (256 per
device, FP16 — paper Table III) A100 predictions from the same export.

The A100 prediction sweep runs through ``repro.campaign`` from the
checked-in ``specs/fig7_resnet.json``: the campaign engine exports each
full ResNet train step (mode="train", mesh [4, 1]) via the same
``resnet_train_exports`` path the host-validated rows use, so campaign
predictions are bit-identical to the pre-port hand-rolled loop."""
from benchmarks.common import emit, mape, measure  # noqa: E402

SPEC = os.path.join(os.path.dirname(__file__), "..", "specs",
                    "fig7_resnet.json")


def _build(depth: int, batch: int, img: int, mesh, barriers: bool = False):
    """Shared-export wrapper: the abstract train step comes from
    ``resnet_train_exports`` (also the campaign engine's resnet path);
    only the concrete-arg builder for host measurement lives here."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.models.params import init_params
    from repro.models.resnet import (ResNetConfig, resnet_specs,
                                     resnet_train_exports)
    from repro.train.optimizer import OptimizerConfig, adamw_init

    cfg = ResNetConfig(depth=depth, block_barriers=barriers)
    specs = resnet_specs(cfg)
    opt_cfg = OptimizerConfig(name="adamw")
    jitted, abs_args = resnet_train_exports(cfg, batch, img, mesh)
    params_abs, _, imgs, lbls = abs_args

    def concrete(key):
        params = init_params(specs, key)
        params = jax.tree.map(lambda x, s: jax.device_put(x, s.sharding),
                              params, params_abs)
        opt = adamw_init(params, opt_cfg)
        rng = np.random.default_rng(0)
        return (params, opt,
                jax.device_put(jnp.asarray(
                    rng.standard_normal((batch, img, img, 3),
                                        dtype=np.float32).astype(np.float16)),
                    imgs.sharding),
                jax.device_put(jnp.asarray(
                    rng.integers(0, 1000, batch, dtype=np.int32)),
                    lbls.sharding))

    return jitted, abs_args, concrete


def main() -> None:
    import jax
    from repro import api
    from repro.core.estimators import ProfilingEstimator, RooflineEstimator
    from repro.core.network import AllToAllNode
    from repro.launch.mesh import make_mesh

    session = api.Session()
    mesh = make_mesh((4, 1), ("data", "model"))
    host = session.get_system("host")
    host_topo = AllToAllNode(num_devices=4,
                             link_bw=host.interconnect.link_bw)
    rows = []

    # host-validated (small batch / image so ground truth runs in seconds)
    for depth in (18, 50):
        jitted, abs_args, concrete = _build(depth, batch=8, img=64,
                                            mesh=mesh)
        with mesh:
            w = session.export(jitted, *abs_args, name=f"resnet{depth}")
            measured = measure(jitted, concrete(jax.random.PRNGKey(0)),
                               runs=3)
        plan_opt = session.plan(w, slicer="linear", fidelity="optimized")
        plan_raw = session.plan(w, slicer="linear", fidelity="raw")
        p_ana = session.predict(plan_opt, system=host,
                                estimator=RooflineEstimator(host),
                                topology=host_topo)
        prof = ProfilingEstimator(program=plan_raw.program, runs=3)
        p_prof = session.predict(plan_raw, system=host, estimator=prof,
                                 topology=host_topo)
        prof_total = p_prof.step_time_s + p_ana.comm_s
        rows.append({
            "name": f"fig7-host-resnet{depth}",
            "us_per_call": measured * 1e6,
            "measured_ms": round(measured * 1e3, 1),
            "analytical_ms": round(p_ana.step_time_s * 1e3, 1),
            "profiling_ms": round(prof_total * 1e3, 1),
            "analytical_mape": round(mape(p_ana.step_time_s, measured), 1),
            "profiling_mape": round(mape(prof_total, measured), 1),
            "reference_bracketed":
                p_ana.step_time_s < measured < prof_total,
        })

    # full-scale A100 predictions (paper config: 256/device, fp16, 224px)
    # — one campaign from the checked-in spec; the engine exports the
    # train steps itself (mode="train")
    res = session.campaign(SPEC, executor="serial")
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    for r in res.ok_rows:
        rows.append({
            "name": f"fig7-a100-{r['workload']}",
            "us_per_call": r["step_time_s"] * 1e6,
            "analytical_ms": round(r["step_time_s"] * 1e3, 2),
            "comm_ms": round(r["comm_s"] * 1e3, 2),
            "segments": r["num_segments"],
        })
    emit(rows, "fig7_resnet")


if __name__ == "__main__":
    main()
