"""Microbenchmarks of the framework's own machinery: parser throughput,
slicers, network scheduler, Pallas kernels (interpret mode), estimators."""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
import time

from benchmarks.common import build_llama_step, emit  # noqa: E402


def _time(fn, n=3) -> float:
    fn()
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    import jax
    import jax.numpy as jnp
    from repro import api
    from repro.core.estimators import RooflineEstimator, SystolicEstimator
    from repro.core.ir import parse, program_cost
    from repro.core.network import Torus, simulate
    from repro.core.pipeline import predict
    from repro.core.slicing import dependency_aware_split, linear_split
    from repro.launch.mesh import make_mesh

    session = api.Session()
    TPU_V5E = session.get_system("tpu-v5e")
    rows = []
    mesh = make_mesh((4, 1), ("data", "model"))
    cfg, jitted, abs_args, _ = build_llama_step(
        "llama3-100m", seq=512, batch=4, mesh=mesh, train=True)
    with mesh:
        w = session.export(jitted, *abs_args, name="llama3-100m")

    hlo = w.hlo_text
    t = _time(lambda: parse(hlo))
    rows.append({"name": "micro-parse-hlo", "us_per_call": t * 1e6,
                 "chars": len(hlo),
                 "mb_per_s": round(len(hlo) / t / 1e6, 1)})
    prog = parse(hlo)
    t = _time(lambda: program_cost(prog))
    rows.append({"name": "micro-program-cost", "us_per_call": t * 1e6,
                 "ops": prog.num_ops})
    t = _time(lambda: linear_split(prog))
    rows.append({"name": "micro-linear-split", "us_per_call": t * 1e6,
                 "segments": len(linear_split(prog))})
    t = _time(lambda: dependency_aware_split(prog))
    rows.append({"name": "micro-dep-split", "us_per_call": t * 1e6,
                 "segments": len(dependency_aware_split(prog)[0])})
    p = predict(prog, RooflineEstimator(TPU_V5E), Torus(dims=(2, 2)),
                slicer="dep", name="micro")
    t = _time(lambda: predict(prog, RooflineEstimator(TPU_V5E),
                              Torus(dims=(2, 2)), slicer="dep",
                              name="micro"))
    rows.append({"name": "micro-predict-e2e", "us_per_call": t * 1e6,
                 "segments": p.num_segments})

    # kernels (interpret mode on CPU — correctness-path timing only)
    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.rmsnorm.ops import rmsnorm
    q = jnp.ones((1, 4, 256, 64), jnp.float32)
    t = _time(lambda: flash_attention(q, q, q).block_until_ready())
    rows.append({"name": "micro-flashattn-interp", "us_per_call": t * 1e6,
                 "shape": "1x4x256x64"})
    x = jnp.ones((4, 512, 1024), jnp.bfloat16)
    wgt = jnp.ones((1024,), jnp.bfloat16)
    t = _time(lambda: rmsnorm(x, wgt).block_until_ready())
    rows.append({"name": "micro-rmsnorm-interp", "us_per_call": t * 1e6,
                 "shape": "4x512x1024"})

    # systolic estimator throughput
    est = SystolicEstimator(TPU_V5E, "cocossim")
    t = _time(lambda: [est.gemm_latency(2048, 2048, 2048)
                       for _ in range(100)])
    rows.append({"name": "micro-systolic-100gemms", "us_per_call": t * 1e6,
                 "per_gemm_us": round(t / 100 * 1e6, 1)})
    emit(rows, "micro_bench")


if __name__ == "__main__":
    main()
