"""Shared benchmark helpers: workload export, host ground truth, CSV."""
from __future__ import annotations

import csv
import os
import statistics
import time

# repro resolves from the installed package (pip install -e .) or
# PYTHONPATH=src — benchmark scripts carry no sys.path edits; run them
# as modules from the repo root: `python -m benchmarks.run [figure...]`

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts",
                         "bench")


def emit(rows: list[dict], name: str) -> None:
    """Write CSV artifact + print `name,us_per_call,derived` lines."""
    os.makedirs(ARTIFACTS, exist_ok=True)
    path = os.path.join(ARTIFACTS, f"{name}.csv")
    if rows:
        fields: list[str] = []
        for r in rows:
            for k in r:
                if k not in fields:
                    fields.append(k)
        with open(path, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=fields)
            w.writeheader()
            w.writerows(rows)
    for r in rows:
        us = r.get("us_per_call", r.get("predicted_us", ""))
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r.get('name', name)},{us},{derived}")


def build_llama_step(arch: str, seq: int, batch: int, mesh,
                     train: bool = True, cfg_overrides: dict | None = None):
    """jitted train step + abstract args + concrete args for an LM arch."""
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ShapeConfig
    from repro.distributed.sharding import ShardingRules
    from repro.models import get_config, input_specs, model_specs
    from repro.models.params import abstract_params, init_params
    from repro.train.loop import train_step_exports
    from repro.train.optimizer import OptimizerConfig, make_optimizer

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.scaled(**cfg_overrides)
    rules = ShardingRules()
    specs = model_specs(cfg)
    shape = ShapeConfig("bench", seq, batch, "train" if train else "prefill")
    if train:
        # single source of the full-train-step export, shared with the
        # campaign engine's mode="train" spec path (repro.train.loop)
        opt_cfg = OptimizerConfig()
        init_fn, _ = make_optimizer(opt_cfg)
        jitted, (params_abs, opt_abs, batch_abs) = train_step_exports(
            cfg, seq, batch, mesh, rules=rules, opt_cfg=opt_cfg)

        def concrete(key):
            params = init_params(specs, key)
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, s.sharding),
                params, params_abs)
            opt = init_fn(params, opt_cfg)
            import numpy as np
            rng = np.random.default_rng(0)
            b = {"tokens": jnp.asarray(rng.integers(
                    0, cfg.vocab_size, (batch, seq), dtype="int32")),
                 "targets": jnp.asarray(rng.integers(
                    0, cfg.vocab_size, (batch, seq), dtype="int32"))}
            b = {k: jax.device_put(v, batch_abs[k].sharding)
                 for k, v in b.items()}
            return params, opt, b

        return cfg, jitted, (params_abs, opt_abs, batch_abs), concrete
    params_abs = abstract_params(specs, mesh, rules)
    batch_abs = input_specs(cfg, shape, mesh, rules)
    from repro.models.transformer import prefill
    fn = jax.jit(lambda p, b: prefill(cfg, p, b))
    return cfg, fn, (params_abs, batch_abs), None


def measure(fn, args, runs: int = 3) -> float:
    """Median wall seconds of a jitted call (post-warmup)."""
    import jax
    out = fn(*args)
    jax.tree.map(lambda x: x.block_until_ready()
                 if hasattr(x, "block_until_ready") else x, out)
    args = _rotate_donated(fn, args, out)
    times = []
    for _ in range(runs):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
        times.append(time.perf_counter() - t0)
        args = _rotate_donated(fn, args, out)
    return statistics.median(times)


def _rotate_donated(fn, args, out):
    """If the jitted fn donates (params, opt), reuse outputs as next inputs."""
    if isinstance(out, tuple) and len(out) == 3 and isinstance(args, tuple):
        if len(args) == 3:
            return (out[0], out[1], args[2])
        if len(args) == 4:                 # resnet: (params, opt, imgs, lbls)
            return (out[0], out[1], args[2], args[3])
    return args


def mape(pred: float, ref: float) -> float:
    return abs(pred - ref) / ref * 100.0
