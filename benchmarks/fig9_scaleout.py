import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=128")

"""Paper Fig 9: Llama-2 7B data-parallel scale-out on 16 -> 128 GH200 GPUs
(4-GPU NVLink nodes in a dragonfly fabric, ATLAHS configuration).

Reproduced claims: (i) both estimator classes predict the communication
fraction growing with scale; (ii) per-GPU step time rises from 16 to 128
GPUs for fixed per-device batch (collective cost grows with ring size
across the dragonfly); (iii) the analytical estimator stays stable while
profiling-projection diverges with deeper communication hierarchies.

The sweep runs through ``repro.campaign`` from the checked-in
``specs/fig9_scaleout.json``: each scale pairs its own workload (batch
2/GPU at 16 GPUs, 1/GPU at 128; per-workload mesh) with its own dragonfly
fabric via the spec's ``zip`` group — the paired-axis grid a plain cross
product cannot express.  Workload export uses the same
``train_step_exports`` path the pre-port loop used, so predictions are
bit-identical to the hand-rolled version (locked by the parity test in
``tests/test_report.py``)."""
from benchmarks.common import emit

SPEC = os.path.join(os.path.dirname(__file__), "..", "specs",
                    "fig9_scaleout.json")


def main() -> None:
    from repro import api

    session = api.Session()
    spec = api.load_spec(SPEC)
    res = session.campaign(spec, executor="thread")
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    idx = {(r["workload"], r["estimator"]): r for r in res.ok_rows}

    rows = []
    for w in spec.workloads:
        n_gpus = w.mesh[0]
        p_ana = idx[(w.name, "roofline")]
        p_prof = idx[(w.name, "roofline-per-op-ovh@raw")]
        prof_total = p_prof["step_time_s"] + p_ana["comm_s"]
        rows.append({
            "name": f"fig9-{n_gpus}gpu",
            "us_per_call": p_ana["step_time_s"] * 1e6,
            "analytical_ms": round(p_ana["step_time_s"] * 1e3, 1),
            "profiling_ms": round(prof_total * 1e3, 1),
            "comm_ms": round(p_ana["comm_s"] * 1e3, 1),
            "comm_fraction": round(p_ana["comm_s"]
                                   / max(p_ana["step_time_s"], 1e-12), 3),
            "num_comm_nodes": p_ana["num_comm"],
        })
    # derived claim check: comm fraction grows with scale
    rows.append({
        "name": "fig9-claim-comm-grows",
        "us_per_call": "",
        "holds": rows[1]["comm_fraction"] > rows[0]["comm_fraction"],
    })
    emit(rows, "fig9_scaleout")


if __name__ == "__main__":
    main()
