import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=128")

"""Paper Fig 9: Llama-2 7B data-parallel scale-out on 16 -> 128 GH200 GPUs
(4-GPU NVLink nodes in a dragonfly fabric, ATLAHS configuration).

Reproduced claims: (i) both estimator classes predict the communication
fraction growing with scale; (ii) per-GPU step time rises from 16 to 128
GPUs for fixed per-device batch (collective cost grows with ring size
across the dragonfly); (iii) the analytical estimator stays stable while
profiling-projection diverges with deeper communication hierarchies."""
import sys

sys.path.insert(0, os.path.dirname(__file__) + "/..")
from benchmarks.common import build_llama_step, emit  # noqa: E402


def main() -> None:
    from repro.campaign import (CampaignSpec, EstimatorSpec, TopologySpec,
                                WorkloadSpec, run_campaign)
    from repro.core.pipeline import export_workload
    from repro.launch.mesh import make_mesh

    rows = []
    # paper: batch 2/GPU at 16 GPUs, 1/GPU at 128 GPUs.  Each scale has
    # its own workload AND its own fabric, so each is a 1-point-per-
    # estimator campaign (profiling-class = per-op costing of the raw
    # export with launch overheads — see fig6 for the rationale).
    for n_gpus, per_dev_batch, nodes_per_router, routers, groups in [
            (16, 2, 1, 2, 2), (128, 1, 4, 4, 2)]:
        mesh = make_mesh((n_gpus, 1), ("data", "model"))
        cfg, jitted, abs_args, _ = build_llama_step(
            "llama2-7b", seq=2048, batch=n_gpus * per_dev_batch, mesh=mesh,
            train=True)
        name = f"llama2-{n_gpus}"
        with mesh:
            w = export_workload(jitted, *abs_args, name=name)
        spec = CampaignSpec(
            name=f"fig9-{n_gpus}",
            workloads=[WorkloadSpec(name=name)],
            systems=["gh200"],
            estimators=[
                EstimatorSpec.from_dict({"kind": "roofline"}),
                EstimatorSpec.from_dict(
                    {"kind": "roofline", "fidelity": "raw",
                     "options": {"mode": "per-op",
                                 "include_overheads": True}}),
            ],
            slicers=["linear"],
            topologies=[TopologySpec.from_dict({"kind": "dragonfly", "params": {
                "num_nodes": n_gpus // 4, "gpus_per_node": 4,
                "nodes_per_router": nodes_per_router,
                "routers_per_group": routers, "groups": groups,
                "intra_bw": 150e9, "inter_bw": 25e9}})],
        )
        res = run_campaign(spec, workloads={name: w}, executor="thread")
        idx = {r["estimator"]: r for r in res.ok_rows}
        p_ana = idx["roofline"]
        p_prof = idx["roofline-per-op-ovh@raw"]
        prof_total = p_prof["step_time_s"] + p_ana["comm_s"]
        rows.append({
            "name": f"fig9-{n_gpus}gpu",
            "us_per_call": p_ana["step_time_s"] * 1e6,
            "analytical_ms": round(p_ana["step_time_s"] * 1e3, 1),
            "profiling_ms": round(prof_total * 1e3, 1),
            "comm_ms": round(p_ana["comm_s"] * 1e3, 1),
            "comm_fraction": round(p_ana["comm_s"]
                                   / max(p_ana["step_time_s"], 1e-12), 3),
            "num_comm_nodes": p_ana["num_comm"],
        })
    # derived claim check: comm fraction grows with scale
    rows.append({
        "name": "fig9-claim-comm-grows",
        "us_per_call": "",
        "holds": rows[1]["comm_fraction"] > rows[0]["comm_fraction"],
    })
    emit(rows, "fig9_scaleout")


if __name__ == "__main__":
    main()
