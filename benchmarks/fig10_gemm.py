"""Paper Fig 10: operator-level GEMM benchmarking (square M=N=K) across
four systolic-array simulators configured as a TPUv3 core with two MXUs.

Reproduced claim: detailed-but-differently-calibrated simulators of the
same hardware spread widely at small sizes and converge (or don't) at
large GEMMs — ONNXim/COCOSSim-class models (double-buffered, fill-
amortized) track the bandwidth/compute roofline envelope within ~20 %,
while SCALE-Sim-class (serial tile loads) and ZigZag-class (compute-only)
presets deviate substantially — matching the paper's observed ranking.

The TPUv3 'reference' is the machine-balance envelope
max(2·M·N·K / peak_flops, bytes / bw) with the xprof-measured sustained
efficiency of large GEMMs on TPUv3 (~0.87 of peak, public xprof guidance),
since real hardware is unavailable offline.

The sweep itself runs through ``repro.campaign`` from the checked-in
``specs/fig10_gemm.json`` (synthesized single-dot_general StableHLO
workloads × four systolic presets); this script only derives the
reference and error columns from the campaign rows.  Per-preset
latencies are identical to the previous hand-rolled
``SystolicEstimator.gemm_latency`` loop at the emitted precision."""
import os

from benchmarks.common import emit

SPEC = os.path.join(os.path.dirname(__file__), "..", "specs",
                    "fig10_gemm.json")


def main() -> None:
    from repro import api
    from repro.core.estimators import PRESETS

    session = api.Session()
    TPU_V3_CORE = session.get_system("tpu-v3")
    spec = api.load_spec(SPEC)
    res = session.campaign(spec, executor="serial")
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    lat = {(r["workload"], r["estimator"]): r["step_time_s"]
           for r in res.ok_rows}

    rows = []
    sizes = [w.gemm["m"] for w in spec.workloads]
    for n in sizes:
        flops = 2.0 * n * n * n
        bytes_ = 3 * n * n * 2  # bf16
        ref = max(flops / (TPU_V3_CORE.flops_for("bf16") * 0.87),
                  bytes_ / TPU_V3_CORE.mem_bw) + 2e-6
        row = {"name": f"fig10-gemm-{n}", "us_per_call": ref * 1e6,
               "reference_us": round(ref * 1e6, 1)}
        for name in PRESETS:
            t = lat[(f"gemm-{n}", f"systolic-{name}")]
            row[f"{name}_us"] = round(t * 1e6, 1)
            row[f"{name}_err_pct"] = round(abs(t - ref) / ref * 100, 1)
        rows.append(row)
    # aggregate MAPE per simulator over large GEMMs (n >= 1024), as the
    # paper reports trends "for large GEMMs"
    gemm_rows = [r for r in rows if r["name"].startswith("fig10-gemm-")]
    for name in PRESETS:
        errs = [r[f"{name}_err_pct"] for r in gemm_rows
                if int(r["name"].split("-")[-1]) >= 1024]
        rows.append({"name": f"fig10-mape-{name}", "us_per_call": "",
                     "large_gemm_mape": round(sum(errs) / len(errs), 1)})
    emit(rows, "fig10_gemm")


if __name__ == "__main__":
    main()
