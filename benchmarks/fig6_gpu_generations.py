import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

"""Paper Fig 6 + Table V: Llama-3 training-step latency on a 4-GPU node
across A100/H100/H200/B200 — analytical vs profiling estimators.

Ground truth: the paper measures real GPUs.  Offline, the methodology's
*structural* claims are validated on the one real platform available (the
host CPU, 4 XLA devices, FSDP over the data axis):

  claim 1 — analytical roofline is optimistic (pred < measured);
  claim 2 — profiling (region-isolated) is pessimistic (pred > measured);
  claim 3 — reference falls between the two estimator classes.

For the A100→B200 systems we reproduce the paper's *predictions* using its
Table IV constants and report Table-V-style speedup matrices for both
estimator classes (speedup error is computed against the roofline-balance
reference, since real-GPU measurements are unavailable offline).
"""
from benchmarks.common import build_llama_step, emit, mape, measure  # noqa: E402

SPEC = os.path.join(os.path.dirname(__file__), "..", "specs",
                    "fig6_gpu.json")


def main() -> None:
    import jax
    from repro import api
    from repro.core.estimators import ProfilingEstimator, RooflineEstimator
    from repro.core.network import AllToAllNode
    from repro.launch.mesh import make_mesh

    session = api.Session()
    host = session.get_system("host")
    rows = []

    # ---------------- host-validated structural claims ----------------
    # single device: multi-device emulation on one CPU core serializes
    # device work and turns FSDP all-gathers into giant memcpys, which
    # would confound the estimator-ordering claim being validated here
    host_topo = AllToAllNode(num_devices=1,
                             link_bw=host.interconnect.link_bw,
                             link_latency=2e-6)
    mesh1 = make_mesh((1, 1), ("data", "model"))
    # 4 layers instead of the arch's 12: host-CPU measurement of the
    # full-size step is ~26-36 s/step and the estimator-ordering outcome
    # is identical — the per-layer GEMM shapes (what the estimators
    # actually cost) are unchanged, only the layer count shrinks
    for arch, seq, batch, layers in [("llama3-100m", 256, 2, 4)]:
        cfg, jitted, abs_args, concrete = build_llama_step(
            arch, seq, batch, mesh1, train=True,
            cfg_overrides={"scan_layers": False, "layer_barriers": True,
                           "remat": "none", "num_layers": layers})
        with mesh1:
            w = session.export(jitted, *abs_args, name=arch)
            measured = measure(jitted, concrete(jax.random.PRNGKey(0)),
                               runs=2)
        plan_opt = session.plan(w, slicer="linear", fidelity="optimized")
        plan_raw = session.plan(w, slicer="linear", fidelity="raw")
        p_ana = session.predict(plan_opt, system=host,
                                estimator=RooflineEstimator(host),
                                topology=host_topo)
        prof = ProfilingEstimator(program=plan_raw.program, runs=3)
        p_prof = session.predict(plan_raw, system=host, estimator=prof,
                                 topology=host_topo)
        # profiling measures the whole-step region; add the measured
        # collective exposure from the optimized program's netsim pass
        prof_total = p_prof.step_time_s + p_ana.comm_s
        rows.append({
            "name": f"fig6-host-{arch}", "us_per_call": measured * 1e6,
            "measured_ms": round(measured * 1e3, 2),
            "analytical_ms": round(p_ana.step_time_s * 1e3, 2),
            "profiling_ms": round(prof_total * 1e3, 2),
            "analytical_mape": round(mape(p_ana.step_time_s, measured), 1),
            "profiling_mape": round(mape(prof_total, measured), 1),
            "analytical_optimistic": p_ana.step_time_s < measured,
            "profiling_pessimistic": prof_total > measured,
            "reference_bracketed": p_ana.step_time_s < measured < prof_total,
        })

    # ---------------- paper-system predictions (A100..B200) -----------
    # one campaign from the checked-in spec (the same grid the
    # paper_full suite runs): 3 train-step workloads × 4 systems × 2
    # estimator classes.  The engine exports the train steps itself via
    # the shared train_step_exports path.  The profiling-CLASS estimator
    # at prediction scale is per-operator costing of the RAW
    # (pre-fusion) export plus per-kernel launch overheads — the same
    # pessimism mechanism as real profiling (compiler scope truncated at
    # region boundaries), without needing the target GPU.
    # Execution-based profiling is used in the host-validated rows above.
    spec = api.load_spec(SPEC)
    gens = list(spec.systems)
    archs = [w.name for w in spec.workloads]
    res = session.campaign(spec, executor="thread")
    idx = {(r["workload"], r["system"], r["estimator"]): r
           for r in res.ok_rows}
    preds: dict[str, dict[str, float]] = {g: {} for g in gens}
    for arch in archs:
        for gen in gens:
            p_ana = idx[(arch, gen, "roofline")]
            p_prof = idx[(arch, gen, "roofline-per-op-ovh@raw")]
            preds[gen][f"{arch}-ana"] = p_ana["step_time_s"]
            preds[gen][f"{arch}-prof"] = p_prof["step_time_s"]
            rows.append({
                "name": f"fig6-{gen}-{arch}",
                "us_per_call": p_ana["step_time_s"] * 1e6,
                "analytical_ms": round(p_ana["step_time_s"] * 1e3, 3),
                "profiling_ms": round(p_prof["step_time_s"] * 1e3, 3),
                "sim_wall_analytical_s": round(
                    p_ana["simulation_wall_s"], 2),
                "sim_wall_profiling_s": round(
                    p_prof["simulation_wall_s"], 2),
            })

    # ---------------- Table V: cross-generation speedups --------------
    for kind in ("ana", "prof"):
        for a, b in zip(gens[:-1], gens[1:]):
            sp = []
            for arch in ("llama3-100m", "llama3-500m", "llama3-1b"):
                sp.append(preds[a][f"{arch}-{kind}"]
                          / preds[b][f"{arch}-{kind}"])
            rows.append({
                "name": f"tableV-{kind}-{a}->{b}",
                "us_per_call": "",
                "mean_speedup": round(sum(sp) / len(sp), 3),
            })
    emit(rows, "fig6_gpu_generations")


if __name__ == "__main__":
    main()
