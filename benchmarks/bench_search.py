"""What-if search performance: the fidelity ladder vs brute force.

Runs both checked-in search specs three ways through one process —
cold ladder, warm ladder (same Session), and top-rung brute force —
and records what the optimizer machinery actually saves:

  * pruning economy — candidates expanded, pruned at the cheap tier
    (ceiling + intra-group + ε-dominated), refined at the top rung;
    the ladder must reach the top rung for well under half the grid
    while its frontier stays *identical* to brute force;
  * cache reuse — a warm re-search through the same session pays zero
    cold misses (plans and (H, C, R) entries are all resident);
  * wall clock — ladder vs brute-force time at the top fidelity
    (reported, never gated).

Emits ``BENCH_search.json`` at the repo root (the perf-trajectory
artifact; ``tools/bench_check.py`` gates its deterministic counters —
never the wall-clock numbers) plus the usual CSV under
``artifacts/bench/``.
"""
import json
import os
import time

from benchmarks.common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SPECS = {
    "gemm": os.path.join(REPO, "specs", "search_gemm.json"),
    "serving": os.path.join(REPO, "specs", "search_serving.json"),
}


def _run_spec(path: str) -> dict:
    from repro import api

    session = api.Session()
    t0 = time.perf_counter()
    ladder = session.search(path)
    ladder_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    warm = session.search(path)
    warm_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    brute = api.Session().search(path, brute_force=True)
    brute_s = time.perf_counter() - t0

    c = ladder.counters
    return {
        "candidates": c["candidates"],
        "infeasible": c["infeasible"],
        "anchors": c["anchors"],
        "pruned_cheap_tier": (c["pruned_ceiling"] + c["pruned_intra"]
                              + c["pruned_dominated"]),
        "top_rung_evaluations": c["top_rung_evaluations"],
        "top_rung_fraction": c["top_rung_fraction"],
        "frontier_size": c["frontier_size"],
        "frontier_matches_brute_force": int(
            ladder.frontier == brute.frontier),
        "brute_force_top_rung_evaluations":
            brute.counters["top_rung_evaluations"],
        "warm_rerun_cache_misses": warm.counters["cache_misses"],
        "ladder_s": round(ladder_s, 4),
        "warm_rerun_s": round(warm_s, 4),
        "brute_force_s": round(brute_s, 4),
    }


def main() -> None:
    report = {"bench": "search"}
    rows = []
    for name, path in sorted(SPECS.items()):
        r = _run_spec(path)
        report[name] = r
        rows.append({"name": f"search-{name}", "us_per_call": "",
                     **{k: v for k, v in r.items()}})

    path = os.path.join(REPO, "BENCH_search.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")
    emit(rows, "bench_search")

    # the ISSUE's acceptance bar + the invariants the gate relies on
    for name in SPECS:
        r = report[name]
        assert r["frontier_matches_brute_force"] == 1, report
        assert r["top_rung_fraction"] < 0.5, report
        assert r["warm_rerun_cache_misses"] == 0, report


if __name__ == "__main__":
    main()
