import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Paper Fig 11: Llama-3 training step on an 8-core TPUv3 slice, comparing
the analytical TPU estimator and the systolic-array (COCOSSim-class)
estimator through the same Compute API on one StableHLO export.

Reproduced claims: (i) one workload representation drives heterogeneous
estimators unmodified (mixed estimator: systolic for GEMM regions,
analytical fallback elsewhere — the paper pairs COCOSSim with an
analytical model the same way); (ii) the analytical estimator is orders of
magnitude cheaper to run (paper: 6.4 s vs 826 s mean) — we report both
wall times; (iii) predictions track model size monotonically."""
import sys

sys.path.insert(0, os.path.dirname(__file__) + "/..")
from benchmarks.common import build_llama_step, emit  # noqa: E402


def main() -> None:
    from repro.core.estimators import (MixedEstimator, RooflineEstimator,
                                       SystolicEstimator)
    from repro.core.network import Torus
    from repro.core.pipeline import export_workload, predict
    from repro.core.systems import TPU_V3_CORE
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((8, 1), ("data", "model"))
    topo = Torus(dims=(4, 2), link_bw=70e9)
    rows = []
    for arch in ("llama3-100m", "llama3-500m", "llama3-1b", "llama3-3b"):
        cfg, jitted, abs_args, _ = build_llama_step(
            arch, seq=2048, batch=8, mesh=mesh, train=True)
        with mesh:
            w = export_workload(jitted, *abs_args, name=arch)
        prog = w.program("optimized")
        p_ana = predict(prog, RooflineEstimator(TPU_V3_CORE), topo,
                        slicer="linear", name=arch)
        cocos = MixedEstimator(SystolicEstimator(TPU_V3_CORE, "cocossim"),
                               RooflineEstimator(TPU_V3_CORE))
        p_sys = predict(prog, cocos, topo, slicer="linear", name=arch)
        rows.append({
            "name": f"fig11-{arch}",
            "us_per_call": p_ana.step_time_s * 1e6,
            "analytical_ms": round(p_ana.step_time_s * 1e3, 2),
            "cocossim_ms": round(p_sys.step_time_s * 1e3, 2),
            "analytical_wall_s": round(p_ana.simulation_wall_s, 3),
            "cocossim_wall_s": round(p_sys.simulation_wall_s, 3),
            "systolic_pessimistic_vs_analytical":
                p_sys.step_time_s >= p_ana.step_time_s,
        })
    # monotonicity claim across model sizes
    ana = [r["analytical_ms"] for r in rows]
    rows.append({"name": "fig11-claim-monotone", "us_per_call": "",
                 "holds": all(a < b for a, b in zip(ana, ana[1:]))})
    emit(rows, "fig11_tpu")


if __name__ == "__main__":
    main()
