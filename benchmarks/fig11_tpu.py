import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

"""Paper Fig 11: Llama-3 training step on an 8-core TPUv3 slice, comparing
the analytical TPU estimator and the systolic-array (COCOSSim-class)
estimator through the same Compute API on one StableHLO export.

Reproduced claims: (i) one workload representation drives heterogeneous
estimators unmodified (mixed estimator: systolic for GEMM regions,
analytical fallback elsewhere — the paper pairs COCOSSim with an
analytical model the same way); (ii) the analytical estimator is orders of
magnitude cheaper to run (paper: 6.4 s vs 826 s mean) — we report both
wall times; (iii) predictions track model size monotonically.

The sweep runs through ``repro.campaign`` from the checked-in
``specs/fig11_tpu.json``: the campaign engine itself exports each
full train step (mode="train", mesh [8, 1]) via the same
``train_step_exports`` path the pre-port loop used, so predictions are
bit-identical to the hand-rolled version."""
from benchmarks.common import emit  # noqa: E402

SPEC = os.path.join(os.path.dirname(__file__), "..", "specs",
                    "fig11_tpu.json")


def main() -> None:
    from repro import api

    session = api.Session()
    spec = api.load_spec(SPEC)
    res = session.campaign(spec, executor="serial")
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    idx = {(r["workload"], r["estimator"]): r for r in res.ok_rows}

    rows = []
    for arch in [w.name for w in spec.workloads]:
        p_ana = idx[(arch, "roofline")]
        p_sys = idx[(arch, "mixed-cocossim")]
        rows.append({
            "name": f"fig11-{arch}",
            "us_per_call": p_ana["step_time_s"] * 1e6,
            "analytical_ms": round(p_ana["step_time_s"] * 1e3, 2),
            "cocossim_ms": round(p_sys["step_time_s"] * 1e3, 2),
            "analytical_wall_s": round(p_ana["simulation_wall_s"], 3),
            "cocossim_wall_s": round(p_sys["simulation_wall_s"], 3),
            "systolic_pessimistic_vs_analytical":
                p_sys["step_time_s"] >= p_ana["step_time_s"],
        })
    # monotonicity claim across model sizes
    ana = [r["analytical_ms"] for r in rows]
    rows.append({"name": "fig11-claim-monotone", "us_per_call": "",
                 "holds": all(a < b for a, b in zip(ana, ana[1:]))})
    emit(rows, "fig11_tpu")


if __name__ == "__main__":
    main()
