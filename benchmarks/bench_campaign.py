"""Campaign-engine performance: plan reuse, batched cache ops, scheduling.

Runs a synthetic GEMM grid (no jax needed) through the campaign engine
under all three executors and measures the per-*workload* costs the plan
phase amortizes:

  * wall time per executor;
  * parse/slice calls vs the per-job baseline (pre-plan engines pay one
    parse + one slice per grid point; the plan store pays one per
    ``(workload, fidelity)`` / per plan key);
  * persistent-cache flock round-trips, batched (one ``put_many`` per
    evaluate phase) vs per-region (one append per miss + tail-reads);
  * duplicate cold misses under parallel executors (the locality
    schedule's leader-first chains must make these zero);
  * cold-parse wall of the streaming single-pass front end vs the legacy
    multi-pass regex parser on a realistic sharded training stack
    (nested while loops, sharding annotations, all-reduce region blocks
    — the shapes real jax exports take), with the deterministic
    passes-per-parse counter;
  * warm-evaluate wall of the vectorized ``evaluate_batch`` grid pass vs
    the per-region scalar loop, values asserted identical;
  * offset-index point lookups: a warm hit must touch zero log bytes.

Emits ``BENCH_campaign.json`` at the repo root (the perf-trajectory
artifact) plus the usual CSV under ``artifacts/bench/``.
"""
import json
import os
import tempfile
import time

from benchmarks.common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: stacked-GEMM workload: distinct shapes -> distinct fingerprints, one
#: region per GEMM under the linear slicer.  200 + 48·i is deliberately
#: disjoint from the single-GEMM grid sizes (512/1024/2048/4096): a
#: shared fingerprint would put two *different* locality chains in a
#: race for the same cache key, making the zero-duplicate-cold-miss
#: assertion below timing-dependent.
STACK_SHAPES = [(200 + 48 * i, 200 + 48 * i, 200 + 48 * i)
                for i in range(24)]


def _grid_spec():
    from repro.campaign import CampaignSpec
    workloads = [{"name": f"gemm-{n}", "fidelity": "raw",
                  "gemm": {"m": n, "n": n, "k": n, "dtype": "bf16"}}
                 for n in (512, 1024, 2048, 4096)]
    workloads.append({"name": "gemm-stack", "fidelity": "raw",
                      "stablehlo_path": "in-memory"})
    return CampaignSpec.from_dict({
        "name": "bench-campaign",
        "workloads": workloads,
        "systems": ["a100", "h100", "b200", "tpu-v3"],
        "estimators": [{"kind": "roofline"},
                       {"kind": "roofline", "options": {"mode": "per-op"}}],
        "slicers": ["linear", "dep"],
        "topologies": [{"kind": "a2a", "params": {"num_devices": 4}}],
    })


def _run_grid(executor: str, workloads: dict) -> dict:
    from repro import api
    session = api.Session()
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        res = session.campaign(_grid_spec(), workloads=workloads,
                               executor=executor, max_workers=4,
                               cache_path=os.path.join(d, "hcr.jsonl"))
        wall = time.perf_counter() - t0
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    return {
        "wall_s": round(wall, 4),
        "jobs": res.plans["jobs"],
        "plan_keys": res.plans["plan_keys"],
        "parse_calls": res.plans["parse_calls"],
        "plans_built": res.plans["plans_built"],
        "cache_hits": res.cache["hits"],
        "cache_misses": res.cache["misses"],
        "lock_roundtrips": res.cache["lock_roundtrips"],
    }


def _cache_op_comparison(workloads: dict) -> dict:
    """Per-region vs batched store traffic for one multi-region workload
    evaluated over several systems (fresh path-backed store each)."""
    from repro.campaign.builders import build_estimator, build_topology
    from repro.campaign.spec import EstimatorSpec, TopologySpec
    from repro.core.estimators.cache import PersistentCache
    from repro.core.pipeline import PredictionJob, build_plan
    from repro.core.systems import get_system

    program = workloads["gemm-stack"].program("raw")
    plan = build_plan(program, slicer="linear", name="gemm-stack")
    out = {"regions": len(plan.compute_regions),
           "fingerprints": len(plan.fingerprints)}
    for batched in (False, True):
        with tempfile.TemporaryDirectory() as d:
            store = PersistentCache(os.path.join(d, "hcr.jsonl"))
            t0 = time.perf_counter()
            for sysname in ("a100", "h100", "b200", "tpu-v3"):
                system = get_system(sysname)
                est = build_estimator(EstimatorSpec(), system)
                topo = build_topology(
                    TopologySpec("a2a", (("num_devices", 4),)), system)
                PredictionJob(estimator=est, topology=topo, plan=plan,
                              name="gemm-stack", cache_store=store,
                              batch_cache=batched).run()
            key = "batched" if batched else "per_region"
            out[f"{key}_lock_roundtrips"] = store.lock_roundtrips
            out[f"{key}_wall_s"] = round(time.perf_counter() - t0, 4)
    out["lock_roundtrip_ratio"] = round(
        out["per_region_lock_roundtrips"]
        / max(out["batched_lock_roundtrips"], 1), 1)
    return out


def _min_wall(fn, repeats: int = 7) -> float:
    """Min-of-k wall time: the least noisy point estimate for short runs."""
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def _front_end_comparison() -> dict:
    """Cold-parse wall: legacy multi-pass regex front end vs the
    streaming single-pass tokenizer, on equal programs through both.

    The headline workload is the sharded training stack — two nested
    while loops (steps x microbatches), quoted ``mhlo.sharding``
    annotations, and multi-line all-reduce region blocks, i.e. the line
    forms that make the legacy parser re-scan (per-nesting-level
    interior re-parses, per-char quote balancing, ungated replica-group
    searches).  The plain GEMM stack is reported alongside as the
    no-nesting floor.  Differential equality of the two front ends is
    pinned by tests/test_parser_diff.py; here we assert only the cheap
    structural invariants so a silently wrong speedup can't ship."""
    from repro.campaign.builders import (synthesize_gemm_stack,
                                         synthesize_sharded_stack)
    from repro.core.ir import tokenize
    from repro.core.ir.parser import parse_stablehlo

    shapes = [(256 * (1 + i % 4), 256 * (1 + (i // 4) % 4), 512)
              for i in range(24)]
    texts = {
        "sharded_train_stack": synthesize_sharded_stack(
            shapes, groups=8, steps=4, microbatches=4),
        "gemm_stack": synthesize_gemm_stack(STACK_SHAPES),
    }
    out = {}
    for name, text in texts.items():
        walls = {fe: _min_wall(lambda fe=fe: parse_stablehlo(text,
                                                             frontend=fe))
                 for fe in ("legacy", "streaming")}
        legacy = parse_stablehlo(text, frontend="legacy")
        before = tokenize.TOKENIZER_PASSES
        streaming = parse_stablehlo(text, frontend="streaming")
        passes = tokenize.TOKENIZER_PASSES - before
        assert len(list(legacy.walk())) == len(list(streaming.walk()))
        out[name] = {
            "legacy_wall_s": round(walls["legacy"], 5),
            "streaming_wall_s": round(walls["streaming"], 5),
            "parse_ratio": round(walls["legacy"] / walls["streaming"], 1),
            "ops": len(list(streaming.walk())),
        }
        if name == "sharded_train_stack":
            out["tokenizer_passes_per_parse"] = passes
    return out


def _evaluate_comparison() -> dict:
    """Warm-evaluate wall: the vectorized ``evaluate_batch`` pass over a
    campaign grid's precomputed ``RegionArrays`` vs the per-region
    scalar loop, in both roofline modes — values must be identical (the
    bit-identity tests/test_campaign_diff.py locks end to end)."""
    from repro.campaign.builders import synthesize_gemm_stack
    from repro.core.estimators.analytical import RooflineEstimator
    from repro.core.ir.parser import parse
    from repro.core.pipeline import build_plan
    from repro.core.systems import get_system

    shapes = [(64 + 8 * (i % 40), 64 + 8 * ((i * 7) % 40), 256)
              for i in range(400)]
    plan = build_plan(parse(synthesize_gemm_stack(shapes)),
                      slicer="linear", name="eval-grid")
    regions, arrays = plan.compute_regions, plan.arrays
    out = {"regions": len(regions)}
    for mode in ("region", "per-op"):
        est = RooflineEstimator(get_system("a100"), mode=mode,
                                include_overheads=True)
        scalar_wall = _min_wall(
            lambda: [est.get_run_time_estimate(r) for r in regions])
        vector_wall = _min_wall(lambda: est.evaluate_batch(arrays))
        assert [est.get_run_time_estimate(r) for r in regions] \
            == est.evaluate_batch(arrays)
        key = mode.replace("-", "_")
        out[key] = {
            "scalar_wall_s": round(scalar_wall, 5),
            "vector_wall_s": round(vector_wall, 5),
            "evaluate_ratio": round(scalar_wall / vector_wall, 1),
        }
    return out


def _cache_index_counters() -> dict:
    """Deterministic I/O counters of the offset-index store: a warm hit
    must read zero log bytes and take zero locks; a lazy process
    resolving K keys from a large shared store does K point reads."""
    from repro.core.estimators.cache import PersistentCache

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "hcr.jsonl")
        PersistentCache(path).put_many(
            {f"k{i}": (float(i), 0.001) for i in range(500)})
        log_bytes = os.path.getsize(path)

        warm = PersistentCache(path)
        warm.scan_bytes = 0
        locks = warm.lock_roundtrips
        warm.get_many([f"k{i}" for i in range(500)])
        out = {
            "log_bytes": log_bytes,
            "warm_hit_scan_bytes": warm.scan_bytes,
            "warm_hit_lock_roundtrips": warm.lock_roundtrips - locks,
        }

        lazy = PersistentCache(path, lazy=True)
        lazy.get_many(["k17", "k251", "k499"])
        out["lazy_point_reads"] = lazy.point_reads
        out["lazy_scan_bytes"] = lazy.scan_bytes
    return out


def _learned_accuracy() -> dict:
    """Learned-tier accuracy on the checked-in golden grid
    (``specs/learned_fidelity.json``): deterministic fit / extrapolation
    counters plus the MAPE headline against the recorded reference —
    the cross-fidelity accuracy row the report prints, as a perf
    artifact.  Run under serial and process executors with fresh caches
    so duplicate cold misses stay a pinned zero."""
    from repro.campaign import CampaignSpec, run_campaign
    from repro.campaign.report import (build_report, load_json,
                                       reference_path)
    from repro.core.estimators import load_model

    spec_path = os.path.join(REPO, "specs", "learned_fidelity.json")
    model = load_model(os.path.join(
        REPO, "specs", "models", "learned-gemm-a100.json"))
    spec = CampaignSpec.from_json(spec_path)
    results = {}
    for ex in ("serial", "process"):
        with tempfile.TemporaryDirectory() as d:
            results[ex] = run_campaign(
                spec, executor=ex, max_workers=4,
                cache_path=os.path.join(d, "hcr.jsonl"))
        assert results[ex].summary["num_failed"] == 0, \
            results[ex].summary["failures"]
    rows = results["serial"].rows
    learned_rows = [r for r in rows
                    if r["estimator"].startswith("learned-")]
    ref = load_json(reference_path(spec_path, spec.name))
    report = build_report(spec.name, rows, reference=ref)
    mape = report["accuracy"]["mape_pct"]
    label = next(k for k in mape if k.startswith("learned-"))
    return {
        "entries_fitted": model.meta["entries_fitted"],
        "families": len(model.families),
        "learned_rows": len(learned_rows),
        "extrapolated_predictions": sum(
            1 for r in learned_rows if r["extrapolated"]),
        "mape_pct": mape[label]["overall"],
        "duplicate_cold_misses": (
            results["process"].cache["misses"]
            - results["serial"].cache["misses"]),
    }


def main() -> None:
    from repro.campaign.builders import synthesize_gemm_stack
    from repro.core.pipeline import Workload

    workloads = {"gemm-stack": Workload(
        name="gemm-stack",
        stablehlo_text=synthesize_gemm_stack(STACK_SHAPES))}

    executors = {ex: _run_grid(ex, workloads)
                 for ex in ("serial", "thread", "process")}
    serial_misses = executors["serial"]["cache_misses"]
    duplicate_cold_misses = {
        ex: r["cache_misses"] - serial_misses for ex, r in executors.items()}

    jobs = executors["serial"]["jobs"]
    report = {
        "bench": "campaign-engine",
        "grid": {"jobs": jobs,
                 "plan_keys": executors["serial"]["plan_keys"],
                 "distinct_cache_keys": serial_misses},
        "executors": executors,
        # what a per-job engine (no plan sharing) would pay: one parse
        # and one slice per grid point
        "per_job_baseline": {"parse_calls": jobs, "plans_built": jobs},
        "parse_call_ratio": round(
            jobs / max(executors["serial"]["parse_calls"], 1), 1),
        "slice_call_ratio": round(
            jobs / max(executors["serial"]["plans_built"], 1), 1),
        "cache_ops": _cache_op_comparison(workloads),
        "duplicate_cold_misses": duplicate_cold_misses,
        "front_ends": _front_end_comparison(),
        "evaluate": _evaluate_comparison(),
        "cache_index": _cache_index_counters(),
        "learned": _learned_accuracy(),
    }
    path = os.path.join(REPO, "BENCH_campaign.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")

    rows = [{"name": f"campaign-{ex}", "us_per_call": r["wall_s"] * 1e6,
             **{k: v for k, v in r.items() if k != "wall_s"}}
            for ex, r in executors.items()]
    rows.append({"name": "campaign-cache-ops", "us_per_call": "",
                 **report["cache_ops"]})
    emit(rows, "bench_campaign")

    assert report["parse_call_ratio"] >= 2, report
    assert report["cache_ops"]["lock_roundtrip_ratio"] >= 5, report
    assert all(v == 0 for v in duplicate_cold_misses.values()), report
    # wall-clock ratios get loose in-bench floors (shared CI runners are
    # noisy); the headline figures live in the report itself
    fe = report["front_ends"]
    assert fe["sharded_train_stack"]["parse_ratio"] >= 4, report
    assert fe["tokenizer_passes_per_parse"] == 1, report
    ev = report["evaluate"]
    assert ev["region"]["evaluate_ratio"] >= 4, report
    assert ev["per_op"]["evaluate_ratio"] >= 4, report
    ci = report["cache_index"]
    assert ci["warm_hit_scan_bytes"] == 0, report
    assert ci["warm_hit_lock_roundtrips"] == 0, report
    lr = report["learned"]
    assert lr["duplicate_cold_misses"] == 0, report
    assert lr["extrapolated_predictions"] < lr["learned_rows"], report
    assert lr["mape_pct"] < 15.0, report


if __name__ == "__main__":
    main()
