"""Campaign-engine performance: plan reuse, batched cache ops, scheduling.

Runs a synthetic GEMM grid (no jax needed) through the campaign engine
under all three executors and measures the per-*workload* costs the plan
phase amortizes:

  * wall time per executor;
  * parse/slice calls vs the per-job baseline (pre-plan engines pay one
    parse + one slice per grid point; the plan store pays one per
    ``(workload, fidelity)`` / per plan key);
  * persistent-cache flock round-trips, batched (one ``put_many`` per
    evaluate phase) vs per-region (one append per miss + tail-reads);
  * duplicate cold misses under parallel executors (the locality
    schedule's leader-first chains must make these zero).

Emits ``BENCH_campaign.json`` at the repo root (the perf-trajectory
artifact) plus the usual CSV under ``artifacts/bench/``.
"""
import json
import os
import tempfile
import time

from benchmarks.common import emit

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: stacked-GEMM workload: distinct shapes -> distinct fingerprints, one
#: region per GEMM under the linear slicer.  200 + 48·i is deliberately
#: disjoint from the single-GEMM grid sizes (512/1024/2048/4096): a
#: shared fingerprint would put two *different* locality chains in a
#: race for the same cache key, making the zero-duplicate-cold-miss
#: assertion below timing-dependent.
STACK_SHAPES = [(200 + 48 * i, 200 + 48 * i, 200 + 48 * i)
                for i in range(24)]


def _grid_spec():
    from repro.campaign import CampaignSpec
    workloads = [{"name": f"gemm-{n}", "fidelity": "raw",
                  "gemm": {"m": n, "n": n, "k": n, "dtype": "bf16"}}
                 for n in (512, 1024, 2048, 4096)]
    workloads.append({"name": "gemm-stack", "fidelity": "raw",
                      "stablehlo_path": "in-memory"})
    return CampaignSpec.from_dict({
        "name": "bench-campaign",
        "workloads": workloads,
        "systems": ["a100", "h100", "b200", "tpu-v3"],
        "estimators": [{"kind": "roofline"},
                       {"kind": "roofline", "options": {"mode": "per-op"}}],
        "slicers": ["linear", "dep"],
        "topologies": [{"kind": "a2a", "params": {"num_devices": 4}}],
    })


def _run_grid(executor: str, workloads: dict) -> dict:
    from repro import api
    session = api.Session()
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        res = session.campaign(_grid_spec(), workloads=workloads,
                               executor=executor, max_workers=4,
                               cache_path=os.path.join(d, "hcr.jsonl"))
        wall = time.perf_counter() - t0
    assert res.summary["num_failed"] == 0, res.summary["failures"]
    return {
        "wall_s": round(wall, 4),
        "jobs": res.plans["jobs"],
        "plan_keys": res.plans["plan_keys"],
        "parse_calls": res.plans["parse_calls"],
        "plans_built": res.plans["plans_built"],
        "cache_hits": res.cache["hits"],
        "cache_misses": res.cache["misses"],
        "lock_roundtrips": res.cache["lock_roundtrips"],
    }


def _cache_op_comparison(workloads: dict) -> dict:
    """Per-region vs batched store traffic for one multi-region workload
    evaluated over several systems (fresh path-backed store each)."""
    from repro.campaign.builders import build_estimator, build_topology
    from repro.campaign.spec import EstimatorSpec, TopologySpec
    from repro.core.estimators.cache import PersistentCache
    from repro.core.pipeline import PredictionJob, build_plan
    from repro.core.systems import get_system

    program = workloads["gemm-stack"].program("raw")
    plan = build_plan(program, slicer="linear", name="gemm-stack")
    out = {"regions": len(plan.compute_regions),
           "fingerprints": len(plan.fingerprints)}
    for batched in (False, True):
        with tempfile.TemporaryDirectory() as d:
            store = PersistentCache(os.path.join(d, "hcr.jsonl"))
            t0 = time.perf_counter()
            for sysname in ("a100", "h100", "b200", "tpu-v3"):
                system = get_system(sysname)
                est = build_estimator(EstimatorSpec(), system)
                topo = build_topology(
                    TopologySpec("a2a", (("num_devices", 4),)), system)
                PredictionJob(estimator=est, topology=topo, plan=plan,
                              name="gemm-stack", cache_store=store,
                              batch_cache=batched).run()
            key = "batched" if batched else "per_region"
            out[f"{key}_lock_roundtrips"] = store.lock_roundtrips
            out[f"{key}_wall_s"] = round(time.perf_counter() - t0, 4)
    out["lock_roundtrip_ratio"] = round(
        out["per_region_lock_roundtrips"]
        / max(out["batched_lock_roundtrips"], 1), 1)
    return out


def main() -> None:
    from repro.campaign.builders import synthesize_gemm_stack
    from repro.core.pipeline import Workload

    workloads = {"gemm-stack": Workload(
        name="gemm-stack",
        stablehlo_text=synthesize_gemm_stack(STACK_SHAPES))}

    executors = {ex: _run_grid(ex, workloads)
                 for ex in ("serial", "thread", "process")}
    serial_misses = executors["serial"]["cache_misses"]
    duplicate_cold_misses = {
        ex: r["cache_misses"] - serial_misses for ex, r in executors.items()}

    jobs = executors["serial"]["jobs"]
    report = {
        "bench": "campaign-engine",
        "grid": {"jobs": jobs,
                 "plan_keys": executors["serial"]["plan_keys"],
                 "distinct_cache_keys": serial_misses},
        "executors": executors,
        # what a per-job engine (no plan sharing) would pay: one parse
        # and one slice per grid point
        "per_job_baseline": {"parse_calls": jobs, "plans_built": jobs},
        "parse_call_ratio": round(
            jobs / max(executors["serial"]["parse_calls"], 1), 1),
        "slice_call_ratio": round(
            jobs / max(executors["serial"]["plans_built"], 1), 1),
        "cache_ops": _cache_op_comparison(workloads),
        "duplicate_cold_misses": duplicate_cold_misses,
    }
    path = os.path.join(REPO, "BENCH_campaign.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {path}")

    rows = [{"name": f"campaign-{ex}", "us_per_call": r["wall_s"] * 1e6,
             **{k: v for k, v in r.items() if k != "wall_s"}}
            for ex, r in executors.items()]
    rows.append({"name": "campaign-cache-ops", "us_per_call": "",
                 **report["cache_ops"]})
    emit(rows, "bench_campaign")

    assert report["parse_call_ratio"] >= 2, report
    assert report["cache_ops"]["lock_roundtrip_ratio"] >= 5, report
    assert all(v == 0 for v in duplicate_cold_misses.values()), report


if __name__ == "__main__":
    main()
