"""Benchmark harness — one function per paper table/figure.

Each figure needs its own XLA host-device count, so every figure runs in a
fresh subprocess (the device count locks at first jax init).  Output:
``name,us_per_call,derived`` CSV lines on stdout + one CSV artifact per
figure under artifacts/bench/.

  python -m benchmarks.run            # everything
  python -m benchmarks.run fig10 micro
"""
from __future__ import annotations

import os
import subprocess
import sys

HERE = os.path.dirname(os.path.abspath(__file__))

FIGURES = {
    "fig6": "fig6_gpu_generations",   # + Table V
    "fig7": "fig7_resnet",
    "fig9": "fig9_scaleout",
    "fig10": "fig10_gemm",
    "fig11": "fig11_tpu",
    "caching": "caching_exp",
    "micro": "micro_bench",
    "campaign": "bench_campaign",
    "serve": "bench_serve",
    "search": "bench_search",
}


def run_figure(key: str) -> int:
    module = FIGURES[key]
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)  # each figure sets its own device count
    print(f"### {key} ({module}) ###", flush=True)
    # -m from the repo root: the benchmarks package resolves from cwd and
    # repro from the installed package (or PYTHONPATH=src) — no figure
    # script carries sys.path edits
    proc = subprocess.run(
        [sys.executable, "-m", f"benchmarks.{module}"],
        cwd=os.path.dirname(HERE), env=env)
    return proc.returncode


def main() -> None:
    keys = sys.argv[1:] or list(FIGURES)
    failed = []
    for key in keys:
        if key not in FIGURES:
            print(f"unknown figure {key!r}; have {list(FIGURES)}")
            failed.append(key)
            continue
        if run_figure(key) != 0:
            failed.append(key)
    if failed:
        print(f"FAILED: {failed}")
        raise SystemExit(1)
    print("benchmarks: all figures complete")


if __name__ == "__main__":
    main()
