"""Deterministic ε-Pareto filtering over candidate objective vectors.

All objectives are *minimized* (the spec layer only admits
lower-is-better metrics; ``perf/$`` is reported as the inverse of
``$/step`` rather than searched on).  The filter is a pure function of
the (id, values) pairs: candidates are processed in sorted-id order and
ties survive together, so the result is independent of input order —
the property that makes search frontiers golden-pinnable.

ε is the *pruning slack* between fidelity tiers: a point is discarded
only when some other point beats it by at least a factor ``1 + eps`` on
**every** objective, so a cheap-tier ranking error smaller than ε can
never prune a point the expensive tier would have put on the frontier.
``eps=0`` is exact Pareto domination (used on the final tier).
"""
from __future__ import annotations

__all__ = ["dominates", "pareto_filter"]


def dominates(a: tuple, b: tuple, eps: float = 0.0) -> bool:
    """True when ``a`` ε-dominates ``b``: ``a_i * (1 + eps) <= b_i`` on
    every objective and ``a_i < b_i`` on at least one.  With ``eps=0``
    this is classic Pareto domination; equal vectors never dominate
    each other."""
    if len(a) != len(b):
        raise ValueError(f"objective arity mismatch: {len(a)} vs {len(b)}")
    return (all(ai * (1.0 + eps) <= bi for ai, bi in zip(a, b))
            and any(ai < bi for ai, bi in zip(a, b)))


def pareto_filter(points: dict[str, tuple], eps: float = 0.0) -> list[str]:
    """ids of the non-ε-dominated points of ``points`` (id -> objective
    vector), in sorted-id order.

    O(n²) pairwise sweep — candidate counts here are grid sizes
    (tens to low thousands), not populations.  Determinism: both loops
    run over the same sorted id list, and survival of ``b`` depends only
    on whether *any* ``a`` dominates it, so shuffling the input dict
    cannot change the result."""
    ids = sorted(points)
    out = []
    for b in ids:
        if not any(a != b and dominates(points[a], points[b], eps)
                   for a in ids):
            out.append(b)
    return out
