"""CLI for the what-if search engine.

::

    python -m repro.search run specs/search_gemm.json
    python -m repro.search run specs/search_gemm.json --check
    python -m repro.search run specs/search_gemm.json --update-golden
    python -m repro.search run specs/search_gemm.json --brute-force
    python -m repro.search validate specs/search_gemm.json ...

``run`` writes ``frontier.json`` / ``frontier.md`` / ``rows.jsonl``
under ``--out`` (default ``artifacts/search/<name>/``).  ``--check``
diffs the frontier against its golden snapshot next to the spec
(``specs/golden/<name>.json``) and exits 1 on drift; ``--update-golden``
rewrites it.  ``--brute-force`` scores every feasible candidate at the
top ladder rung with no pruning — the reference for prune soundness.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from ..campaign.__main__ import _preset_device_count
from .engine import run_search
from .report import (build_search_report, check_frontier, golden_path,
                     load_json, make_frontier_golden, render_markdown,
                     write_json)
from .spec import SearchSpec


def _run_command(args) -> int:
    with open(args.spec) as f:
        d = json.load(f)
    spec = SearchSpec.from_file_dict(d, args.spec)
    _preset_device_count([(spec.name, spec.campaign_for_rung(0))])
    result = run_search(spec, cache_path=args.cache,
                        brute_force=args.brute_force,
                        progress=not args.quiet)
    report = build_search_report(result)

    out_dir = args.out or os.path.join("artifacts", "search", spec.name)
    os.makedirs(out_dir, exist_ok=True)
    paths = {"json": os.path.join(out_dir, "frontier.json"),
             "md": os.path.join(out_dir, "frontier.md"),
             "rows": os.path.join(out_dir, "rows.jsonl")}
    with open(paths["json"], "w") as f:
        json.dump(report, f, indent=2)
    with open(paths["md"], "w") as f:
        f.write(render_markdown(report))
    with open(paths["rows"], "w") as f:
        for row in result.rows:
            f.write(json.dumps(row) + "\n")

    c = result.counters
    print(f"search {spec.name!r}: {c['frontier_size']} frontier points "
          f"from {c['candidates']} candidates "
          f"({c['top_rung_evaluations']} scored at the top rung; "
          f"{c['pruned_ceiling'] + c['pruned_dominated']} pruned, "
          f"{c['infeasible']} infeasible); wall {result.wall_s:.2f} s")
    for p in report["frontier"]:
        vals = ", ".join(f"{o}={v:.6g}" for o, v in p["values"].items())
        print(f"  * {p['key']}: {vals}")
    print(f"  wrote {paths['json']}, {paths['md']}")

    gpath = golden_path(args.spec, spec.name)
    if args.update_golden:
        write_json(gpath, make_frontier_golden(report))
        print(f"  updated golden {gpath}")
        return 0
    if args.check:
        golden = load_json(gpath)
        if golden is None:
            print(f"  CHECK FAILED: no golden at {gpath} "
                  "(run with --update-golden to create it)")
            return 1
        failures = check_frontier(golden, report, args.tolerance)
        if failures:
            print(f"  CHECK FAILED ({len(failures)} violations):")
            for f_ in failures:
                print(f"    - {f_}")
            return 1
        print(f"  golden OK ({len(golden['frontier'])} frontier points, "
              f"tolerance {args.tolerance})")
    return 0


def _validate_command(args) -> int:
    status = 0
    for path in args.specs:
        try:
            with open(path) as f:
                spec = SearchSpec.from_file_dict(json.load(f), path)
        except (OSError, ValueError, KeyError) as e:
            print(f"INVALID {path}: {e}")
            status = 1
            continue
        n = len(spec.campaign_for_rung(0).expand())
        print(f"ok {path}: search {spec.name!r}, {n} candidates, "
              f"{len(spec.ladder)}-rung ladder, "
              f"objectives {list(spec.objectives)}")
    return status


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.search",
        description="Multi-fidelity what-if search over the system grid")
    sub = ap.add_subparsers(dest="command", required=True)

    run_p = sub.add_parser("run", help="run a search spec")
    run_p.add_argument("spec", help="search spec JSON file")
    run_p.add_argument("--out", help="output dir "
                       "(default artifacts/search/<name>)")
    run_p.add_argument("--cache", help="persistent (H,C,R) cache path")
    run_p.add_argument("--check", action="store_true",
                       help="diff the frontier against its golden")
    run_p.add_argument("--update-golden", action="store_true",
                       help="rewrite the golden frontier snapshot")
    run_p.add_argument("--tolerance", type=float, default=1e-9,
                       help="relative tolerance for --check")
    run_p.add_argument("--brute-force", action="store_true",
                       help="score everything at the top rung, no pruning")
    run_p.add_argument("--quiet", action="store_true")
    run_p.set_defaults(func=_run_command)

    val_p = sub.add_parser("validate", help="validate search spec files")
    val_p.add_argument("specs", nargs="+")
    val_p.set_defaults(func=_validate_command)

    args = ap.parse_args(argv)
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
