"""What-if search with TCO: campaigns as an optimizer, not a sweep.

Public surface::

    from repro.search import SearchSpec, run_search
    result = run_search(SearchSpec.from_json("specs/search_gemm.json"))
    result.frontier          # candidate keys on the Pareto frontier

CLI: ``python -m repro.search run|validate`` (see ``docs/search.md``).
"""
from .engine import SearchResult, run_search
from .pareto import dominates, pareto_filter
from .report import (build_search_report, check_frontier,
                     make_frontier_golden, render_markdown)
from .spec import CONSTRAINT_KEYS, OBJECTIVES, SearchSpec

__all__ = [
    "SearchSpec", "SearchResult", "run_search",
    "dominates", "pareto_filter",
    "build_search_report", "render_markdown",
    "make_frontier_golden", "check_frontier",
    "OBJECTIVES", "CONSTRAINT_KEYS",
]
