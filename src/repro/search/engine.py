"""The multi-fidelity search engine: expand → score cheap → prune →
refine → frontier.

One :func:`run_search` call answers a :class:`~repro.search.spec.SearchSpec`
query:

1. **expand** the candidate grid (workload × system × slicer × topology)
   and drop candidates that fail *structural* constraints up front —
   memory-capacity fit, missing cost/power ratings for a priced
   objective;
2. **score** every feasible candidate on ladder rung 0 (the cheap
   analytical tier) through the shared :class:`PlanStore` plan phase and
   the estimators' vectorized ``evaluate_batch`` fast path;
3. **calibrate**: evaluate one *anchor* candidate per
   (workload, system) group at the top rung and rescale the whole
   group's cheap scores by the anchor's bias ratio.  The cheap tier's
   error against the refined tier is dominated by a per-system,
   per-problem-size utilization term (measured ~5× across this repo's
   catalog, vs ~1.0× within a group across topology/slicer choices),
   so a multiplicative anchor correction turns a hopelessly biased
   ranking into a nearly rank-faithful one;
4. **prune** on the calibrated scores with the deterministic ε-Pareto
   filter plus ε-slackened constraint ceilings — ε now only needs to
   cover the small *residual* (post-calibration) error, then **refine**
   the survivors on each higher rung, reusing the same (H, C, R) cache
   store — a refinement re-visits regions the cheap tier already
   fingerprinted, so only genuinely new (estimator-config, region)
   pairs miss;
5. emit the exact (ε=0) Pareto **frontier** of the final-rung values,
   with per-point provenance of every rung that scored it and
   ``uncertainty_s`` carried through from a learned rung.

Domination is judged **within a workload group**: candidates that solve
different problems (a 1 k GEMM vs an 8 k GEMM, decode at batch 4 vs
batch 32) are never compared, so the frontier is the union of one
sub-frontier per workload entry — "for each what-if, which
system × slicer × topology points are worth it".

Everything is deterministic — candidate order is canonical, the filter
is order-independent, and evaluation reuses the campaign ``_execute``
path whose outputs are golden-pinned — so a search frontier can be
snapshot-tested exactly like a campaign grid.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from ..campaign.builders import build_system, build_topology
from ..campaign.plans import PlanStore
from ..campaign.runner import _execute, _Registries, _workload_texts
from ..campaign.spec import JobSpec
from ..core.estimators.cache import PersistentCache
from .pareto import pareto_filter
from .spec import SearchSpec

__all__ = ["run_search", "SearchResult", "candidate_key"]


def candidate_key(job: JobSpec) -> str:
    """The candidate identity a job scores — every axis except the
    estimator (which is the fidelity ladder's, not the candidate's)."""
    return " × ".join((job.workload, job.system, job.slicer,
                       job.topology.label))


@dataclass
class SearchResult:
    """Everything :func:`run_search` learned, JSON-ready via report."""
    spec: SearchSpec
    candidates: dict = field(default_factory=dict)  # key -> record
    frontier: list = field(default_factory=list)    # keys, sorted
    counters: dict = field(default_factory=dict)
    #: per-(workload × system) anchor calibration: group -> {anchor, scale}
    calibration: dict = field(default_factory=dict)
    rows: list = field(default_factory=list)        # every eval row, in order
    wall_s: float = 0.0


def _objective_values(row: dict, objectives: tuple) -> tuple | None:
    """The row's objective vector, or None when a metric is missing."""
    try:
        return tuple(float(row[o]) for o in objectives)
    except KeyError:
        return None


def _grouped_pareto(cand: dict, live: dict, eps: float) -> list[str]:
    """ε-Pareto survivors of ``live`` (key -> objective tuple), with
    domination judged only between candidates of the same workload."""
    by_group: dict[str, dict] = {}
    for k, vals in live.items():
        by_group.setdefault(cand[k]["workload"], {})[k] = vals
    out: list[str] = []
    for g in sorted(by_group):
        out.extend(pareto_filter(by_group[g], eps))
    return sorted(out)


def _intra_group_prune(cand: dict, kept: dict) -> tuple[dict, list[str]]:
    """Exact (ε=0) Pareto prune *within* each (workload, system) group.

    All members of a group share one calibration scale, so their cheap
    scores are perfectly rank-correlated with the refined tier (the
    estimator bias is per-system × per-problem-size, not per-topology)
    — which licenses exact domination here, including on ties that an
    ε-slackened comparison could never prune (e.g. sharding a workload
    whose regions all land on one device: identical step time, strictly
    more $/step).  Returns (survivors, pruned_keys)."""
    by_ws: dict[tuple, dict] = {}
    for k, vals in kept.items():
        r = cand[k]
        by_ws.setdefault((r["workload"], r["system"]), {})[k] = vals
    out: dict = {}
    pruned: list[str] = []
    for g in sorted(by_ws):
        surv = set(pareto_filter(by_ws[g], 0.0))
        for k in by_ws[g]:
            if k in surv:
                out[k] = by_ws[g][k]
            else:
                pruned.append(k)
    return out, sorted(pruned)


def _ceiling_violations(values: dict, constraints: dict,
                        slack: float) -> list[str]:
    """Names of ``max_*`` ceilings violated by ``values`` with
    multiplicative ``slack`` (0 = exact)."""
    out = []
    for ck, limit in constraints.items():
        if not ck.startswith("max_"):
            continue
        metric = ck[len("max_"):]
        v = values.get(metric)
        if v is not None and v > limit * (1.0 + slack):
            out.append(ck)
    return out


def run_search(spec: SearchSpec, *, session=None,
               cache: PersistentCache | None = None,
               cache_path: str | None = None,
               plan_store: PlanStore | None = None,
               brute_force: bool = False,
               progress: bool = False) -> SearchResult:
    """Run the fidelity-ladder search (see module docstring).

    ``session``/``cache``/``plan_store`` follow the campaign runner's
    warm-caller contract: a :class:`repro.api.Session` or the serve
    daemon passes its live stores so repeated what-ifs re-parse and
    re-cost nothing.  ``brute_force=True`` scores *every* feasible
    candidate on the final rung with no pruning — the reference the
    prune-soundness test (and the paper-honesty check in CI) compares
    frontier membership against."""
    t0 = time.perf_counter()
    spec.validate(session=session)
    cs0 = spec.campaign_for_rung(0)
    regs = _Registries.for_session(session, cs0)
    store = cache if cache is not None else PersistentCache(cache_path)
    plans = plan_store if plan_store is not None else PlanStore({})
    plans.add_texts(_workload_texts(cs0, None))

    result = SearchResult(spec=spec)
    cand = result.candidates
    objectives = spec.objectives
    constraints = spec.constraints
    top_rung = len(spec.ladder) - 1
    rungs = [top_rung] if brute_force else list(range(len(spec.ladder)))

    def log(msg: str) -> None:
        if progress:
            print(msg, flush=True)

    priced = any(o == "usd_per_step" for o in objectives) or \
        "max_usd_per_step" in constraints
    rated = any(o == "joules_per_step" for o in objectives) or \
        "max_joules_per_step" in constraints

    # candidate jobs per rung, keyed by candidate identity
    jobs_by_rung: dict[int, dict] = {}

    def jobs_for(rung: int) -> dict:
        if rung not in jobs_by_rung:
            jobs_by_rung[rung] = {
                candidate_key(j): j
                for j in spec.campaign_for_rung(rung).expand()}
        return jobs_by_rung[rung]

    evaluated_by_rung: dict[int, int] = {r: 0 for r in rungs}

    def score(key: str, rung: int) -> None:
        """Evaluate candidate ``key`` on ladder rung ``rung`` (idempotent
        — an anchor already scored at the top rung is not re-run)."""
        job = jobs_for(rung)[key]
        plan = plans.get(*plans.key_for(job))
        rec = cand.get(key)
        if rec is None:
            rec = cand[key] = {
                "key": key, "workload": job.workload,
                "system": job.system, "slicer": job.slicer,
                "topology": job.topology.label,
                "feasible": True, "rungs": [], "by_rung": {}}
            # structural feasibility, before spending any evaluation
            system = build_system(job.system, registry=regs.systems)
            ctx = regs.context(system_name=job.system,
                               program=plan.program)
            topo = build_topology(job.topology, system,
                                  registry=regs.topologies, context=ctx)
            rec["num_devices"] = topo.num_devices
            reasons = []
            if priced and system.cost_per_hour is None:
                reasons.append("unpriced (no cost_per_hour in catalog)")
            if rated and system.tdp_watts is None:
                reasons.append("unrated (no tdp_watts in catalog)")
            if constraints.get("mem_capacity_fit"):
                working_set = max(
                    (r.cost.bytes for r in plan.compute_regions),
                    default=0.0)
                if working_set > system.mem_capacity:
                    reasons.append(
                        f"mem_capacity_fit ({working_set:.3g} B > "
                        f"{system.mem_capacity:.3g} B)")
            if reasons:
                rec["feasible"] = False
                rec["reason"] = "; ".join(reasons)
        if not rec["feasible"] or rung in rec["by_rung"]:
            return
        row, _ = _execute(job, plan, store, regs)
        result.rows.append(row)
        evaluated_by_rung[rung] += 1
        values = _objective_values(row, objectives)
        if values is None:
            rec["feasible"] = False
            rec["reason"] = (
                f"row from {row['estimator']} lacks objective "
                f"metric(s) {list(objectives)}")
            return
        rec["by_rung"][rung] = dict(zip(objectives, values))
        # rec["values"] tracks the highest-fidelity scoring so far
        # (anchors get their top-rung score before the middle rungs run)
        if rung >= rec.get("_max_rung", -1):
            rec["_max_rung"] = rung
            rec["values"] = dict(zip(objectives, values))
        # merge (not replace): a learned rung's uncertainty_s stays
        # attached even after a final systolic rung re-scores
        rec.setdefault("extras", {}).update({
            k: row[k] for k in ("step_time_s", "usd_per_step",
                                "perf_per_usd", "joules_per_step",
                                "uncertainty_s", "uncertainty_rel",
                                "extrapolated")
            if k in row})
        rec["rungs"].append({
            "rung": rung, "estimator": row["estimator"],
            "fidelity": row["fidelity"],
            "values": dict(zip(objectives, values)),
            **({"uncertainty_s": row["uncertainty_s"]}
               if "uncertainty_s" in row else {})})

    # ---- rung 0: score the whole grid on the cheapest tier ----
    first = rungs[0]
    for key in sorted(jobs_for(first)):
        score(key, first)
    infeasible = sum(1 for r in cand.values() if not r["feasible"])
    log(f"  rung {first} ({spec.ladder[first].label}): "
        f"{evaluated_by_rung[first]} candidates scored, "
        f"{infeasible} infeasible")
    live = sorted(k for k, r in cand.items()
                  if r["feasible"] and first in r["by_rung"])

    # ---- calibrate + prune (only when there is a refinement rung) ----
    survivors = live
    pruned_dominated = pruned_ceiling = pruned_intra = n_anchors = 0
    if len(rungs) > 1:
        groups: dict[tuple, list] = {}
        for k in live:
            r = cand[k]
            groups.setdefault((r["workload"], r["system"]), []).append(k)
        calibrated: dict[str, dict] = {}
        for g in sorted(groups):
            members = groups[g]
            # anchor: the group's cheap-tier best on the first objective
            # (deterministic tie-break on key), scored at the TOP rung
            anchor = min(members, key=lambda k: (
                cand[k]["by_rung"][first][objectives[0]], k))
            score(anchor, top_rung)
            n_anchors += 1
            a = cand[anchor]
            top_vals = a["by_rung"].get(top_rung)
            cheap_vals = a["by_rung"][first]
            scale = {o: (top_vals[o] / cheap_vals[o]
                         if top_vals and cheap_vals[o] else 1.0)
                     for o in objectives}
            result.calibration[" × ".join(g)] = {
                "anchor": anchor, "scale": scale}
            for k in members:
                calibrated[k] = {
                    o: cand[k]["by_rung"][first][o] * scale[o]
                    for o in objectives}
        log(f"  calibrate: {n_anchors} anchors scored at "
            f"rung {top_rung} ({spec.ladder[top_rung].label})")

        # ε-slackened ceilings, exact intra-(workload, system) prune,
        # then grouped ε-Pareto on the calibrated scores — conservative
        # throughout: only clearly-out points die here
        kept = {}
        for k in live:
            viol = _ceiling_violations(calibrated[k], constraints,
                                       spec.epsilon)
            if viol:
                cand[k]["pruned"] = f"ceiling: {', '.join(viol)}"
                pruned_ceiling += 1
            else:
                kept[k] = tuple(calibrated[k][o] for o in objectives)
        kept, intra = _intra_group_prune(cand, kept)
        for k in intra:
            cand[k]["pruned"] = ("dominated within its (workload, "
                                 "system) group at the cheap rung")
        pruned_intra = len(intra)
        survivors = _grouped_pareto(cand, kept, spec.epsilon)
        for k in set(kept) - set(survivors):
            cand[k]["pruned"] = "ε-dominated at the cheap rung (calibrated)"
        pruned_dominated = len(kept) - len(survivors)
        log(f"  prune: {pruned_ceiling} over ceiling, {pruned_intra} "
            f"intra-group dominated, {pruned_dominated} ε-dominated → "
            f"{len(survivors)} survivors")

        # ---- refine survivors on every higher rung ----
        for rung in rungs[1:]:
            for key in survivors:
                score(key, rung)
            log(f"  rung {rung} ({spec.ladder[rung].label}): "
                f"{evaluated_by_rung[rung]} candidates scored")

    # ---- final: exact ceilings, exact grouped Pareto, top-rung values ----
    final_infeasible = 0
    final = {}
    for k in survivors:
        r = cand[k]
        vals = r["by_rung"].get(top_rung)
        if not r["feasible"] or vals is None:
            continue
        viol = _ceiling_violations(vals, constraints, 0.0)
        if viol:
            r["pruned"] = f"ceiling (final): {', '.join(viol)}"
            final_infeasible += 1
            continue
        final[k] = tuple(vals[o] for o in objectives)
    result.frontier = _grouped_pareto(cand, final, 0.0)
    for k in result.frontier:
        cand[k]["on_frontier"] = True
    for r in cand.values():
        r["rungs"].sort(key=lambda e: e["rung"])
        r.pop("_max_rung", None)

    n = len(cand)
    top_evals = evaluated_by_rung.get(top_rung, 0)
    result.counters = {
        "candidates": n,
        "infeasible": infeasible,
        "anchors": n_anchors,
        "pruned_ceiling": pruned_ceiling,
        "pruned_intra": pruned_intra,
        "pruned_dominated": pruned_dominated,
        "final_infeasible": final_infeasible,
        "evaluations": [
            {"rung": r, "estimator": spec.ladder[r].label,
             "evaluated": evaluated_by_rung[r]} for r in rungs],
        "top_rung_evaluations": top_evals,
        "top_rung_fraction": round(top_evals / n, 4) if n else 0.0,
        "frontier_size": len(result.frontier),
        "cache_hits": sum(r.get("cache_hits", 0) for r in result.rows),
        "cache_misses": sum(r.get("cache_misses", 0) for r in result.rows),
        "brute_force": brute_force,
    }
    result.wall_s = time.perf_counter() - t0
    return result
