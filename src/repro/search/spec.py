"""Declarative what-if search specs (validated JSON, like campaigns).

A :class:`SearchSpec` is a campaign grid *minus* the estimator axis,
*plus* the three things that turn a sweep into an optimizer:

* ``objectives`` — two or more row metrics to jointly minimize
  (``step_time_s``, ``usd_per_step``, ``joules_per_step``, …);
* ``constraints`` — feasibility gates (``mem_capacity_fit``, spend and
  latency ceilings) applied before and after refinement;
* ``ladder`` — an ordered list of estimator specs, cheapest first.  The
  engine scores every candidate on rung 0, ε-Pareto-prunes, then
  re-scores only the survivors on each higher rung.

The candidate set is the cross product of the workload / system /
slicer / topology axes; batch, sequence length, mesh, and parallelism
knobs live on the workload and topology entries exactly as they do in
campaign specs, so "sweep batch sizes" is just several workload entries.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field

from ..campaign.spec import (CampaignSpec, EstimatorSpec, TopologySpec,
                             WorkloadSpec)

__all__ = ["SearchSpec", "OBJECTIVES", "CONSTRAINT_KEYS"]

#: metrics a search may minimize — every one is a campaign result-row
#: field, lower-is-better (``perf/$`` is reported, not searched: it is
#: the inverse of ``usd_per_step``)
OBJECTIVES = ("step_time_s", "usd_per_step", "joules_per_step",
              "compute_s", "comm_s", "exposed_comm_s")

#: recognised constraint keys and their meaning:
#: ``mem_capacity_fit`` (bool) — the plan's largest region working set
#: must fit the system's per-device HBM; ``max_*`` (float) — hard
#: ceilings on the named metric (slackened by ε at the pruning tier,
#: exact on the final tier)
CONSTRAINT_KEYS = ("mem_capacity_fit", "max_step_time_s",
                   "max_usd_per_step", "max_joules_per_step")


@dataclass
class SearchSpec:
    """The declarative what-if query (see ``docs/search.md``)."""
    name: str = "search"
    workloads: list[WorkloadSpec] = field(default_factory=list)
    systems: list[str] = field(default_factory=lambda: ["a100"])
    slicers: list[str] = field(default_factory=lambda: ["linear"])
    topologies: list[TopologySpec] = field(
        default_factory=lambda: [TopologySpec()])
    objectives: tuple = ("step_time_s", "usd_per_step")
    ladder: list[EstimatorSpec] = field(
        default_factory=lambda: [EstimatorSpec()])
    constraints: dict = field(default_factory=dict)
    #: ε-Pareto pruning slack between ladder rungs (see search/pareto.py)
    epsilon: float = 0.25
    system_catalog: list[str] = field(default_factory=list)

    #: spec file's directory when loaded via :meth:`from_json` (class
    #: attribute, not a spec key — same convention as CampaignSpec)
    base_dir = None

    @classmethod
    def from_dict(cls, d: dict, *, session=None) -> "SearchSpec":
        d = dict(d)
        known = set(cls.__dataclass_fields__)
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown search spec keys: {sorted(unknown)}")
        spec = cls(
            name=d.get("name", "search"),
            workloads=[WorkloadSpec.from_dict(w)
                       for w in d.get("workloads", [])],
            systems=list(d.get("systems", ["a100"])),
            slicers=list(d.get("slicers", ["linear"])),
            topologies=[TopologySpec.from_dict(t)
                        for t in d.get("topologies", [{}])],
            objectives=tuple(d.get("objectives",
                                   ("step_time_s", "usd_per_step"))),
            ladder=[EstimatorSpec.from_dict(e)
                    for e in d.get("ladder", [{}])],
            constraints=dict(d.get("constraints", {})),
            epsilon=float(d.get("epsilon", 0.25)),
            system_catalog=[str(p) for p in d.get("system_catalog", [])],
        )
        spec.validate(session=session)
        return spec

    @classmethod
    def from_file_dict(cls, d: dict, path: str, *,
                       session=None) -> "SearchSpec":
        d = dict(d)
        base = os.path.dirname(os.path.abspath(path))
        if d.get("system_catalog"):
            d["system_catalog"] = [
                p if os.path.isabs(p) else os.path.join(base, p)
                for p in d["system_catalog"]]
        spec = cls.from_dict(d, session=session)
        spec.base_dir = base
        return spec

    @classmethod
    def from_json(cls, path: str, *, session=None) -> "SearchSpec":
        with open(path) as f:
            d = json.load(f)
        return cls.from_file_dict(d, path, session=session)

    def to_dict(self) -> dict:
        """JSON-ready dict form; round-trips through :meth:`from_dict`."""
        d = asdict(self)
        d["objectives"] = list(self.objectives)
        for e in d["ladder"]:
            e["options"] = dict(e["options"])
        for t in d["topologies"]:
            t["params"] = dict(t["params"])
        if not d.get("system_catalog"):
            d.pop("system_catalog", None)
        if not d.get("constraints"):
            d.pop("constraints", None)
        return d

    # ------------------------------ validation ------------------------------

    def validate(self, *, session=None) -> None:
        """Reject queries that could not run — delegates the axis checks
        to a tier-0 :class:`CampaignSpec` (same vocabularies, same
        did-you-mean errors) and adds the search-only rules."""
        if not self.ladder:
            raise ValueError("search spec: ladder needs at least one "
                             "estimator rung")
        if not self.objectives or len(set(self.objectives)) < 2:
            raise ValueError(
                "search spec: need at least two distinct objectives "
                f"(a one-objective 'frontier' is just min); have "
                f"{list(self.objectives)}")
        bad = [o for o in self.objectives if o not in OBJECTIVES]
        if bad:
            raise ValueError(f"search spec: unknown objectives {bad}; "
                             f"have {list(OBJECTIVES)}")
        if self.epsilon < 0:
            raise ValueError(
                f"search spec: epsilon must be >= 0, got {self.epsilon}")
        unknown = sorted(set(self.constraints) - set(CONSTRAINT_KEYS))
        if unknown:
            raise ValueError(f"search spec: unknown constraints {unknown}; "
                             f"have {list(CONSTRAINT_KEYS)}")
        for k, v in self.constraints.items():
            if not k.startswith("max_"):
                continue
            if not (isinstance(v, (int, float)) and v > 0):
                raise ValueError(
                    f"search spec: constraint {k} must be a positive "
                    f"number, got {v!r}")
            # ceilings are enforced on the scored objective vectors, so a
            # ceiling on an unscored metric would be silently ignored —
            # reject it instead of returning a frontier that violates it
            metric = k[len("max_"):]
            if metric not in self.objectives:
                raise ValueError(
                    f"search spec: constraint {k} needs '{metric}' among "
                    f"the objectives (ceilings are enforced on scored "
                    f"metrics only); have {list(self.objectives)}")
        self.campaign_for_rung(0).validate(session=session)

    # ------------------------------- lowering -------------------------------

    def campaign_for_rung(self, rung: int) -> CampaignSpec:
        """The campaign grid of ladder rung ``rung``: this spec's axes
        with the estimator axis pinned to that rung.  The engine expands
        it for job ids and reuses the whole plan/evaluate machinery."""
        cs = CampaignSpec(
            name=self.name,
            workloads=self.workloads,
            systems=list(self.systems),
            estimators=[self.ladder[rung]],
            slicers=list(self.slicers),
            topologies=list(self.topologies),
            system_catalog=list(self.system_catalog),
        )
        cs.base_dir = self.base_dir
        return cs
