"""Frontier reports: JSON + markdown, golden-pinnable like campaigns.

The JSON report is the artifact ``python -m repro.search run`` writes
and the ``/search`` serve endpoint returns; :func:`make_frontier_golden`
distills the deterministic core (frontier membership, objective values,
pruning counters) into a snapshot under ``specs/golden/`` and
:func:`check_frontier` diffs a fresh run against it — membership is
exact, values compare within a relative tolerance, counters must match
exactly (a pruning regression is a correctness bug here, not noise).
"""
from __future__ import annotations

from ..campaign.report import golden_path, load_json, write_json  # noqa: F401
from .engine import SearchResult

__all__ = ["build_search_report", "render_markdown",
           "make_frontier_golden", "check_frontier",
           "golden_path", "load_json", "write_json"]

#: counters whose drift means the optimizer changed behavior (pinned
#: exactly in goldens; wall-clock and cache traffic are excluded)
_PINNED_COUNTERS = ("candidates", "infeasible", "anchors",
                    "pruned_ceiling", "pruned_intra", "pruned_dominated",
                    "final_infeasible", "top_rung_evaluations",
                    "frontier_size")


def build_search_report(result: SearchResult) -> dict:
    """The full JSON report for one search run."""
    spec = result.spec
    frontier = []
    for k in result.frontier:
        r = result.candidates[k]
        point = {
            "key": k,
            "workload": r["workload"], "system": r["system"],
            "slicer": r["slicer"], "topology": r["topology"],
            "num_devices": r.get("num_devices"),
            "values": r["values"],
            "extras": r.get("extras", {}),
            "provenance": r["rungs"],
        }
        frontier.append(point)
    dominated = [
        {"key": k, "reason": r.get("pruned") or r.get("reason")
         or "dominated at final rung",
         **({"values": r["values"]} if "values" in r else {})}
        for k, r in sorted(result.candidates.items())
        if not r.get("on_frontier")]
    return {
        "search": spec.name,
        "objectives": list(spec.objectives),
        "epsilon": spec.epsilon,
        "ladder": [e.label for e in spec.ladder],
        "constraints": dict(spec.constraints),
        "counters": result.counters,
        "calibration": result.calibration,
        "frontier": frontier,
        "dominated": dominated,
        "wall_s": round(result.wall_s, 4),
    }


def _fmt(v: float) -> str:
    return f"{v:.6g}"


def render_markdown(report: dict) -> str:
    """Human-readable digest of :func:`build_search_report` output."""
    c = report["counters"]
    objectives = report["objectives"]
    lines = [f"# Search report: {report['search']}", "",
             f"Objectives (minimized): {', '.join(objectives)}; "
             f"ladder: {' → '.join(report['ladder'])}; "
             f"ε = {report['epsilon']}.", ""]
    evals = " → ".join(
        f"{e['evaluated']} @ {e['estimator']}" for e in c["evaluations"])
    lines += [
        f"{c['candidates']} candidates expanded; {c['infeasible']} "
        f"infeasible; {c['pruned_ceiling']} over ceiling, {c['pruned_intra']} intra-group dominated, and "
        f"{c['pruned_dominated']} ε-dominated at the cheap rung; "
        f"evaluations: {evals}; frontier size {c['frontier_size']} "
        f"({c['top_rung_evaluations']}/{c['candidates']} = "
        f"{c['top_rung_fraction']:.0%} of the grid scored at the top "
        "rung).", "", "## Pareto frontier", ""]
    extras = sorted({k for p in report["frontier"] for k in p["extras"]
                     if k not in objectives and k != "step_time_s"})
    headers = ["point", "devices", *objectives, *extras]
    body = []
    for p in report["frontier"]:
        row = [p["key"], p.get("num_devices", "—")]
        row += [_fmt(p["values"][o]) for o in objectives]
        for x in extras:
            v = p["extras"].get(x)
            row.append(_fmt(v) if isinstance(v, float) else
                       ("—" if v is None else str(v)))
        body.append(row)
    lines += ["| " + " | ".join(str(h) for h in headers) + " |",
              "|" + "|".join("---" for _ in headers) + "|"]
    lines += ["| " + " | ".join(str(cell) for cell in r) + " |"
              for r in body]
    if report["dominated"]:
        lines += ["", "## Dominated / pruned / infeasible", ""]
        lines += [f"- `{d['key']}` — {d['reason']}"
                  for d in report["dominated"]]
    return "\n".join(lines) + "\n"


# ----------------------------- golden snapshots -----------------------------


def make_frontier_golden(report: dict) -> dict:
    """The deterministic core of a report, as a golden snapshot."""
    return {
        "search": report["search"],
        "objectives": report["objectives"],
        "epsilon": report["epsilon"],
        "ladder": report["ladder"],
        "counters": {k: report["counters"][k] for k in _PINNED_COUNTERS},
        "frontier": [{"key": p["key"], "values": p["values"]}
                     for p in report["frontier"]],
    }


def check_frontier(golden: dict, report: dict,
                   tolerance: float = 1e-9) -> list[str]:
    """Diff a fresh report against its golden; returns failure strings
    (empty = pass).  Membership and counters are exact; objective values
    compare within relative ``tolerance``."""
    failures = []
    want = {p["key"]: p["values"] for p in golden["frontier"]}
    have = {p["key"]: p["values"] for p in report["frontier"]}
    for k in sorted(set(want) - set(have)):
        failures.append(f"frontier point {k!r} missing from this run")
    for k in sorted(set(have) - set(want)):
        failures.append(f"unexpected frontier point {k!r}")
    for k in sorted(set(want) & set(have)):
        for o, wv in want[k].items():
            hv = have[k].get(o)
            if hv is None:
                failures.append(f"{k}: objective {o} missing")
                continue
            denom = max(abs(wv), 1e-30)
            if abs(hv - wv) / denom > tolerance:
                failures.append(
                    f"{k}: {o} drifted {wv} -> {hv} "
                    f"(rel {abs(hv - wv) / denom:.3e} > {tolerance})")
    for ck in _PINNED_COUNTERS:
        wv, hv = golden["counters"].get(ck), report["counters"].get(ck)
        if wv != hv:
            failures.append(f"counter {ck}: golden {wv} != run {hv}")
    return failures
