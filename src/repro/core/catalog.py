"""Data-driven system catalog (paper Table IV as checked-in JSON).

Every :class:`~repro.core.systems.System` the pipeline knows about is a
record in a catalog file — the shipped ones live in ``specs/systems/``
(one file per system, file stem = catalog id) and users point the CLI
(``--systems``) or a :class:`repro.api.Session` at their own.  A
:class:`SystemRegistry` merges catalogs with later paths (and API
registrations) taking precedence, remembers each entry's source file for
``python -m repro.campaign list``, and resolves the special id ``host``
to the calibrated host-CPU system.

The module is stdlib-only — spec validation loads the catalog in
environments without numpy/jax.
"""
from __future__ import annotations

import difflib
import json
import os

from .systems import System, host_system

#: the shipped catalog, relative to the repo root (editable install /
#: PYTHONPATH=src layouts); resolved lazily so a relocated package
#: degrades to an empty default catalog instead of an import error.
#: A wheel install has no specs/ tree next to the package — point
#: REPRO_SYSTEMS_DIR at a catalog directory there (unknown-system errors
#: say so).
_DEFAULT_DIR = (os.environ.get("REPRO_SYSTEMS_DIR")
                or os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "..", "..", "specs", "systems"))

_REQUIRED_FIELDS = ("id", "name", "peak_flops", "mem_bw", "mem_capacity",
                    "interconnect")


def validate_system_dict(d: dict, *, source: str = "<dict>") -> None:
    """Schema check for one catalog record; raises ValueError with the
    offending source on malformed entries (CI runs this over every
    shipped ``specs/systems/*.json`` via ``repro.campaign list --check``).
    """
    if not isinstance(d, dict):
        raise ValueError(f"{source}: system record must be an object, "
                         f"got {type(d).__name__}")
    missing = [k for k in _REQUIRED_FIELDS if k not in d]
    if missing:
        raise ValueError(f"{source}: system record missing {missing}")
    known = set(_REQUIRED_FIELDS) | {
        "mxu_rows", "mxu_cols", "n_mxu", "clock_hz", "vmem_bytes",
        "kernel_overhead_s", "cost_per_hour", "tdp_watts"}
    unknown = sorted(set(d) - known)
    if unknown:
        raise ValueError(f"{source}: unknown system fields {unknown}")
    for k in ("cost_per_hour", "tdp_watts"):
        if k in d and d[k] is not None and not (
                isinstance(d[k], (int, float)) and d[k] > 0):
            raise ValueError(f"{source}: {k} must be a positive number")
    pf = d["peak_flops"]
    if (not isinstance(pf, dict) or not pf
            or not all(isinstance(v, (int, float)) and v > 0
                       for v in pf.values())):
        raise ValueError(
            f"{source}: peak_flops must be a non-empty dtype -> FLOP/s map")
    for k in ("mem_bw", "mem_capacity"):
        if not (isinstance(d[k], (int, float)) and d[k] > 0):
            raise ValueError(f"{source}: {k} must be a positive number")
    ic = d["interconnect"]
    if not isinstance(ic, dict) or "kind" not in ic or "link_bw" not in ic:
        raise ValueError(
            f"{source}: interconnect needs at least kind and link_bw")
    ic_known = {"kind", "link_bw", "link_latency", "links_per_device",
                "params"}
    ic_unknown = sorted(set(ic) - ic_known)
    if ic_unknown:
        raise ValueError(
            f"{source}: unknown interconnect fields {ic_unknown}")
    if not (isinstance(ic["link_bw"], (int, float)) and ic["link_bw"] > 0):
        raise ValueError(f"{source}: interconnect.link_bw must be positive")
    if "params" in ic and not isinstance(ic["params"], dict):
        raise ValueError(f"{source}: interconnect.params must be an object")


class SystemRegistry:
    """id -> :class:`System` catalog with source tracking and scoping.

    ``parent`` lookups make a session registry an overlay over the
    shipped default catalog: local registrations and loaded catalogs
    shadow (or extend) the defaults without mutating them.
    """

    def __init__(self, paths: list[str] | tuple = (),
                 parent: "SystemRegistry | None" = None):
        self.parent = parent
        self._systems: dict[str, System] = {}
        self._sources: dict[str, str] = {}
        for p in paths:
            self.load_path(p)

    # ---------------------------- registration ----------------------------

    def register(self, sid: str, system: System | dict, *,
                 source: str = "<api>", replace: bool = False) -> System:
        """Add one system under catalog id ``sid`` (dicts are validated
        and converted).  Within one registry a duplicate id is an error
        unless ``replace=True``; shadowing a *parent* entry is allowed —
        that is how a user catalog overrides a shipped record."""
        sid = sid.lower()
        if isinstance(system, dict):
            d = dict(system)
            d.pop("id", None)
            validate_system_dict({"id": sid, **d}, source=source)
            system = System.from_dict(d)
        if sid in self._systems and not replace:
            raise ValueError(
                f"system {sid!r} already registered "
                f"(from {self._sources.get(sid, '<api>')}); pass "
                "replace=True to override it")
        if sid == "host":
            raise ValueError(
                "system id 'host' is reserved for the calibrated host CPU")
        self._systems[sid] = system
        self._sources[sid] = source
        return system

    def load_file(self, path: str, *, replace: bool = True) -> str:
        """Load one catalog record file; returns the registered id."""
        with open(path) as f:
            try:
                d = json.load(f)
            except json.JSONDecodeError as e:
                raise ValueError(f"{path}: not valid JSON: {e}") from None
        validate_system_dict(d, source=path)
        sid = str(d.pop("id"))
        self.register(sid, System.from_dict(d), source=path,
                      replace=replace)
        return sid

    def load_path(self, path: str) -> list[str]:
        """Load a catalog file, or every ``*.json`` in a directory
        (sorted, so later files win deterministically on duplicate ids);
        returns the registered ids."""
        if os.path.isdir(path):
            ids = []
            for name in sorted(os.listdir(path)):
                if name.endswith(".json"):
                    ids.append(self.load_file(os.path.join(path, name)))
            return ids
        return [self.load_file(path)]

    # ------------------------------ queries ------------------------------

    def names(self) -> list[str]:
        """Every known catalog id (parents included), sorted; the special
        id ``host`` is not listed — it is computed, not cataloged."""
        seen = set(self._systems)
        if self.parent is not None:
            seen.update(self.parent.names())
        return sorted(seen)

    def __contains__(self, name: str) -> bool:
        n = name.lower()
        return (n == "host" or n in self._systems
                or (self.parent is not None and name in self.parent))

    def get(self, name: str) -> System:
        """The system for a catalog id (``host`` -> calibrated host CPU);
        unknown ids raise with the live catalog and a did-you-mean."""
        n = name.lower()
        if n == "host":
            return host_system()
        if n in self._systems:
            return self._systems[n]
        if self.parent is not None and name in self.parent:
            return self.parent.get(name)
        raise KeyError(self.unknown_message(name))

    def unknown_message(self, name) -> str:
        have = ["host", *self.names()]
        msg = f"unknown system {name!r}; have {have}"
        close = difflib.get_close_matches(str(name).lower(), have, n=1)
        if close:
            msg += f" — did you mean {close[0]!r}?"
        elif len(have) == 1 and not os.path.isdir(_DEFAULT_DIR):
            # empty default catalog: the package is installed without the
            # repo's specs/ tree next to it
            msg += (f" (no system catalog found at {_DEFAULT_DIR!r} — "
                    "set REPRO_SYSTEMS_DIR, pass --systems, or run from "
                    "the repo checkout)")
        return msg

    def source(self, sid: str) -> str:
        """Where a catalog entry came from (file path or ``<api>``)."""
        n = sid.lower()
        if n in self._sources:
            return self._sources[n]
        if self.parent is not None:
            return self.parent.source(sid)
        raise KeyError(self.unknown_message(sid))

    def as_dict(self) -> dict[str, System]:
        """id -> System snapshot of the whole catalog (parents merged,
        local entries winning) — the back-compat ``SYSTEMS`` surface."""
        out = self.parent.as_dict() if self.parent is not None else {}
        out.update(self._systems)
        return out

    def local_systems(self) -> dict[str, System]:
        """This registry's own (non-inherited) entries — what a session
        ships to process-pool campaign workers."""
        return dict(self._systems)

    def scope(self) -> "SystemRegistry":
        """A child registry: local catalogs/registrations, parent fallback."""
        return SystemRegistry(parent=self)


_DEFAULT: SystemRegistry | None = None


def default_registry() -> SystemRegistry:
    """The shipped catalog (``specs/systems/``), loaded once per process."""
    global _DEFAULT
    if _DEFAULT is None:
        reg = SystemRegistry()
        if os.path.isdir(_DEFAULT_DIR):
            reg.load_path(_DEFAULT_DIR)
        _DEFAULT = reg
    return _DEFAULT
