# The paper's primary contribution: a StableHLO-based cross-architecture,
# cross-fidelity performance-modeling methodology (HeSPaS).  Subpackages:
#   ir/         unified workload representation (StableHLO-MLIR + HLO text)
#   slicing/    linear + dependency-aware compute/comm splitting
#   estimators/ Compute API: analytical / profiling / systolic backends
#   network/    topology-aware collective + scheduler simulation
#   trace/      Chakra-style COMP/COMM trace graphs
#   systems.py  hardware descriptions (GPUs, TPUs, host)
#   pipeline.py end-to-end export -> slice -> estimate -> netsim driver
