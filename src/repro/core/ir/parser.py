"""Front ends: StableHLO-MLIR text and post-SPMD HLO text -> Program.

Two textual dialects flow through the methodology (paper §III-B):

* the *raw export*  — ``jax.jit(f).lower(...).as_text()`` — StableHLO MLIR with
  ``sdy`` sharding annotations, global shapes, collectives only if the program
  used shard_map / explicit collectives;
* the *optimized representation* — ``lowered.compile().as_text()`` — XLA's
  SPMD-partitioned, fused, optimized HLO with per-device shapes and explicit
  ``all-reduce``/``all-gather``/... ops.  This plays the role of the paper's
  hlo-opt pipeline output ("compiler effects visible to the model").

Both are parsed into the same :class:`repro.core.ir.graph.Program`.
"""
from __future__ import annotations

import json
import re

from .graph import OpNode, Program
from .types import TensorType, hlo_types_in, mlir_types_in, parse_mlir_tensor

# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

# DOTALL: block comments may span lines (jax metadata dumps do); without it
# a multi-line /* ... */ survives stripping and corrupts the next op line
_COMMENT_RE = re.compile(r"/\*.*?\*/", re.DOTALL)
_SSA_RE = re.compile(r"%[\w.\-#]+")

# HLO opcode -> normalized mnemonic
_HLO_NORMALIZE = {
    "dot": "dot_general",
    "all-reduce": "all_reduce", "all-gather": "all_gather",
    "reduce-scatter": "reduce_scatter", "all-to-all": "all_to_all",
    "collective-permute": "collective_permute",
    "collective-broadcast": "collective_broadcast",
    "all-reduce-start": "all_reduce", "all-gather-start": "all_gather",
    "collective-permute-start": "collective_permute",
    "all-reduce-done": "async_done", "all-gather-done": "async_done",
    "collective-permute-done": "async_done", "async-done": "async_done",
    "get-tuple-element": "get_tuple_element",
    "dynamic-slice": "dynamic_slice", "dynamic-update-slice": "dynamic_update_slice",
    "broadcast": "broadcast_in_dim", "rng-bit-generator": "rng_bit_generator",
    "select-and-scatter": "select_and_scatter", "reduce-window": "reduce_window",
    "batch-norm-training": "batch_norm_training", "batch-norm-grad": "batch_norm_grad",
    "custom-call": "custom_call",
}


def _strip_comments(text: str) -> str:
    return _COMMENT_RE.sub("", text)


def _parse_replica_groups(text: str) -> tuple[int, int] | None:
    """Return ``(num_groups, group_size)`` from any textual form.

    Accepted grammar (all three forms the XLA/StableHLO printers emit),
    tried in this order — first match wins:

    1. HLO iota form — no whitespace tolerated (matches the printer)::

           replica_groups=[G,S]<=[N]            ->  (G, S)

    2. HLO explicit form — groups are ``{...}`` lists of device ids;
       whitespace is tolerated *between* groups but not around the
       ``replica_groups=`` key; the group size is taken from the first
       group (XLA emits uniform groups), empty first group counts as 1::

           replica_groups={{0,1,2,3},{4,5,6,7}} ->  (2, 4)

    3. MLIR dense form — whitespace tolerated around ``=`` and ``:``;
       the shape is read from the ``tensor<GxSxi64>`` type, not the
       elements; a ``tensor<0x0xi64>`` (empty groups) yields None::

           replica_groups = dense<[[0, 1], [2, 3]]> : tensor<2x2xi64>
                                                 ->  (2, 2)

    Text with none of these (or a malformed variant) yields None — the
    op is then modeled without a group split.  Comments never reach this
    function: both front ends strip ``/* ... */`` before line handling.
    """
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]", text)
    if m:
        return int(m.group(1)), int(m.group(2))
    m = re.search(r"replica_groups=\{(\{[^}]*\}(?:,\s*\{[^}]*\})*)\}", text)
    if m:
        groups = re.findall(r"\{([^}]*)\}", m.group(1))
        if groups:
            size = len([x for x in groups[0].split(",") if x.strip() != ""])
            return len(groups), max(size, 1)
    m = re.search(r"replica_groups\s*=\s*dense<[^>]*>\s*:\s*tensor<(\d+)x(\d+)xi64>", text)
    if m:
        return int(m.group(1)), int(m.group(2))
    m = re.search(r"replica_groups\s*=\s*dense<[^>]*>\s*:\s*tensor<0x0xi64>", text)
    if m:
        return None
    return None


def _parse_dims_pair(text: str, key: str) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Parse MLIR ``key = [a, b] x [c, d]`` -> ((a,b),(c,d))."""
    m = re.search(key + r"\s*=\s*\[([\d, ]*)\]\s*x\s*\[([\d, ]*)\]", text)
    if not m:
        return (), ()
    l = tuple(int(x) for x in m.group(1).split(",") if x.strip())
    r = tuple(int(x) for x in m.group(2).split(",") if x.strip())
    return l, r


def _parse_hlo_dims(text: str, key: str) -> tuple[int, ...]:
    m = re.search(key + r"=\{([\d,]*)\}", text)
    if not m:
        return ()
    return tuple(int(x) for x in m.group(1).split(",") if x.strip())


# ---------------------------------------------------------------------------
# StableHLO MLIR text parser
# ---------------------------------------------------------------------------

_MLIR_OP_RE = re.compile(
    r"^\s*(?:(%[\w.\-#]+(?::\d+)?(?:\s*,\s*%[\w.\-#]+)*)\s*=\s*)?"  # results
    r'("?)([\w]+\.[\w]+|call|return)\2'                              # mnemonic
)
_MLIR_FUNC_RE = re.compile(r"^\s*func\.func\s+(?:public\s+|private\s+)?@([\w.\-]+)\((.*)$")


def _balance(line: str) -> int:
    bal = 0
    in_str = False
    prev = ""
    for ch in line:
        if ch == '"' and prev != "\\":
            in_str = not in_str
        elif not in_str:
            if ch in "{(":
                bal += 1
            elif ch in "})":
                bal -= 1
        prev = ch
    return bal


class _MlirParser:
    def __init__(self, text: str):
        self.lines = _strip_comments(text).splitlines()
        self.uid = 0

    def parse(self) -> Program:
        functions: dict[str, list[OpNode]] = {}
        meta: dict = {}
        m = re.search(r"mhlo.num_partitions = (\d+)", self.lines[0] if self.lines else "")
        if m:
            meta["num_partitions"] = int(m.group(1))
        mesh_m = re.search(r"sdy.mesh @\w+ = <\[(.*?)\]>", "\n".join(self.lines[:8]))
        if mesh_m:
            axes = re.findall(r'"(\w+)"=(\d+)', mesh_m.group(1))
            meta["mesh"] = {name: int(size) for name, size in axes}
        i = 0
        entry_name = None
        func_raw: dict[str, str] = {}
        meta["func_raw"] = func_raw
        while i < len(self.lines):
            fm = _MLIR_FUNC_RE.match(self.lines[i])
            if fm:
                name = fm.group(1)
                start = i
                args = [(a, parse_mlir_tensor(t))
                        for a, t in re.findall(
                            r"(%[\w.\-]+):\s*tensor<([^>]*)>", self.lines[i])]
                body, i = self._collect_region_lines(i)
                functions[name] = self._parse_ops(body)
                func_raw[name] = "\n".join(self.lines[start:i])
                meta.setdefault("func_args", {})[name] = args
                if entry_name is None or name == "main":
                    entry_name = name if entry_name is None or name == "main" else entry_name
            else:
                i += 1
        entry = functions.get("main") or (functions[entry_name] if entry_name else [])
        return Program(entry=entry, functions=functions, dialect="stablehlo", meta=meta)

    def _collect_region_lines(self, start: int) -> tuple[list[str], int]:
        """Collect lines of a brace-balanced block starting at ``start``.

        Returns the interior lines (everything after the opening line, up to
        but excluding the closing line at balance zero) and the next index.
        """
        bal = _balance(self.lines[start])
        i = start + 1
        body: list[str] = []
        while i < len(self.lines) and bal > 0:
            bal += _balance(self.lines[i])
            if bal > 0:
                body.append(self.lines[i])
            i += 1
        return body, i

    def _parse_ops(self, lines: list[str]) -> list[OpNode]:
        ops: list[OpNode] = []
        i = 0
        while i < len(lines):
            line = lines[i]
            om = _MLIR_OP_RE.match(line)
            if not om:
                i += 1
                continue
            # collect full (possibly multi-line, region-carrying) op text
            bal = _balance(line)
            block = [line]
            j = i + 1
            while bal > 0 and j < len(lines):
                bal += _balance(lines[j])
                block.append(lines[j])
                j += 1
            # pretty-printed `while` has a balanced header; its regions start
            # on the following ` cond {` line — pull them into the block
            if "while" in line and j < len(lines) and re.match(r"^\s*cond\s*\{", lines[j]):
                rbal = _balance(lines[j])
                block.append(lines[j])
                j += 1
                while rbal > 0 and j < len(lines):
                    rbal += _balance(lines[j])
                    block.append(lines[j])
                    j += 1
            op = self._make_op(om, block)
            if op is not None:
                ops.append(op)
            i = j if j > i + 1 else i + 1
        return ops

    def _make_op(self, om: re.Match, block: list[str]) -> OpNode | None:
        header = block[0]
        raw = "\n".join(block)
        results_txt = om.group(1) or ""
        mnem = om.group(3)
        if mnem.startswith(("stablehlo.", "mhlo.", "chlo.", "sdy.", "arith.", "func.", "tf.")):
            op_name = mnem.split(".", 1)[1]
        else:
            op_name = mnem
        if op_name in ("return",):
            return None
        # results: "%3:2" form or "%a, %b" form
        results: list[str] = []
        for tok in results_txt.split(","):
            tok = tok.strip()
            if not tok:
                continue
            if ":" in tok:
                base, n = tok.split(":")
                results.extend(f"{base}#{k}" for k in range(int(n)))
                results.append(base)
            else:
                results.append(tok)
        # operand names: SSA ids on the header after '=' and before signature
        after = header.split("=", 1)[1] if "=" in header and results_txt else header
        sig_idx = after.rfind(" : ")
        operand_zone = after[:sig_idx] if sig_idx != -1 else after
        operands = tuple(t for t in _SSA_RE.findall(operand_zone) if t not in results)
        # types
        operand_types, result_types = self._signature_types(header)
        # uniform-typed ops (`%c = stablehlo.add %a, %b : tensor<..>`) list the
        # shared type once; replicate it per operand for byte accounting
        if len(operand_types) == 1 and len(operands) > 1 and " -> " not in header:
            operand_types = operand_types * len(operands)
        attrs: dict = {"header": header}
        if op_name == "dot_general":
            lb, rb = _parse_dims_pair(header, "batching_dims")
            lc, rc = _parse_dims_pair(header, "contracting_dims")
            attrs.update(lhs_batch=lb, rhs_batch=rb, lhs_contract=lc, rhs_contract=rc)
        if op_name == "convolution":
            fg = re.search(r"feature_group_count\s*=\s*(\d+)", raw)
            attrs["feature_group_count"] = int(fg.group(1)) if fg else 1
            dn = re.search(r"dim_numbers\s*=\s*(\[[^\]]*\]x\[[^\]]*\]->\[[^\]]*\])", header)
            if dn:
                attrs["dim_labels"] = dn.group(1)
        rg = _parse_replica_groups(raw)
        if rg:
            attrs["replica_groups"] = rg
        if "channel_handle" in raw or "channel_id" in raw:
            attrs["channel"] = True
        # gather/scatter/reduce dims, all_gather dim
        gd = re.search(r"all_gather_dim\s*=\s*(\d+)", raw)
        if gd:
            attrs["gather_dim"] = int(gd.group(1))
        node = OpNode(
            uid=self._next_uid(), results=tuple(results), op=op_name,
            operands=operands, operand_types=tuple(operand_types),
            result_types=tuple(result_types), attrs=attrs, raw=raw,
        )
        if op_name == "call" or mnem == "func.call":
            callee = re.search(r"@([\w.\-]+)", header)
            if callee:
                node.called = (callee.group(1),)
        # nested regions (while / reduce / all_reduce bodies ...)
        if len(block) > 1:
            interior = block[1:]
            # drop the final closing line(s)
            region_ops = self._parse_ops(interior)
            if region_ops:
                if op_name == "while":
                    cond_ops, body_ops = self._split_while(interior)
                    node.regions = [cond_ops, body_ops]
                    node.trip_count = self._trip_count(block)
                else:
                    node.regions = [region_ops]
        return node

    def _split_while(self, interior: list[str]) -> tuple[list[OpNode], list[OpNode]]:
        """Split pretty-printed while into cond/body regions on '} do {'."""
        depth = 0
        split = None
        for idx, line in enumerate(interior):
            if depth == 1 and re.match(r"^\s*\}\s*do\s*\{", line):
                split = idx
                break
            depth += _balance(line)
        if split is None:
            return [], self._parse_ops(interior)
        return self._parse_ops(interior[:split]), self._parse_ops(interior[split + 1:])

    def _trip_count(self, block: list[str]) -> int:
        """Heuristic: largest small-integer constant in the cond region."""
        text = "\n".join(block)
        best = 1
        for m in re.finditer(r"dense<(\d+)>\s*:\s*tensor<i(?:32|64)>", text):
            v = int(m.group(1))
            if 1 < v <= 1_000_000:
                best = max(best, v)
        return best

    def _signature_types(self, header: str) -> tuple[list[TensorType], list[TensorType]]:
        sig_idx = header.rfind(" : ")
        if sig_idx == -1:
            return [], mlir_types_in(header)
        sig = header[sig_idx + 3:]
        if "->" in sig:
            lhs, rhs = sig.split("->", 1)
            return mlir_types_in(lhs), mlir_types_in(rhs)
        ts = mlir_types_in(sig)
        return ts, ts

    def _next_uid(self) -> int:
        self.uid += 1
        return self.uid


# ---------------------------------------------------------------------------
# HLO text parser (post-SPMD, optimized)
# ---------------------------------------------------------------------------

_HLO_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)(?:\.v\d+)?\s*\(.*\)\s*->\s*.*\{\s*$")
_HLO_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(.*?\)|[a-z]\w*\[[\d,]*\](?:\{[^}]*\})?|[a-z]\w*\[\])\s*"
    r"([\w\-]+)\((.*?)\)(.*)$"
)


class _HloParser:
    def __init__(self, text: str):
        self.text = _strip_comments(text)
        self.uid = 0

    def parse(self) -> Program:
        meta: dict = {}
        m = re.search(r"num_partitions=(\d+)", self.text)
        if m:
            meta["num_partitions"] = int(m.group(1))
        computations: dict[str, list[OpNode]] = {}
        entry_name = None
        lines = self.text.splitlines()
        i = 0
        while i < len(lines):
            cm = _HLO_COMP_RE.match(lines[i])
            if cm:
                is_entry, name = bool(cm.group(1)), cm.group(2)
                body: list[str] = []
                i += 1
                while i < len(lines) and not lines[i].startswith("}"):
                    body.append(lines[i])
                    i += 1
                computations[name] = self._parse_ops(body)
                if is_entry:
                    entry_name = name
            i += 1
        entry = computations.get(entry_name, [])
        prog = Program(entry=entry, functions=computations, dialect="hlo", meta=meta)
        self._attach_called_regions(prog)
        return prog

    def _parse_ops(self, lines: list[str]) -> list[OpNode]:
        ops = []
        for line in lines:
            om = _HLO_OP_RE.match(line)
            if not om:
                continue
            _, name, type_txt, opcode, operand_txt, attr_txt = om.groups()
            op_name = _HLO_NORMALIZE.get(opcode, opcode.replace("-", "_"))
            result_types = tuple(hlo_types_in(type_txt))
            operands = tuple(_SSA_RE.findall(operand_txt)) or tuple(
                t for t in re.findall(r"[\w.\-]+", operand_txt)
                if not re.fullmatch(r"[a-z]\w*\[[\d,]*\]", t)
            )
            attrs: dict = {}
            if op_name == "dot_general":
                attrs["lhs_contract"] = _parse_hlo_dims(attr_txt, "lhs_contracting_dims")
                attrs["rhs_contract"] = _parse_hlo_dims(attr_txt, "rhs_contracting_dims")
                attrs["lhs_batch"] = _parse_hlo_dims(attr_txt, "lhs_batch_dims")
                attrs["rhs_batch"] = _parse_hlo_dims(attr_txt, "rhs_batch_dims")
            if op_name == "convolution":
                fg = re.search(r"feature_group_count=(\d+)", attr_txt)
                attrs["feature_group_count"] = int(fg.group(1)) if fg else 1
                dl = re.search(r"dim_labels=([\w>\-_]+)", attr_txt)
                if dl:
                    attrs["dim_labels"] = dl.group(1)
            rg = _parse_replica_groups(attr_txt)
            if rg:
                attrs["replica_groups"] = rg
            if opcode.endswith("-start"):
                attrs["async_start"] = True
            if op_name == "async_done":
                attrs["async_done"] = True
            md = re.search(r'op_name="([^"]*)"', attr_txt)
            if md:
                attrs["op_name"] = md.group(1)
            called = tuple(re.findall(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)", attr_txt))
            node = OpNode(
                uid=self._next_uid(), results=(f"%{name}",), op=op_name,
                operands=operands, operand_types=(), result_types=result_types,
                attrs=attrs, raw=line, called=called,
            )
            if op_name == "while":
                tc = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', attr_txt)
                if tc:
                    node.trip_count = int(tc.group(1))
                else:
                    node.trip_count = 0  # resolve later from condition comp
            ops.append(node)
        # operand types resolvable from defs within the computation
        defs = {r: op for op in ops for r in op.results}
        for op in ops:
            otypes = []
            for o in op.operands:
                d = defs.get(o)
                otypes.append(d.result_types[0] if d and d.result_types else None)
            op.operand_types = tuple(t for t in otypes if t is not None)
        return ops

    def _attach_called_regions(self, prog: Program) -> None:
        """Attach fusion/while called computations as regions; fix trip counts.

        Iterates every computation (not just the entry walk) so fusions inside
        while bodies get their called bodies attached too.  Computations form a
        DAG in HLO, so attachment cannot cycle.
        """
        all_ops = [op for comp in prog.functions.values() for op in comp]
        for op in all_ops:
            if not op.called:
                continue
            if op.op == "while":
                cond = prog.resolve(op.called[0]) if len(op.called) > 0 else None
                body = prog.resolve(op.called[1]) if len(op.called) > 1 else None
                # 'condition=' regex ordering: condition first, then body
                op.regions = [r for r in (cond, body) if r is not None]
                if op.trip_count == 0:
                    op.trip_count = self._cond_trip_count(cond) if cond else 1
            elif op.op in ("fusion", "call", "map", "reduce", "reduce_window",
                           "scatter", "select_and_scatter", "sort", "all_reduce",
                           "reduce_scatter", "custom_call", "conditional"):
                regions = [prog.resolve(c) for c in op.called]
                op.regions = [r for r in regions if r]

    @staticmethod
    def _cond_trip_count(cond: list[OpNode]) -> int:
        best = 1
        for op in cond:
            m = re.search(r"constant\((\d+)\)", op.raw)
            if m:
                v = int(m.group(1))
                if 1 < v <= 1_000_000:
                    best = max(best, v)
        return best

    def _next_uid(self) -> int:
        self.uid += 1
        return self.uid


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

#: which front end :func:`parse`/:func:`parse_stablehlo`/:func:`parse_hlo`
#: use when no explicit ``frontend=`` is given.  ``"streaming"`` is the
#: single-pass tokenizer front end (:mod:`repro.core.ir.streaming`);
#: ``"legacy"`` is the multi-pass regex parser in this module, kept as the
#: independent reference implementation for the differential test harness
#: (tests/test_parser_diff.py asserts node-for-node Program equality).
DEFAULT_FRONTEND = "streaming"


def _resolve_frontend(frontend: str | None) -> str:
    fe = frontend or DEFAULT_FRONTEND
    if fe not in ("streaming", "legacy"):
        raise ValueError(f"unknown parser frontend {fe!r} "
                         "(expected 'streaming' or 'legacy')")
    return fe


def parse_stablehlo(text: str, frontend: str | None = None) -> Program:
    """Parse StableHLO-MLIR text (``lowered.as_text()``)."""
    if _resolve_frontend(frontend) == "streaming":
        from .streaming import parse_stablehlo_streaming
        return parse_stablehlo_streaming(text)
    return _MlirParser(text).parse()


def parse_hlo(text: str, frontend: str | None = None) -> Program:
    """Parse (optimized, possibly SPMD-partitioned) HLO text."""
    if _resolve_frontend(frontend) == "streaming":
        from .streaming import parse_hlo_streaming
        return parse_hlo_streaming(text)
    return _HloParser(text).parse()


#: calls to :func:`parse` in this process — parsing multi-MB HLO text is
#: the single most expensive per-workload cost, so the campaign engine's
#: plan store memoizes it per (workload, fidelity); tests and benchmarks
#: assert on this counter
PARSE_CALLS = 0


def parse(text: str, frontend: str | None = None) -> Program:
    """Auto-detect dialect."""
    global PARSE_CALLS
    PARSE_CALLS += 1
    head = text[:4096]
    if "HloModule" in head:
        return parse_hlo(text, frontend=frontend)
    return parse_stablehlo(text, frontend=frontend)
