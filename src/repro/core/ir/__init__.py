"""Unified IR front end: StableHLO-MLIR and HLO text -> one op graph."""
from .collectives import CommSpec, collect_collectives, comm_spec, total_collective_bytes
from .graph import COLLECTIVE_OPS, OpNode, Program, dependency_edges
from .opcost import Cost, op_cost, program_cost
from .parser import parse, parse_hlo, parse_stablehlo
from .types import DTYPE_BYTES, TensorType

__all__ = [
    "CommSpec", "collect_collectives", "comm_spec", "total_collective_bytes",
    "COLLECTIVE_OPS", "OpNode", "Program", "dependency_edges",
    "Cost", "op_cost", "program_cost",
    "parse", "parse_hlo", "parse_stablehlo",
    "DTYPE_BYTES", "TensorType",
]
