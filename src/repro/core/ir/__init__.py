"""Unified IR front end: StableHLO-MLIR and HLO text -> one op graph."""
from .collectives import CommSpec, collect_collectives, comm_spec, total_collective_bytes
from .graph import COLLECTIVE_OPS, OpNode, Program, dependency_edges
from .opcost import Cost, op_cost, program_cost
from .arrays import ProgramArrays, RegionArrays, build_program_arrays, build_region_arrays
from .diff import assert_programs_equal, program_diff
from .parser import parse, parse_hlo, parse_stablehlo
from .streaming import parse_hlo_streaming, parse_stablehlo_streaming
from .types import DTYPE_BYTES, TensorType

__all__ = [
    "CommSpec", "collect_collectives", "comm_spec", "total_collective_bytes",
    "COLLECTIVE_OPS", "OpNode", "Program", "dependency_edges",
    "Cost", "op_cost", "program_cost",
    "parse", "parse_hlo", "parse_stablehlo",
    "parse_hlo_streaming", "parse_stablehlo_streaming",
    "program_diff", "assert_programs_equal",
    "ProgramArrays", "RegionArrays",
    "build_program_arrays", "build_region_arrays",
    "DTYPE_BYTES", "TensorType",
]
