"""Streaming front ends: single-pass tokenizer -> Program.

These parsers produce node-for-node the same :class:`Program` as the
legacy regex parsers in :mod:`repro.core.ir.parser` (same ops, operands,
types, attrs, regions, trip counts, raw text — everything except the
internal ``uid`` numbering, which is clean-sequential here where the
legacy MLIR parser burns uids on a discarded pre-parse of ``while``
interiors).  The equivalence is enforced by the differential harness in
``tests/test_parser_diff.py``, which drives every checked-in workload
text and randomized op lines through both front ends.

Where the speed comes from (same grammar, less work):

* one tokenization pass — line balance and op-header matches are
  computed once (:mod:`repro.core.ir.tokenize`) and nested regions are
  parsed over *index ranges* into the token lists, never re-scanned;
* ``str.count`` brace balancing for lines without string literals
  (the common case by far) instead of a per-character Python loop;
* interned type/signature tables — repeated ``tensor<...>`` bodies,
  whole trailing signatures, and HLO type columns parse once;
* containment-gated attribute regexes — ``replica_groups``/
  ``all_gather_dim``/``op_name``/``calls`` searches only run on lines
  that contain the key at all (the legacy parser runs them on every op);
* ``while`` interiors are split *before* parsing, so cond/body are each
  parsed exactly once (the legacy parser parses the interior twice).
"""
from __future__ import annotations

import re

from .graph import OpNode, Program
from .parser import (
    _HLO_COMP_RE,
    _HLO_NORMALIZE,
    _MLIR_FUNC_RE,
    _SSA_RE,
    _HloParser,
)
from .tokenize import (
    HloTokens,
    MlirTokens,
    hlo_types_interned,
    intern_tensor,
    mlir_signature_types,
    mlir_types_interned,
    strip_comments,
)

# ---------------------------------------------------------------------------
# precompiled attribute patterns (the legacy parser builds these per call)
# ---------------------------------------------------------------------------

_NUM_PARTS_MLIR_RE = re.compile(r"mhlo.num_partitions = (\d+)")
_MESH_RE = re.compile(r"sdy.mesh @\w+ = <\[(.*?)\]>")
_MESH_AXES_RE = re.compile(r'"(\w+)"=(\d+)')
_FUNC_ARG_RE = re.compile(r"(%[\w.\-]+):\s*tensor<([^>]*)>")
_COND_RE = re.compile(r"^\s*cond\s*\{")
_DO_RE = re.compile(r"^\s*\}\s*do\s*\{")
_DIMS_PAIR_TAIL = r"\s*=\s*\[([\d, ]*)\]\s*x\s*\[([\d, ]*)\]"
_BATCHING_RE = re.compile("batching_dims" + _DIMS_PAIR_TAIL)
_CONTRACTING_RE = re.compile("contracting_dims" + _DIMS_PAIR_TAIL)
_FG_MLIR_RE = re.compile(r"feature_group_count\s*=\s*(\d+)")
_DN_RE = re.compile(r"dim_numbers\s*=\s*(\[[^\]]*\]x\[[^\]]*\]->\[[^\]]*\])")
_GD_RE = re.compile(r"all_gather_dim\s*=\s*(\d+)")
_CALLEE_RE = re.compile(r"@([\w.\-]+)")
_TRIP_RE = re.compile(r"dense<(\d+)>\s*:\s*tensor<i(?:32|64)>")

_NUM_PARTS_HLO_RE = re.compile(r"num_partitions=(\d+)")
_HLO_DIMS_RES = {
    key: re.compile(key + r"=\{([\d,]*)\}")
    for key in ("lhs_contracting_dims", "rhs_contracting_dims",
                "lhs_batch_dims", "rhs_batch_dims")
}
_FG_HLO_RE = re.compile(r"feature_group_count=(\d+)")
_DL_RE = re.compile(r"dim_labels=([\w>\-_]+)")
_OPNAME_RE = re.compile(r'op_name="([^"]*)"')
_CALLED_RE = re.compile(r"(?:calls|to_apply|condition|body)=%?([\w.\-]+)")
_KTC_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_TOKEN_RE = re.compile(r"[\w.\-]+")

_MLIR_DIALECT_PREFIXES = ("stablehlo.", "mhlo.", "chlo.", "sdy.",
                         "arith.", "func.", "tf.")

#: mnemonic -> normalized op name (dialect prefix stripped); there are only
#: a handful of distinct mnemonics per module, so a dict hit replaces a
#: tuple-startswith + split per op
_MNEM_TABLE: dict[str, str] = {}

#: HLO opcode -> normalized mnemonic, growing over the _HLO_NORMALIZE seed
_OPCODE_TABLE: dict[str, str] = dict(_HLO_NORMALIZE)

#: "[a, b]"-interior -> parsed int tuple (dim lists repeat across layers)
_INTS_TABLE: dict[str, tuple[int, ...]] = {}

_NEW_NODE = OpNode.__new__


def _mnem_op_name(mnem: str) -> str:
    try:
        return _MNEM_TABLE[mnem]
    except KeyError:
        if mnem.startswith(_MLIR_DIALECT_PREFIXES):
            name = mnem.split(".", 1)[1]
        else:
            name = mnem
        _MNEM_TABLE[mnem] = name
        return name


def _ints(txt: str) -> tuple[int, ...]:
    try:
        return _INTS_TABLE[txt]
    except KeyError:
        v = tuple(int(x) for x in txt.split(",") if x.strip())
        if len(_INTS_TABLE) >= 1 << 16:
            _INTS_TABLE.clear()
        _INTS_TABLE[txt] = v
        return v


# _parse_replica_groups' four forms, precompiled, with the necessary
# substring of each form as a containment gate: a regex only runs when its
# gate is present, so a multi-line collective block pays one scan instead
# of up to four (the legacy helper re.searches every form in order)
_RG_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[\d+\]")
_RG_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\s*\{[^}]*\})*)\}")
_RG_GROUP_RE = re.compile(r"\{([^}]*)\}")
_RG_DENSE_RE = re.compile(
    r"replica_groups\s*=\s*dense<[^>]*>\s*:\s*tensor<(\d+)x(\d+)xi64>")


def _replica_groups(text: str) -> tuple[int, int] | None:
    """Gated :func:`parser._parse_replica_groups` — same grammar, same
    try-order, same result on every input (each gate is a substring the
    corresponding regex cannot match without)."""
    if "]<=[" in text:
        m = _RG_IOTA_RE.search(text)
        if m:
            return int(m.group(1)), int(m.group(2))
    if "replica_groups={" in text:
        m = _RG_EXPLICIT_RE.search(text)
        if m:
            groups = _RG_GROUP_RE.findall(m.group(1))
            if groups:
                size = len([x for x in groups[0].split(",") if x.strip() != ""])
                return len(groups), max(size, 1)
    if "dense<" in text:
        m = _RG_DENSE_RE.search(text)
        if m:
            return int(m.group(1)), int(m.group(2))
    return None


def _dims_pair(rex: re.Pattern, text: str, pos: int = 0,
               endpos: int | None = None) -> tuple[tuple[int, ...], tuple[int, ...]]:
    m = rex.search(text, pos, len(text) if endpos is None else endpos)
    if not m:
        return (), ()
    return _ints(m.group(1)), _ints(m.group(2))


def _hlo_dims(key: str, text: str) -> tuple[int, ...]:
    m = _HLO_DIMS_RES[key].search(text)
    if not m:
        return ()
    return _ints(m.group(1))


# ---------------------------------------------------------------------------
# StableHLO-MLIR streaming parser
# ---------------------------------------------------------------------------

class _StreamingMlir:
    def __init__(self, text: str):
        self.toks = MlirTokens(strip_comments(text))
        self.uid = 0

    def parse(self) -> Program:
        lines = self.toks.lines
        functions: dict[str, list[OpNode]] = {}
        meta: dict = {}
        m = _NUM_PARTS_MLIR_RE.search(lines[0] if lines else "")
        if m:
            meta["num_partitions"] = int(m.group(1))
        mesh_m = _MESH_RE.search("\n".join(lines[:8]))
        if mesh_m:
            axes = _MESH_AXES_RE.findall(mesh_m.group(1))
            meta["mesh"] = {name: int(size) for name, size in axes}
        i = 0
        n = len(lines)
        entry_name = None
        func_raw: dict[str, str] = {}
        meta["func_raw"] = func_raw
        while i < n:
            line = lines[i]
            fm = _MLIR_FUNC_RE.match(line) if "func.func" in line else None
            if fm:
                name = fm.group(1)
                start = i
                args = [(a, intern_tensor(t))
                        for a, t in _FUNC_ARG_RE.findall(line)]
                body_lo, body_hi, i = self._collect_region_range(i)
                functions[name] = self._parse_ops(body_lo, body_hi)
                func_raw[name] = "\n".join(lines[start:i])
                meta.setdefault("func_args", {})[name] = args
                if entry_name is None or name == "main":
                    entry_name = name
            else:
                i += 1
        entry = functions.get("main") or (functions[entry_name] if entry_name else [])
        return Program(entry=entry, functions=functions,
                       dialect="stablehlo", meta=meta)

    def _collect_region_range(self, start: int) -> tuple[int, int, int]:
        """Index-range form of the legacy ``_collect_region_lines``: the
        interior of the brace-balanced block opening at ``start`` is
        ``lines[lo:hi]``; returns ``(lo, hi, next_i)``."""
        bals = self.toks.bals
        n = len(bals)
        bal = bals[start]
        i = start + 1
        hi = i
        while i < n and bal > 0:
            bal += bals[i]
            i += 1
            if bal > 0:
                hi = i
        return start + 1, hi, i

    def _parse_ops(self, lo: int, hi: int) -> list[OpNode]:
        ops: list[OpNode] = []
        lines, bals, oms = self.toks.lines, self.toks.bals, self.toks.oms
        i = lo
        while i < hi:
            om = oms[i]
            if om is None:
                i += 1
                continue
            bal = bals[i]
            j = i + 1
            while bal > 0 and j < hi:
                bal += bals[j]
                j += 1
            # pretty-printed `while`: balanced header, regions start on the
            # following ` cond {` line — pull them into the block
            if "while" in lines[i] and j < hi and _COND_RE.match(lines[j]):
                rbal = bals[j]
                j += 1
                while rbal > 0 and j < hi:
                    rbal += bals[j]
                    j += 1
            op = self._make_op(om, i, j)
            if op is not None:
                ops.append(op)
            i = j if j > i + 1 else i + 1
        return ops

    def _make_op(self, om: re.Match, lo: int, hi: int) -> OpNode | None:
        lines = self.toks.lines
        header = lines[lo]
        raw = header if hi - lo == 1 else "\n".join(lines[lo:hi])
        results_txt, mnem = om.group(1, 3)
        results_txt = results_txt or ""
        op_name = _mnem_op_name(mnem)
        if op_name == "return":
            return None
        if not results_txt:
            results: tuple[str, ...] = ()
        elif ":" not in results_txt and "," not in results_txt:
            results = (results_txt,)
        else:
            rlist: list[str] = []
            for tok in results_txt.split(","):
                tok = tok.strip()
                if not tok:
                    continue
                if ":" in tok:
                    base, nres = tok.split(":")
                    rlist.extend(f"{base}#{k}" for k in range(int(nres)))
                    rlist.append(base)
                else:
                    rlist.append(tok)
            results = tuple(rlist)
        # the operand/attr zone is everything between the mnemonic (the
        # match end — the span before it holds only results/whitespace,
        # never an SSA use or attribute) and the trailing ` : ` signature
        # (types only); identical token set to the legacy split-on-`=`
        # slice since mnemonics contain no `%`
        hsig_idx = header.rfind(" : ")
        zone_lo = om.end()
        zone_hi = hsig_idx if hsig_idx != -1 else len(header)
        found = _SSA_RE.findall(header, zone_lo, zone_hi)
        operands = tuple([t for t in found if t not in results]) \
            if results else tuple(found)
        if hsig_idx == -1:
            operand_types: tuple = ()
            result_types = tuple(mlir_types_interned(header))
        else:
            operand_types, result_types = mlir_signature_types(
                header[hsig_idx + 3:])
        if len(operand_types) == 1 and len(operands) > 1 and " -> " not in header:
            operand_types = operand_types * len(operands)
        attrs: dict = {"header": header}
        if op_name == "dot_general":
            lb, rb = _dims_pair(_BATCHING_RE, header, zone_lo, zone_hi) \
                if "batching_dims" in header else ((), ())
            lc, rc = _dims_pair(_CONTRACTING_RE, header, zone_lo, zone_hi) \
                if "contracting_dims" in header else ((), ())
            attrs["lhs_batch"] = lb
            attrs["rhs_batch"] = rb
            attrs["lhs_contract"] = lc
            attrs["rhs_contract"] = rc
        if op_name == "convolution":
            fg = _FG_MLIR_RE.search(raw)
            attrs["feature_group_count"] = int(fg.group(1)) if fg else 1
            dn = _DN_RE.search(header)
            if dn:
                attrs["dim_labels"] = dn.group(1)
        if "replica_groups" in raw:
            rg = _replica_groups(raw)
            if rg:
                attrs["replica_groups"] = rg
        if "channel_handle" in raw or "channel_id" in raw:
            attrs["channel"] = True
        if "all_gather_dim" in raw:
            gd = _GD_RE.search(raw)
            if gd:
                attrs["gather_dim"] = int(gd.group(1))
        uid = self.uid = self.uid + 1
        # bypass the dataclass __init__: all eleven fields are assigned in
        # declaration order, so the node is indistinguishable from a
        # normally-constructed one (the differential harness compares every
        # field and would catch a drifted field list)
        node = _NEW_NODE(OpNode)
        node.__dict__ = {
            "uid": uid, "results": results, "op": op_name,
            "operands": operands, "operand_types": operand_types,
            "result_types": result_types, "attrs": attrs, "regions": [],
            "trip_count": 1, "raw": raw, "called": (),
        }
        if op_name == "call":  # covers bare `call` and `func.call`
            callee = _CALLEE_RE.search(header)
            if callee:
                node.called = (callee.group(1),)
        if hi - lo > 1:
            ilo, ihi = lo + 1, hi
            if op_name == "while":
                split = self._find_while_split(ilo, ihi)
                if split is None:
                    cond_ops: list[OpNode] = []
                    body_ops = self._parse_ops(ilo, ihi)
                else:
                    cond_ops = self._parse_ops(ilo, split)
                    body_ops = self._parse_ops(split + 1, ihi)
                if cond_ops or body_ops:
                    node.regions = [cond_ops, body_ops]
                    node.trip_count = self._trip_count(raw)
            else:
                region_ops = self._parse_ops(ilo, ihi)
                if region_ops:
                    node.regions = [region_ops]
        return node

    def _find_while_split(self, lo: int, hi: int) -> int | None:
        """Index of the '} do {' line at depth 1 within [lo, hi), if any."""
        lines, bals = self.toks.lines, self.toks.bals
        depth = 0
        for idx in range(lo, hi):
            if depth == 1 and _DO_RE.match(lines[idx]):
                return idx
            depth += bals[idx]
        return None

    @staticmethod
    def _trip_count(raw: str) -> int:
        """Heuristic: largest small-integer constant in the while block."""
        best = 1
        if "dense<" in raw:
            for m in _TRIP_RE.finditer(raw):
                v = int(m.group(1))
                if 1 < v <= 1_000_000:
                    best = max(best, v)
        return best

    def _next_uid(self) -> int:
        self.uid += 1
        return self.uid


# ---------------------------------------------------------------------------
# HLO streaming parser
# ---------------------------------------------------------------------------

class _StreamingHlo:
    def __init__(self, text: str):
        self.text = strip_comments(text)
        self.uid = 0

    def parse(self) -> Program:
        meta: dict = {}
        if "num_partitions=" in self.text:
            m = _NUM_PARTS_HLO_RE.search(self.text)
            if m:
                meta["num_partitions"] = int(m.group(1))
        toks = HloTokens(self.text)
        lines = toks.lines
        computations: dict[str, list[OpNode]] = {}
        entry_name = None
        i = 0
        n = len(lines)
        while i < n:
            line = lines[i]
            cm = _HLO_COMP_RE.match(line) if "{" in line else None
            if cm:
                is_entry, name = bool(cm.group(1)), cm.group(2)
                lo = i + 1
                i = lo
                while i < n and not lines[i].startswith("}"):
                    i += 1
                computations[name] = self._parse_ops(toks, lo, i)
                if is_entry:
                    entry_name = name
            i += 1
        entry = computations.get(entry_name, [])
        prog = Program(entry=entry, functions=computations,
                       dialect="hlo", meta=meta)
        self._attach_called_regions(prog)
        return prog

    def _parse_ops(self, toks: HloTokens, lo: int, hi: int) -> list[OpNode]:
        ops: list[OpNode] = []
        lines, oms = toks.lines, toks.oms
        for i in range(lo, hi):
            om = oms[i]
            if om is None:
                continue
            _, name, type_txt, opcode, operand_txt, attr_txt = om.groups()
            try:
                op_name = _OPCODE_TABLE[opcode]
            except KeyError:
                op_name = _OPCODE_TABLE[opcode] = opcode.replace("-", "_")
            result_types = hlo_types_interned(type_txt)
            # gate the SSA scan on a `%`; the legacy type-like fullmatch
            # filter is a provable no-op (word tokens cannot contain the `[`
            # the pattern requires), so the fallback is the plain token list
            operands = (tuple(_SSA_RE.findall(operand_txt))
                        if "%" in operand_txt else ())
            if not operands and operand_txt:
                operands = tuple(_TOKEN_RE.findall(operand_txt))
            attrs: dict = {}
            if op_name == "dot_general":
                attrs["lhs_contract"] = _hlo_dims("lhs_contracting_dims", attr_txt)
                attrs["rhs_contract"] = _hlo_dims("rhs_contracting_dims", attr_txt)
                attrs["lhs_batch"] = _hlo_dims("lhs_batch_dims", attr_txt)
                attrs["rhs_batch"] = _hlo_dims("rhs_batch_dims", attr_txt)
            if op_name == "convolution":
                fg = _FG_HLO_RE.search(attr_txt)
                attrs["feature_group_count"] = int(fg.group(1)) if fg else 1
                dl = _DL_RE.search(attr_txt)
                if dl:
                    attrs["dim_labels"] = dl.group(1)
            if "replica_groups" in attr_txt:
                rg = _replica_groups(attr_txt)
                if rg:
                    attrs["replica_groups"] = rg
            if opcode.endswith("-start"):
                attrs["async_start"] = True
            if op_name == "async_done":
                attrs["async_done"] = True
            if 'op_name="' in attr_txt:
                md = _OPNAME_RE.search(attr_txt)
                if md:
                    attrs["op_name"] = md.group(1)
            if ("calls" in attr_txt or "to_apply" in attr_txt
                    or "condition" in attr_txt or "body" in attr_txt):
                called = tuple(_CALLED_RE.findall(attr_txt))
            else:
                called = ()
            uid = self.uid = self.uid + 1
            # same __init__ bypass as the MLIR front end (see _make_op)
            node = _NEW_NODE(OpNode)
            node.__dict__ = {
                "uid": uid, "results": ("%" + name,), "op": op_name,
                "operands": operands, "operand_types": (),
                "result_types": result_types, "attrs": attrs, "regions": [],
                "trip_count": 1, "raw": lines[i], "called": called,
            }
            if op_name == "while":
                tc = _KTC_RE.search(attr_txt) if "known_trip_count" in attr_txt else None
                node.trip_count = int(tc.group(1)) if tc else 0
            ops.append(node)
        defs = {r: op for op in ops for r in op.results}
        get = defs.get
        for op in ops:
            if not op.operands:
                continue
            otypes = []
            for o in op.operands:
                d = get(o)
                if d is not None and d.result_types:
                    otypes.append(d.result_types[0])
            op.operand_types = tuple(otypes)
        return ops

    def _attach_called_regions(self, prog: Program) -> None:
        """Same semantics as the legacy ``_attach_called_regions`` /
        ``Program.resolve`` pair, with the fuzzy lookup precomputed: exact
        name first, else the first computation (in insertion order) whose
        name's leading dot-component matches."""
        exact = prog.functions
        prefix: dict[str, list[OpNode]] = {}
        for k, v in exact.items():
            p = k.split(".", 1)[0]
            if p not in prefix:
                prefix[p] = v

        def resolve(name: str) -> list[OpNode] | None:
            name = name.lstrip("%@")
            r = exact.get(name)
            return r if r is not None else prefix.get(name)

        for comp in prog.functions.values():
            for op in comp:
                if not op.called:
                    continue
                if op.op == "while":
                    cond = resolve(op.called[0]) if len(op.called) > 0 else None
                    body = resolve(op.called[1]) if len(op.called) > 1 else None
                    op.regions = [r for r in (cond, body) if r is not None]
                    if op.trip_count == 0:
                        op.trip_count = (_HloParser._cond_trip_count(cond)
                                         if cond else 1)
                elif op.op in ("fusion", "call", "map", "reduce",
                               "reduce_window", "scatter",
                               "select_and_scatter", "sort", "all_reduce",
                               "reduce_scatter", "custom_call",
                               "conditional"):
                    regions = [resolve(c) for c in op.called]
                    op.regions = [r for r in regions if r]

    def _next_uid(self) -> int:
        self.uid += 1
        return self.uid


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def parse_stablehlo_streaming(text: str) -> Program:
    """Single-pass parse of StableHLO-MLIR text."""
    return _StreamingMlir(text).parse()


def parse_hlo_streaming(text: str) -> Program:
    """Single-pass parse of (optimized, post-SPMD) HLO text."""
    return _StreamingHlo(text).parse()
