"""Single-pass streaming tokenizer for the MLIR/HLO text front ends.

The legacy parser (:mod:`repro.core.ir.parser`) re-scans text repeatedly:
every line is brace-balanced with a per-character Python loop, nested
region lines are regex-matched once per nesting level, and every
``tensor<...>`` / ``f32[...]`` type is re-parsed at each occurrence.  This
module makes one pass over the text and records, per line, everything the
parser needs later:

* the line text itself (round-trip property: joining the token texts with
  ``"\\n"`` reproduces the comment-stripped input),
* the brace/paren balance (``str.count`` fast path when the line carries
  no string literal; the legacy character loop otherwise — the two are
  equivalent exactly when no ``"`` is present),
* the pre-computed op-header regex match.

It also owns the *interned tables*: repeated type/signature substrings
(``tensor<4096x4096xbf16>``, ``f32[64,64]{1,0}``, whole `` : (...) ->
...`` signatures) resolve to shared :class:`TensorType` instances through
bounded memo dictionaries, so an L-layer model pays the type-parsing cost
once per distinct shape instead of once per occurrence.

``TOKENIZER_PASSES`` counts full-text tokenization passes; the benchmark
suite asserts exactly one pass per parse (the legacy front end would
count one per nesting level if it were instrumented the same way).
"""
from __future__ import annotations

from .parser import _HLO_OP_RE, _MLIR_OP_RE, _balance, _strip_comments
from .types import TensorType, mlir_types_in, parse_mlir_tensor

#: full-text tokenization passes in this process; benchmarks and CI assert
#: exactly 1 per parse (single-pass property of the streaming front end)
TOKENIZER_PASSES = 0

#: interned-table size bound; tables reset (not LRU-evict) past this, so a
#: pathological stream of unique shapes cannot grow memory without bound
_TABLE_LIMIT = 1 << 16

_TENSOR_TABLE: dict[str, TensorType | None] = {}
_MLIR_SIG_TABLE: dict[str, tuple[tuple[TensorType, ...], tuple[TensorType, ...]]] = {}
_HLO_TYPES_TABLE: dict[str, tuple[TensorType, ...]] = {}


def _bounded(table: dict) -> dict:
    if len(table) >= _TABLE_LIMIT:
        table.clear()
    return table


def intern_tensor(body: str) -> TensorType | None:
    """Interned :func:`repro.core.ir.types.parse_mlir_tensor`.

    Equal bodies yield the *same* (frozen, hashable) TensorType object —
    the shape/string table of the streaming front end."""
    try:
        return _TENSOR_TABLE[body]
    except KeyError:
        t = parse_mlir_tensor(body)
        _bounded(_TENSOR_TABLE)[body] = t
        return t


def mlir_types_interned(text: str) -> list[TensorType]:
    """:func:`types.mlir_types_in` over interned tensor bodies."""
    from .types import _MLIR_TENSOR_RE
    out = []
    for m in _MLIR_TENSOR_RE.finditer(text):
        t = intern_tensor(m.group(1))
        if t is not None:
            out.append(t)
    return out


def mlir_signature_types(
        sig: str) -> tuple[tuple[TensorType, ...], tuple[TensorType, ...]]:
    """Interned MLIR trailing-signature split: ``sig`` is everything after
    the last `` : `` of an op header.  Returns (operand_types,
    result_types) exactly as the legacy ``_signature_types`` computes them
    for the same header, memoized on the signature substring (layer-stacked
    models repeat whole signatures verbatim)."""
    try:
        return _MLIR_SIG_TABLE[sig]
    except KeyError:
        if "->" in sig:
            lhs, rhs = sig.split("->", 1)
            pair = (tuple(mlir_types_interned(lhs)),
                    tuple(mlir_types_interned(rhs)))
        else:
            ts = tuple(mlir_types_interned(sig))
            pair = (ts, ts)
        _bounded(_MLIR_SIG_TABLE)[sig] = pair
        return pair


def hlo_types_interned(text: str) -> tuple[TensorType, ...]:
    """Interned :func:`types.hlo_types_in` (HLO result-type column)."""
    try:
        return _HLO_TYPES_TABLE[text]
    except KeyError:
        from .types import _HLO_TYPE_RE
        out = []
        for m in _HLO_TYPE_RE.finditer(text):
            dtype, dims = m.group(1), m.group(2)
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append(TensorType(shape, dtype))
        tup = tuple(out)
        _bounded(_HLO_TYPES_TABLE)[text] = tup
        return tup


def fast_balance(line: str) -> int:
    """Brace/paren balance of ``line``, equal to the legacy per-character
    ``parser._balance`` on every input.

    Fast paths: a line with no ``"`` cannot toggle the in-string state, so
    the balance is a plain count difference (C-speed ``str.count``); a line
    with quotes but no escaped quote (``\\"``) splits on ``"`` — the
    even-indexed segments are exactly the out-of-string spans the legacy
    loop counts (an unterminated quote leaves the tail in-string, which the
    split reproduces: the tail lands in an odd segment).  Only lines
    carrying an escaped quote fall back to the per-character loop."""
    if '"' not in line:
        return (line.count("{") + line.count("(")
                - line.count("}") - line.count(")"))
    if '\\"' in line:
        return _balance(line)
    bal = 0
    for seg in line.split('"')[::2]:
        bal += (seg.count("{") + seg.count("(")
                - seg.count("}") - seg.count(")"))
    return bal


def strip_comments(text: str) -> str:
    """Comment stripping with a containment gate (most exports carry no
    ``/* ... */`` at all); identical output to ``parser._strip_comments``."""
    if "/*" in text:
        return _strip_comments(text)
    return text


class MlirTokens:
    """One tokenization pass over StableHLO-MLIR text.

    ``lines[i]`` / ``bals[i]`` / ``oms[i]`` are the text, brace balance,
    and op-header match of line *i*.  Region handling in the streaming
    parser works on index ranges into these parallel lists, so nested
    regions never re-scan text."""

    __slots__ = ("lines", "bals", "oms")

    def __init__(self, stripped_text: str):
        global TOKENIZER_PASSES
        TOKENIZER_PASSES += 1
        self.lines = stripped_text.splitlines()
        # fast_balance, with its common no-quote path inlined: a Python
        # call per line costs more than the four C-level str.counts
        self.bals = [
            fast_balance(ln) if '"' in ln else
            ln.count("{") + ln.count("(") - ln.count("}") - ln.count(")")
            for ln in self.lines]
        match = _MLIR_OP_RE.match
        self.oms = [match(ln) for ln in self.lines]


class HloTokens:
    """One tokenization pass over (post-SPMD) HLO text.

    Only op-definition lines (containing ``=``) are regex-matched; the
    computation-header match is left to the parser's top-level loop, which
    touches a handful of lines per module."""

    __slots__ = ("lines", "oms")

    def __init__(self, stripped_text: str):
        global TOKENIZER_PASSES
        TOKENIZER_PASSES += 1
        self.lines = stripped_text.splitlines()
        match = _HLO_OP_RE.match
        self.oms = [match(ln) if "=" in ln else None for ln in self.lines]


def reset_tables() -> None:
    """Drop every interned table (tests use this for isolation)."""
    _TENSOR_TABLE.clear()
    _MLIR_SIG_TABLE.clear()
    _HLO_TYPES_TABLE.clear()
