"""Dialect-neutral op graph — the unified workload representation.

Both front ends (StableHLO-MLIR text, post-SPMD HLO text) produce this
graph; everything downstream (slicing, estimation, network simulation)
consumes only this form. This realizes the paper's "single source of
truth" property: one representation drives every fidelity level.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

from .types import TensorType

# normalized collective mnemonics (StableHLO underscores; HLO hyphens map here)
COLLECTIVE_OPS = {
    "all_reduce", "all_gather", "reduce_scatter", "all_to_all",
    "collective_permute", "collective_broadcast", "send", "recv",
    "ragged_all_to_all",
}
# ops that carry no work (metadata / flow only)
ZERO_COST_OPS = {
    "parameter", "constant", "iota", "get_tuple_element", "tuple", "return",
    "after_all", "optimization_barrier", "partition_id", "replica_id",
    "get_dimension_size", "sharding_constraint", "custom_call_sharding",
}


@dataclass
class OpNode:
    uid: int                       # unique within program, topological order
    results: tuple[str, ...]       # SSA names defined
    op: str                        # normalized mnemonic, e.g. "dot_general"
    operands: tuple[str, ...]      # SSA names consumed
    operand_types: tuple[TensorType, ...]
    result_types: tuple[TensorType, ...]
    attrs: dict = field(default_factory=dict)
    regions: list[list["OpNode"]] = field(default_factory=list)
    trip_count: int = 1            # >1 for while/scan bodies
    raw: str = ""                  # original text (single- or multi-line)
    called: tuple[str, ...] = ()   # names of called computations (fusion/call)

    @property
    def is_collective(self) -> bool:
        return self.op in COLLECTIVE_OPS

    @property
    def is_async_start(self) -> bool:
        return bool(self.attrs.get("async_start"))

    @property
    def is_async_done(self) -> bool:
        return bool(self.attrs.get("async_done"))

    def walk(self) -> Iterator["OpNode"]:
        """Yield self and all region ops recursively."""
        yield self
        for region in self.regions:
            for op in region:
                yield from op.walk()


@dataclass
class Program:
    """A parsed module: entry computation + callee computations."""
    entry: list[OpNode]
    functions: dict[str, list[OpNode]]
    dialect: str                             # "stablehlo" | "hlo"
    meta: dict = field(default_factory=dict)  # num_partitions, mesh, ...

    def walk(self) -> Iterator[OpNode]:
        for op in self.entry:
            yield from op.walk()

    def resolve(self, name: str) -> list[OpNode] | None:
        """Look up a callee computation by (possibly %-prefixed) name."""
        name = name.lstrip("%@")
        if name in self.functions:
            return self.functions[name]
        # HLO names often carry numeric suffixes already; try fuzzy match
        for k in self.functions:
            if k == name or k.split(".")[0] == name:
                return self.functions[k]
        return None

    def collectives(self) -> list[OpNode]:
        return [op for op in self.walk() if op.is_collective and not op.is_async_done]

    @property
    def num_ops(self) -> int:
        return sum(1 for _ in self.walk())


def build_def_use(ops: list[OpNode]) -> dict[str, int]:
    """Map SSA name -> uid of defining op (entry level only)."""
    defs: dict[str, int] = {}
    for op in ops:
        for r in op.results:
            defs[r] = op.uid
    return defs


def dependency_edges(ops: list[OpNode]) -> dict[int, set[int]]:
    """uid -> set of uids it depends on (within the given op list)."""
    defs = build_def_use(ops)
    deps: dict[int, set[int]] = {}
    for op in ops:
        deps[op.uid] = {defs[o] for o in op.operands if o in defs}
    return deps
