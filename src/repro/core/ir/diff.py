"""Structural Program comparison for the differential parser harness.

:func:`program_diff` compares two parsed :class:`Program`\\ s node for
node — every OpNode field *except* ``uid`` (the streaming front end
numbers uids clean-sequentially, while the legacy MLIR parser burns uids
on a discarded pre-parse of ``while`` interiors; nothing downstream
depends on the absolute values, only on definition order and identity).
Instead of raw uid equality it checks the *uid correspondence* is a
consistent bijection across the whole program, which also catches a
front end that copies a shared HLO computation where the other attaches
it by reference.
"""
from __future__ import annotations

from .graph import OpNode, Program

_FIELDS = ("results", "op", "operands", "operand_types", "result_types",
           "attrs", "trip_count", "raw", "called")


class _Differ:
    def __init__(self, limit: int):
        self.out: list[str] = []
        self.limit = limit
        self.seen: set[tuple[int, int]] = set()
        self.a2b: dict[int, int] = {}
        self.b2a: dict[int, int] = {}

    def full(self) -> bool:
        return len(self.out) >= self.limit

    def note(self, msg: str) -> None:
        if not self.full():
            self.out.append(msg)

    def ops(self, a: list[OpNode], b: list[OpNode], path: str) -> None:
        if self.full():
            return
        if len(a) != len(b):
            self.note(f"{path}: {len(a)} ops != {len(b)} ops")
        for i, (x, y) in enumerate(zip(a, b)):
            self.node(x, y, f"{path}[{i}]")

    def node(self, a: OpNode, b: OpNode, path: str) -> None:
        if self.full():
            return
        pa, pb = self.a2b.get(id(a)), self.b2a.get(id(b))
        if pa is not None and pa != id(b):
            self.note(f"{path}: node appears twice on the left but maps to "
                      "two distinct right nodes (sharing mismatch)")
        if pb is not None and pb != id(a):
            self.note(f"{path}: node appears twice on the right but maps to "
                      "two distinct left nodes (sharing mismatch)")
        self.a2b[id(a)] = id(b)
        self.b2a[id(b)] = id(a)
        if (id(a), id(b)) in self.seen:
            return
        self.seen.add((id(a), id(b)))
        for f in _FIELDS:
            va, vb = getattr(a, f), getattr(b, f)
            if va != vb:
                self.note(f"{path}.{f}: {va!r} != {vb!r}")
        if len(a.regions) != len(b.regions):
            self.note(f"{path}.regions: {len(a.regions)} != {len(b.regions)}")
            return
        for ri, (ra, rb) in enumerate(zip(a.regions, b.regions)):
            self.ops(ra, rb, f"{path}.regions[{ri}]")


def program_diff(a: Program, b: Program, limit: int = 50) -> list[str]:
    """All structural differences between two parses, as readable strings.

    Empty list == node-for-node identical Programs (modulo uid values,
    whose correspondence must still be a consistent bijection)."""
    d = _Differ(limit)
    if a.dialect != b.dialect:
        d.note(f"dialect: {a.dialect!r} != {b.dialect!r}")
    if a.meta != b.meta:
        ka, kb = set(a.meta), set(b.meta)
        if ka != kb:
            d.note(f"meta keys: {sorted(ka)} != {sorted(kb)}")
        for k in sorted(ka & kb):
            if a.meta[k] != b.meta[k]:
                d.note(f"meta[{k}]: differs")
    if list(a.functions) != list(b.functions):
        d.note(f"functions: {list(a.functions)} != {list(b.functions)}")
    for name in a.functions:
        if name in b.functions:
            d.ops(a.functions[name], b.functions[name], f"fn {name}")
    d.ops(a.entry, b.entry, "entry")
    return d.out


def assert_programs_equal(a: Program, b: Program) -> None:
    """Raise AssertionError with every difference if the parses diverge."""
    diffs = program_diff(a, b)
    if diffs:
        raise AssertionError(
            "programs differ:\n  " + "\n  ".join(diffs))
