"""Communication-operator extraction (paper §III-B(b), slicing stage).

Communication sizes are inferred from tensor types and communication
semantics from the StableHLO/HLO collective operator — exactly the mapping
the paper uses to build Chakra COMM nodes.
"""
from __future__ import annotations

from dataclasses import dataclass

from .graph import OpNode, Program


@dataclass(frozen=True)
class CommSpec:
    kind: str            # all_reduce | all_gather | reduce_scatter | all_to_all | collective_permute
    bytes_in: float      # per-participant input payload bytes
    bytes_out: float     # per-participant output payload bytes
    group_size: int      # participants per replica group
    num_groups: int      # concurrent disjoint groups
    op_uid: int = -1
    label: str = ""

    @property
    def algo_bytes(self) -> float:
        """Payload size the collective algorithm moves, per participant.

        all_reduce: input size (reduce-scatter + all-gather over it)
        all_gather: output size (each rank ends with the full tensor)
        reduce_scatter: input size
        all_to_all: input size (full resharding)
        collective_permute: input size (point-to-point)
        """
        if self.kind == "all_gather":
            return max(self.bytes_out, self.bytes_in)
        return max(self.bytes_in, self.bytes_out / max(self.group_size, 1))


def comm_spec(op: OpNode, default_world: int = 1) -> CommSpec:
    bytes_in = sum(t.nbytes for t in op.operand_types)
    bytes_out = sum(t.nbytes for t in op.result_types)
    if bytes_in == 0 and bytes_out > 0:
        # HLO parser resolves operand types from defs; fall back to result
        if op.op == "all_gather":
            bytes_in = bytes_out  # conservative
        else:
            bytes_in = bytes_out
    rg = op.attrs.get("replica_groups")
    if rg:
        num_groups, group_size = rg
    else:
        num_groups, group_size = 1, default_world
    label = op.attrs.get("op_name", "") or op.op
    return CommSpec(
        kind=op.op, bytes_in=bytes_in, bytes_out=bytes_out,
        group_size=max(group_size, 1), num_groups=max(num_groups, 1),
        op_uid=op.uid, label=label,
    )


def collect_collectives(program: Program) -> list[tuple[CommSpec, int]]:
    """All collectives in the program with their loop multiplicity.

    Returns (spec, multiplicity) where multiplicity is the product of
    enclosing while trip counts (a collective inside a scan-over-layers body
    executes L times per step).
    """
    world = program.meta.get("num_partitions", 1)
    out: list[tuple[CommSpec, int]] = []

    def visit(ops: list[OpNode], mult: int) -> None:
        for op in ops:
            if op.is_collective and not op.is_async_done:
                out.append((comm_spec(op, world), mult))
            if op.op == "while":
                body = op.regions[-1] if op.regions else []
                visit(body, mult * max(op.trip_count, 1))
            else:
                for region in op.regions:
                    visit(region, mult)

    visit(program.entry, 1)
    return out


def total_collective_bytes(program: Program) -> dict[str, float]:
    """Per-kind algorithm bytes (per participant), summed over the program."""
    totals: dict[str, float] = {}
    for spec, mult in collect_collectives(program):
        totals[spec.kind] = totals.get(spec.kind, 0.0) + spec.algo_bytes * mult
    return totals
