"""Array-of-structs views of parsed programs and sliced regions.

Two representations, both plain numpy + interned tables, both picklable
(they ride inside :class:`~repro.core.pipeline.PredictionPlan` to process
workers):

* :class:`ProgramArrays` — the whole op graph flattened to parallel
  arrays (op codes into an interned mnemonic table, CSR operand/result
  indices, interned shape/dtype tables).  This is the structure-of-arrays
  twin of the per-node :class:`OpNode` objects: cheap to scan, cheap to
  ship, and the natural substrate for future whole-graph analyses.

* :class:`RegionArrays` — the *evaluation-ready* per-region arrays the
  estimators consume: region flops / boundary bytes / dominant dtype for
  roofline region mode, CSR per-op flops/bytes/dtype for per-op mode, and
  the region fingerprints (so (H,C,R) cache keys for a whole plan are one
  string-concat per region, memoized per key prefix).  Built once at plan
  time; :meth:`RooflineEstimator.evaluate_batch` turns a plan evaluation
  into a handful of vectorized numpy expressions that are bit-identical
  to the scalar per-region path (same float64 operations in the same
  order — sums are performed left-to-right in Python over the numpy
  results precisely to preserve IEEE associativity with the legacy loop).

:class:`RegionArrays` also carries a per-region CSR of GEMM dimensions
(batch, M, N, K, operand dtype) for every ``dot_general`` directly in a
region's op list — the substrate for
:meth:`SystolicEstimator.evaluate_batch`.  The systolic scalar path
recurses into nested control-flow regions and multiplies by trip count
*after* summing each level, a fold that a flat weighted array cannot
replay bit-identically when a loop body holds several GEMMs; regions
hiding a ``dot_general`` below the top level therefore clear
``gemm_exact`` and the estimator declines the whole batch back to the
scalar loop rather than return approximately-right values.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .graph import OpNode, Program
from .opcost import op_cost

#: dtype assumed when an op has no result types (mirrors the estimators)
_DEFAULT_DTYPE = "bf16"


class _Interner:
    """Value -> dense index table (insertion-ordered)."""

    def __init__(self):
        self.index: dict = {}
        self.values: list = []

    def __call__(self, value) -> int:
        idx = self.index.get(value)
        if idx is None:
            idx = len(self.values)
            self.index[value] = idx
            self.values.append(value)
        return idx


@dataclass
class ProgramArrays:
    """Flattened op graph in walk order (entry + nested regions)."""
    op_table: list[str]                 # interned mnemonics
    dtype_table: list[str]              # interned dtypes
    shape_table: list[tuple[int, ...]]  # interned shape tuples
    opcodes: np.ndarray                 # int32[N] -> op_table
    trip_counts: np.ndarray             # int64[N]
    operand_offsets: np.ndarray         # int64[N+1] CSR
    operand_defs: np.ndarray            # int32[nnz] defining op row, -1 = external
    result_offsets: np.ndarray          # int64[N+1] CSR
    result_shapes: np.ndarray           # int32[nnz] -> shape_table
    result_dtypes: np.ndarray           # int32[nnz] -> dtype_table

    @property
    def num_ops(self) -> int:
        return len(self.opcodes)


def build_program_arrays(program: Program) -> ProgramArrays:
    """Flatten ``program.walk()`` order into a :class:`ProgramArrays`.

    Operand references resolve to the row of the op that defined the SSA
    name earlier in walk order (-1 when defined outside the walked entry,
    e.g. a function argument)."""
    ops: list[OpNode] = list(program.walk())
    op_i = _Interner()
    dt_i = _Interner()
    sh_i = _Interner()
    defs: dict[str, int] = {}
    opcodes = np.empty(len(ops), dtype=np.int32)
    trips = np.empty(len(ops), dtype=np.int64)
    operand_offsets = np.zeros(len(ops) + 1, dtype=np.int64)
    result_offsets = np.zeros(len(ops) + 1, dtype=np.int64)
    operand_defs: list[int] = []
    result_shapes: list[int] = []
    result_dtypes: list[int] = []
    for row, op in enumerate(ops):
        opcodes[row] = op_i(op.op)
        trips[row] = op.trip_count
        for name in op.operands:
            operand_defs.append(defs.get(name, -1))
        operand_offsets[row + 1] = len(operand_defs)
        for name in op.results:
            defs[name] = row
        for t in op.result_types:
            result_shapes.append(sh_i(t.shape))
            result_dtypes.append(dt_i(t.dtype))
        result_offsets[row + 1] = len(result_shapes)
    return ProgramArrays(
        op_table=op_i.values, dtype_table=dt_i.values, shape_table=sh_i.values,
        opcodes=opcodes, trip_counts=trips,
        operand_offsets=operand_offsets,
        operand_defs=np.asarray(operand_defs, dtype=np.int32),
        result_offsets=result_offsets,
        result_shapes=np.asarray(result_shapes, dtype=np.int32),
        result_dtypes=np.asarray(result_dtypes, dtype=np.int32),
    )


def gemm_dims(op: OpNode) -> tuple[int, int, int, int] | None:
    """(batch, M, N, K) of a ``dot_general``, or None.

    Single source of the GEMM-shape parse shared by the systolic
    estimator's scalar path and the vectorized arrays built here — the
    two must agree op-for-op or the batch path stops being a replay of
    the scalar one."""
    if op.op != "dot_general" or len(op.operand_types) < 2:
        return None
    lhs, rhs = op.operand_types[0], op.operand_types[1]
    lb = op.attrs.get("lhs_batch", ())
    lc = op.attrs.get("lhs_contract", ())
    rb = op.attrs.get("rhs_batch", ())
    rc = op.attrs.get("rhs_contract", ())
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    k = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(d for i, d in enumerate(lhs.shape)
                  if i not in lb and i not in lc)
    n = math.prod(d for i, d in enumerate(rhs.shape)
                  if i not in rb and i not in rc)
    return batch, m, n, k


def _hides_gemm(op: OpNode) -> bool:
    """A ``dot_general`` somewhere below ``op``'s own regions."""
    for region in op.regions:
        for sub in region:
            if gemm_dims(sub) is not None or _hides_gemm(sub):
                return True
    return False


@dataclass
class RegionArrays:
    """Per-compute-region evaluation arrays, in plan segment order."""
    fingerprints: list[str]             # region fingerprint per region
    dtype_table: list[str]              # interned dtypes
    flops: np.ndarray                   # float64[R] region.cost.flops
    boundary_bytes: np.ndarray          # float64[R] in+out boundary traffic
    dtype_idx: np.ndarray               # int32[R] dominant dtype per region
    op_offsets: np.ndarray              # int64[R+1] CSR into per-op arrays
    op_flops: np.ndarray                # float64[nnz] op_cost(op).flops
    op_bytes: np.ndarray                # float64[nnz] op_cost(op).bytes
    op_dtype_idx: np.ndarray            # int32[nnz]
    op_active: np.ndarray               # float64[nnz] 1.0 iff flops or bytes
    gemm_offsets: np.ndarray            # int64[R+1] CSR into gemm arrays
    gemm_batch: np.ndarray              # float64[G] dot_general batch
    gemm_m: np.ndarray                  # float64[G]
    gemm_n: np.ndarray                  # float64[G]
    gemm_k: np.ndarray                  # float64[G]
    gemm_dtype_idx: np.ndarray          # int32[G] operand dtype
    gemm_exact: bool = True             # no GEMMs hidden below top level
    _key_cache: dict = field(default_factory=dict, repr=False, compare=False)

    @property
    def num_regions(self) -> int:
        return len(self.fingerprints)

    def keys_for(self, prefix: str) -> list[str]:
        """(H,C,config,R) cache keys for every region: ``prefix`` is the
        estimator's ``hw|toolchain|config|`` part; memoized per prefix so
        a warm grid re-evaluation does zero string work."""
        keys = self._key_cache.get(prefix)
        if keys is None:
            keys = [prefix + f for f in self.fingerprints]
            self._key_cache[prefix] = keys
        return keys


def _dominant_dtype(region) -> str:
    """Dominant dtype by result bytes — must mirror
    ``RooflineEstimator._dtype_of`` exactly (strictly-greater compare,
    bf16 default) so precomputed indices reproduce the scalar path."""
    best, best_bytes = _DEFAULT_DTYPE, -1.0
    for op in region.ops:
        for t in op.result_types:
            if t.nbytes > best_bytes:
                best, best_bytes = t.dtype, t.nbytes
    return best


def build_region_arrays(regions: list) -> RegionArrays:
    """Build :class:`RegionArrays` from finalized compute regions."""
    dt_i = _Interner()
    nr = len(regions)
    flops = np.empty(nr, dtype=np.float64)
    boundary = np.empty(nr, dtype=np.float64)
    dtype_idx = np.empty(nr, dtype=np.int32)
    op_offsets = np.zeros(nr + 1, dtype=np.int64)
    op_flops: list[float] = []
    op_bytes: list[float] = []
    op_dtype: list[int] = []
    op_active: list[float] = []
    fingerprints: list[str] = []
    gemm_offsets = np.zeros(nr + 1, dtype=np.int64)
    gemm_b: list[float] = []
    gemm_m: list[float] = []
    gemm_n: list[float] = []
    gemm_k: list[float] = []
    gemm_dtype: list[int] = []
    gemm_exact = True
    for r, region in enumerate(regions):
        fingerprints.append(region.fingerprint)
        flops[r] = region.cost.flops
        boundary[r] = region.boundary_in_bytes + region.boundary_out_bytes
        dtype_idx[r] = dt_i(_dominant_dtype(region))
        for op in region.ops:
            c = op_cost(op)
            op_flops.append(c.flops)
            op_bytes.append(c.bytes)
            op_dtype.append(dt_i(op.result_types[0].dtype if op.result_types
                                 else _DEFAULT_DTYPE))
            op_active.append(1.0 if (c.flops > 0 or c.bytes > 0) else 0.0)
            dims = gemm_dims(op)
            if dims is not None:
                b, m, n, k = dims
                gemm_b.append(float(b))
                gemm_m.append(float(m))
                gemm_n.append(float(n))
                gemm_k.append(float(k))
                gemm_dtype.append(dt_i(op.operand_types[0].dtype))
            elif _hides_gemm(op):
                gemm_exact = False
        op_offsets[r + 1] = len(op_flops)
        gemm_offsets[r + 1] = len(gemm_b)
    return RegionArrays(
        fingerprints=fingerprints, dtype_table=dt_i.values,
        flops=flops, boundary_bytes=boundary, dtype_idx=dtype_idx,
        op_offsets=op_offsets,
        op_flops=np.asarray(op_flops, dtype=np.float64),
        op_bytes=np.asarray(op_bytes, dtype=np.float64),
        op_dtype_idx=np.asarray(op_dtype, dtype=np.int32),
        op_active=np.asarray(op_active, dtype=np.float64),
        gemm_offsets=gemm_offsets,
        gemm_batch=np.asarray(gemm_b, dtype=np.float64),
        gemm_m=np.asarray(gemm_m, dtype=np.float64),
        gemm_n=np.asarray(gemm_n, dtype=np.float64),
        gemm_k=np.asarray(gemm_k, dtype=np.float64),
        gemm_dtype_idx=np.asarray(gemm_dtype, dtype=np.int32),
        gemm_exact=gemm_exact,
    )
