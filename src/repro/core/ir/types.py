"""Tensor types shared by the StableHLO-MLIR and HLO-text front ends."""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

# bytes per element for every dtype our models emit
DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "s4": 0.5,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "u4": 0.5,
    "i64": 8, "i32": 4, "i16": 2, "i8": 1, "i1": 0.125,
    "pred": 0.125, "c64": 8, "c128": 16, "token": 0,
}

_MLIR_TENSOR_RE = re.compile(r"tensor<([^<>]*)>")
# HLO text: bf16[256,512]{1,0} or f32[] or s32[4]
_HLO_TYPE_RE = re.compile(r"\b([a-z]+\d+[a-z0-9]*|pred|token)\[([0-9,]*)\](?:\{[^}]*\})?")


@dataclass(frozen=True)
class TensorType:
    shape: tuple[int, ...]
    dtype: str

    @property
    def num_elements(self) -> int:
        return math.prod(self.shape) if self.shape else 1

    @property
    def nbytes(self) -> float:
        return self.num_elements * DTYPE_BYTES.get(self.dtype, 4)

    def __str__(self) -> str:  # normalized, layout-free
        dims = "x".join(str(d) for d in self.shape)
        return f"{dims}x{self.dtype}" if dims else self.dtype


def parse_mlir_tensor(body: str) -> TensorType | None:
    """Parse the inside of ``tensor<...>``: e.g. ``4x6xf32`` or ``f32`` or ``1xi1``."""
    body = body.strip()
    if not body:
        return None
    parts = body.split("x")
    dims: list[int] = []
    for i, p in enumerate(parts):
        if p and (p[0].isdigit() or p == "?"):
            dims.append(-1 if p == "?" else int(p))
        else:
            dtype = "x".join(parts[i:])
            return TensorType(tuple(dims), dtype.strip())
    return TensorType(tuple(dims), parts[-1])


def mlir_types_in(text: str) -> list[TensorType]:
    out = []
    for m in _MLIR_TENSOR_RE.finditer(text):
        t = parse_mlir_tensor(m.group(1))
        if t is not None:
            out.append(t)
    return out


def hlo_types_in(text: str) -> list[TensorType]:
    out = []
    for m in _HLO_TYPE_RE.finditer(text):
        dtype, dims = m.group(1), m.group(2)
        shape = tuple(int(d) for d in dims.split(",")) if dims else ()
        out.append(TensorType(shape, dtype))
    return out
