"""Per-operator FLOP / byte accounting over the unified op graph.

This is the "operator-level metrics" layer the paper's analytical estimator
aggregates (§III-B(c)(i)).  FLOPs for contractions come from parsed dimension
numbers; elementwise ops count one (or a few) flops per output element;
pure data-movement ops cost bytes only.

Unlike XLA's ``cost_analysis`` (which counts ``while`` bodies ONCE — verified
empirically: a scan of length 4 and length 8 report identical flops), this
accounting multiplies region costs by the loop trip count, so scan-over-layers
models report full-step numbers.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from .graph import OpNode, Program, ZERO_COST_OPS

# transcendental-ish ops: weight >1 flop/element
_EXPENSIVE_ELEMENTWISE = {
    "exponential": 4, "exp": 4, "log": 4, "logistic": 6, "tanh": 6,
    "rsqrt": 2, "sqrt": 2, "power": 4, "sine": 4, "cosine": 4,
    "erf": 8, "exponential_minus_one": 4, "log_plus_one": 4, "cbrt": 4,
    "atan2": 8, "divide": 1,
}
# simple elementwise / cheap ops: 1 flop/element
_SIMPLE_ELEMENTWISE = {
    "add", "subtract", "multiply", "maximum", "minimum", "negate", "abs",
    "and", "or", "xor", "not", "compare", "select", "clamp", "floor",
    "ceil", "round_nearest_afz", "round_nearest_even", "sign",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "remainder", "is_finite", "popcnt", "clz", "reduce_precision",
    "stochastic_convert",
}
# data movement: 0 flops, bytes = in+out
_MOVEMENT = {
    "reshape", "transpose", "broadcast_in_dim", "convert", "bitcast",
    "bitcast_convert", "copy", "slice", "dynamic_slice",
    "dynamic_update_slice", "concatenate", "pad", "reverse", "gather",
    "scatter", "real", "imag", "copy_start", "copy_done", "domain",
}


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0          # HBM traffic estimate (operands + results)
    transcendentals: float = 0.0
    by_op: dict = field(default_factory=dict)
    bytes_by_op: dict = field(default_factory=dict)

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        self.transcendentals += other.transcendentals
        for k, v in other.by_op.items():
            self.by_op[k] = self.by_op.get(k, 0.0) + v
        for k, v in other.bytes_by_op.items():
            self.bytes_by_op[k] = self.bytes_by_op.get(k, 0.0) + v
        return self

    def scaled(self, k: float) -> "Cost":
        return Cost(self.flops * k, self.bytes * k, self.transcendentals * k,
                    {n: v * k for n, v in self.by_op.items()},
                    {n: v * k for n, v in self.bytes_by_op.items()})


def _dot_flops(op: OpNode) -> float:
    if not op.operand_types or len(op.operand_types) < 2:
        # fall back: 2 * out_elems * sqrt-ish — better to use result only
        out = sum(t.num_elements for t in op.result_types)
        return 2.0 * out
    lhs, rhs = op.operand_types[0], op.operand_types[1]
    lc = op.attrs.get("lhs_contract", ())
    lb = op.attrs.get("lhs_batch", ())
    if any(d >= len(lhs.shape) for d in (*lc, *lb)) or any(
            d >= len(rhs.shape)
            for d in (*op.attrs.get("rhs_contract", ()),
                      *op.attrs.get("rhs_batch", ()))):
        # malformed/mismatched operand types: fall back to output-based bound
        return 2.0 * sum(t.num_elements for t in op.result_types)
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    contract = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(d for i, d in enumerate(lhs.shape) if i not in lc and i not in lb)
    rb = op.attrs.get("rhs_batch", ())
    rc = op.attrs.get("rhs_contract", ())
    n = math.prod(d for i, d in enumerate(rhs.shape) if i not in rc and i not in rb)
    return 2.0 * batch * m * n * contract


def _conv_flops(op: OpNode) -> float:
    if len(op.operand_types) < 2 or not op.result_types:
        return 0.0
    lhs, rhs, out = op.operand_types[0], op.operand_types[1], op.result_types[0]
    groups = op.attrs.get("feature_group_count", 1) or 1
    # kernel: spatial dims are everything except input/output-feature dims.
    # With dim_labels like [0, 1, i, o] / 01io, the i/o positions vary; the
    # product of ALL kernel dims = prod(spatial) * Cin/g * Cout, so
    # flops = 2 * out_spatial*batch * prod(kernel)/Cout * Cout / g ... simplify:
    kernel_elems = rhs.num_elements            # spatial * (Cin/g) * Cout
    out_elems = out.num_elements               # batch * out_spatial * Cout
    cout = _conv_out_features(op, rhs, out)
    per_out = kernel_elems / max(cout, 1)      # spatial * Cin/g
    return 2.0 * out_elems * per_out / 1.0     # groups already folded in Cin/g


def _conv_out_features(op: OpNode, rhs, out) -> int:
    labels = op.attrs.get("dim_labels", "")
    # HLO form: b01f_01io->b01f ; MLIR form: [b, 0, 1, f]x[0, 1, i, o]->[b, 0, 1, f]
    try:
        if "x" in labels and "[" in labels:
            kernel_part = labels.split("x")[1].split("->")[0]
            toks = [t.strip() for t in kernel_part.strip("[]").split(",")]
            o_pos = toks.index("o")
            return rhs.shape[o_pos]
        if "_" in labels:
            kernel_part = labels.split("_")[1].split("->")[0]
            o_pos = kernel_part.index("o")
            return rhs.shape[o_pos]
    except (ValueError, IndexError):
        pass
    return rhs.shape[-1] if rhs.shape else 1


_SLICE_LIKE = {"dynamic_slice", "slice", "gather", "get_tuple_element",
               "bitcast", "reshape"}


def _fusion_input_bytes(body_ops: list[OpNode]) -> float:
    """HBM read bytes at a fusion's boundary, slice-aware.

    For each fusion ``parameter``, if every direct consumer in the body is a
    slice-like op, charge the consumers' OUTPUT sizes (only those elements
    are read); otherwise charge the parameter's full size."""
    total = 0.0
    consumers: dict[str, list[OpNode]] = {}
    for sub in body_ops:
        for o in sub.operands:
            consumers.setdefault(o, []).append(sub)
    for sub in body_ops:
        if sub.op != "parameter":
            continue
        psize = sum(t.nbytes for t in sub.result_types)
        users = [u for r in sub.results for u in consumers.get(r, [])]
        if users and all(u.op in _SLICE_LIKE for u in users):
            read = sum(t.nbytes for u in users for t in u.result_types)
            total += min(psize, read)
        elif users and all(u.op == "dynamic_update_slice"
                           and u.operands and u.operands[0] in sub.results
                           for u in users):
            # in-place buffer update: only the update window moves
            upd = sum(u.operand_types[1].nbytes for u in users
                      if len(u.operand_types) > 1)
            total += min(psize, upd)
        else:
            total += psize
    return total


def op_cost(op: OpNode, program: Program | None = None) -> Cost:
    """Cost of a single op, including its regions (× trip count for loops)."""
    c = Cost()
    name = op.op
    out_elems = sum(t.num_elements for t in op.result_types)
    in_bytes = sum(t.nbytes for t in op.operand_types)
    out_bytes = sum(t.nbytes for t in op.result_types)

    if name in ZERO_COST_OPS or op.is_async_done:
        return c
    if op.is_collective:
        # collectives cost no device flops; bytes handled by the network model
        return c
    if name == "while":
        body = op.regions[-1] if op.regions else []
        inner = Cost()
        for sub in body:
            inner += op_cost(sub, program)
        return inner.scaled(max(op.trip_count, 1))
    if name in ("fusion", "call", "map", "conditional", "sort", "composite"):
        inner = Cost()
        body_ops: list[OpNode] = []
        for region in op.regions:
            body_ops.extend(region)
            for sub in region:
                inner += op_cost(sub, program)
        if program is not None and not op.regions and op.called:
            for callee in op.called:
                body = program.resolve(callee)
                if body:
                    body_ops.extend(body)
                    for sub in body:
                        inner += op_cost(sub, program)
        # fused region: memory traffic only at boundaries (paper §IV-C1).
        # Boundary operands consumed exclusively through slice-like body ops
        # are charged at the SLICE size, not the full operand: a fusion that
        # dynamic-slices layer i's weights out of a scan-stacked [L, ...]
        # parameter reads only that layer from HBM (naive accounting charged
        # the full stack per loop iteration — 236 TB/chip on deepseek-v3).
        in_eff = _fusion_input_bytes(body_ops) if body_ops else in_bytes
        inner.bytes = in_eff + out_bytes
        inner.bytes_by_op = {name: inner.bytes}
        if name == "sort":
            inner.flops += out_elems * math.log2(max(out_elems, 2))
        return inner

    if name == "dot_general":
        c.flops = _dot_flops(op)
        c.bytes = in_bytes + out_bytes
    elif name == "convolution":
        c.flops = _conv_flops(op)
        c.bytes = in_bytes + out_bytes
    elif name in ("reduce", "reduce_window"):
        c.flops = sum(t.num_elements for t in op.operand_types) or out_elems
        c.bytes = in_bytes + out_bytes
    elif name in _EXPENSIVE_ELEMENTWISE:
        w = _EXPENSIVE_ELEMENTWISE[name]
        c.flops = out_elems * w
        c.transcendentals = out_elems
        c.bytes = in_bytes + out_bytes
    elif name in _SIMPLE_ELEMENTWISE:
        c.flops = out_elems
        c.bytes = in_bytes + out_bytes
    elif name in _MOVEMENT:
        if name in ("dynamic_slice", "slice", "gather"):
            c.bytes = 2 * out_bytes          # read the window, write it
        elif name == "dynamic_update_slice" and len(op.operand_types) > 1:
            c.bytes = 2 * op.operand_types[1].nbytes
        else:
            c.bytes = in_bytes + out_bytes
    elif name in ("custom_call", "batch_norm_training", "batch_norm_grad",
                  "cholesky", "triangular_solve", "fft"):
        c.flops = out_elems * 2
        c.bytes = in_bytes + out_bytes
    else:
        # unknown op: treat as elementwise so nothing silently disappears
        c.flops = out_elems
        c.bytes = in_bytes + out_bytes
    c.by_op[name] = c.flops
    c.bytes_by_op[name] = c.bytes
    return c


def program_cost(program: Program) -> Cost:
    total = Cost()
    for op in program.entry:
        total += op_cost(op, program)
    return total
