from .analytical import RooflineEstimator
from .base import ComputeEstimator, MixedEstimator
from .cache import CachedEstimator, CacheStats
from .profiling import ProfilingEstimator
from .systolic import PRESETS, SystolicEstimator

__all__ = [
    "ComputeEstimator", "MixedEstimator", "RooflineEstimator",
    "CachedEstimator", "CacheStats", "ProfilingEstimator",
    "SystolicEstimator", "PRESETS",
]
