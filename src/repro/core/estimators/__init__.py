from .analytical import RooflineEstimator
from .base import ComputeEstimator, MixedEstimator
from .cache import CachedEstimator, CacheStats
from .learned import (LearnedEstimator, LearnedModel, fit_model, load_model,
                      region_family, save_model)
from .profiling import ProfilingEstimator
from .systolic import PRESETS, SystolicEstimator
from .table import TableEstimator, load_profile, record_profile, save_profile

__all__ = [
    "ComputeEstimator", "MixedEstimator", "RooflineEstimator",
    "CachedEstimator", "CacheStats", "ProfilingEstimator",
    "SystolicEstimator", "PRESETS",
    "TableEstimator", "load_profile", "record_profile", "save_profile",
    "LearnedEstimator", "LearnedModel", "fit_model", "save_model",
    "load_model", "region_family",
]
