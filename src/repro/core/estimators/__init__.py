from .analytical import RooflineEstimator
from .base import ComputeEstimator, MixedEstimator
from .cache import CachedEstimator, CacheStats
from .profiling import ProfilingEstimator
from .systolic import PRESETS, SystolicEstimator
from .table import TableEstimator, load_profile, record_profile, save_profile

__all__ = [
    "ComputeEstimator", "MixedEstimator", "RooflineEstimator",
    "CachedEstimator", "CacheStats", "ProfilingEstimator",
    "SystolicEstimator", "PRESETS",
    "TableEstimator", "load_profile", "record_profile", "save_profile",
]
