"""Simulation-based estimator: cycle-approximate systolic-array model.

TPU-native adaptation of the paper's §IV-C3 estimator class (ONNXim,
COCOSSim, SCALE-Sim, ZigZag).  The model walks the HBM→VMEM→MXU hierarchy:

  * the GEMM is tiled into (mxu_rows × mxu_cols) output tiles with the
    contraction streamed through the array (weight-stationary);
  * each output tile costs K + fill cycles, where fill = rows + cols - 2
    is the systolic fill/drain latency;
  * tiles pipeline across ``n_mxu`` arrays; double buffering overlaps the
    HBM→VMEM stream of the next tile with compute unless disabled;
  * the final latency is max(compute pipeline, memory stream) + overhead.

Four presets reproduce the fidelity spread of the paper's Fig 10:
  onnxim    — double-buffered, high utilization (closest to TPU trends)
  cocossim  — double-buffered, per-tile re-fill charged (slightly slower)
  scalesim  — no double buffering, serial tile loads (pessimistic)
  zigzag    — pure compute cycles, no fill/memory modeling (optimistic)

Supports only matrix-multiplication regions natively (``supports``); pair
with a roofline fallback through MixedEstimator, as the paper pairs
COCOSSim with an analytical TPU estimator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from ..ir.arrays import RegionArrays, gemm_dims as _gemm_dims
from ..ir.graph import OpNode
from ..ir.types import DTYPE_BYTES
from ..registry import register_estimator
from ..slicing.regions import ComputeRegion
from ..systems import System
from .base import ComputeEstimator


@dataclass(frozen=True)
class SystolicPreset:
    name: str
    double_buffer: bool = True
    charge_fill_per_tile: bool = True
    model_memory: bool = True
    utilization: float = 1.0       # sustained/peak derate


PRESETS = {
    "onnxim": SystolicPreset("onnxim", True, False, True, 0.95),
    "cocossim": SystolicPreset("cocossim", True, True, True, 0.90),
    "scalesim": SystolicPreset("scalesim", False, True, True, 0.85),
    "zigzag": SystolicPreset("zigzag", True, False, False, 1.0),
}


@register_estimator("systolic")
class SystolicEstimator(ComputeEstimator):
    """Cycle-approximate MXU model behind the Compute API."""

    def __init__(self, system: System, preset: str = "cocossim"):
        super().__init__(system)
        self.preset = PRESETS[preset]
        self.toolchain = f"systolic-{preset}"

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "SystolicEstimator":
        return cls(system, options.get("preset", "cocossim"))

    def supports(self, region: ComputeRegion) -> bool:
        """Native support: regions whose cost is ≥90% matmul flops."""
        mat = sum(op_flops for op in region.ops
                  for op_flops in [self._matmul_flops(op)])
        total = region.cost.flops
        return total > 0 and mat / total >= 0.9

    @staticmethod
    def _matmul_flops(op: OpNode) -> float:
        dims = _gemm_dims(op)
        if dims is None:
            total = 0.0
            for r in op.regions:
                for sub in r:
                    total += SystolicEstimator._matmul_flops(sub)
            return total * max(op.trip_count, 1)
        b, m, n, k = dims
        return 2.0 * b * m * n * k

    def gemm_latency(self, m: int, n: int, k: int, batch: int = 1,
                     dtype: str = "bf16") -> float:
        p = self.preset
        s = self.system
        rows, cols = s.mxu_rows, s.mxu_cols
        tiles_m = math.ceil(m / rows)
        tiles_n = math.ceil(n / cols)
        fill = rows + cols - 2
        if p.charge_fill_per_tile:
            cycles_per_tile = k + fill
        else:
            # fill amortized across the tile stream (pipelined drain)
            cycles_per_tile = k
        tiles = tiles_m * tiles_n * batch
        compute_cycles = tiles * cycles_per_tile / s.n_mxu + fill
        compute_t = compute_cycles / (s.clock_hz * p.utilization)

        if not p.model_memory:
            return compute_t + s.kernel_overhead_s
        eb = DTYPE_BYTES.get(dtype, 2)
        bytes_moved = batch * (m * k + k * n + m * n) * eb
        mem_t = bytes_moved / s.mem_bw
        if p.double_buffer:
            t = max(compute_t, mem_t)
        else:
            t = compute_t + mem_t
        return t + s.kernel_overhead_s

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        total = 0.0
        for op in region.ops:
            total += self._op_latency(op)
        return total

    def _op_latency(self, op: OpNode) -> float:
        dims = _gemm_dims(op)
        if dims is not None:
            b, m, n, k = dims
            dtype = op.operand_types[0].dtype if op.operand_types else "bf16"
            return self.gemm_latency(m, n, k, batch=b, dtype=dtype)
        total = 0.0
        for r in op.regions:
            for sub in r:
                total += self._op_latency(sub)
        return total * max(op.trip_count, 1)

    def evaluate_batch(self, arrays: RegionArrays) -> list[float] | None:
        """All regions of a plan as vectorized GEMM-dimension math.

        Bit-identical to :meth:`get_run_time_estimate` per region: each
        float64 expression mirrors :meth:`gemm_latency` operation for
        operation and in the same order (Python's exact-int intermediate
        products all stay below 2**53 for any realizable GEMM, where
        float64 products are exact, so the numpy pipeline lands on the
        same doubles), ``np.maximum`` is IEEE ``max``, and each region's
        tile latencies are summed left-to-right in Python — non-GEMM ops
        contribute an exact ``+0.0`` in the scalar walk, so skipping
        them preserves the sum.  Returns None (declining the batch back
        to the scalar loop) when the plan hides a ``dot_general`` inside
        nested control flow, where the scalar path's sum-then-multiply
        trip-count fold has no exact flat-array replay."""
        if not arrays.gemm_exact:
            return None
        p = self.preset
        s = self.system
        rows, cols = s.mxu_rows, s.mxu_cols
        b, m = arrays.gemm_batch, arrays.gemm_m
        n, k = arrays.gemm_n, arrays.gemm_k
        tiles_m = np.ceil(m / rows)
        tiles_n = np.ceil(n / cols)
        fill = rows + cols - 2
        if p.charge_fill_per_tile:
            cycles_per_tile = k + fill
        else:
            cycles_per_tile = k
        tiles = tiles_m * tiles_n * b
        compute_cycles = tiles * cycles_per_tile / s.n_mxu + fill
        compute_t = compute_cycles / (s.clock_hz * p.utilization)

        if not p.model_memory:
            t = compute_t + s.kernel_overhead_s
        else:
            eb = np.array([float(DTYPE_BYTES.get(dt, 2))
                           for dt in arrays.dtype_table], dtype=np.float64)
            bytes_moved = b * (m * k + k * n + m * n) \
                * eb[arrays.gemm_dtype_idx]
            mem_t = bytes_moved / s.mem_bw
            if p.double_buffer:
                t = np.maximum(compute_t, mem_t)
            else:
                t = compute_t + mem_t
            t = t + s.kernel_overhead_s
        vals = t.tolist()
        offs = arrays.gemm_offsets.tolist()
        out = []
        for r in range(arrays.num_regions):
            total = 0.0
            for v in vals[offs[r]:offs[r + 1]]:
                total += v
            out.append(total)
        return out
