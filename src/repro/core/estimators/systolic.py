"""Simulation-based estimator: cycle-approximate systolic-array model.

TPU-native adaptation of the paper's §IV-C3 estimator class (ONNXim,
COCOSSim, SCALE-Sim, ZigZag).  The model walks the HBM→VMEM→MXU hierarchy:

  * the GEMM is tiled into (mxu_rows × mxu_cols) output tiles with the
    contraction streamed through the array (weight-stationary);
  * each output tile costs K + fill cycles, where fill = rows + cols - 2
    is the systolic fill/drain latency;
  * tiles pipeline across ``n_mxu`` arrays; double buffering overlaps the
    HBM→VMEM stream of the next tile with compute unless disabled;
  * the final latency is max(compute pipeline, memory stream) + overhead.

Four presets reproduce the fidelity spread of the paper's Fig 10:
  onnxim    — double-buffered, high utilization (closest to TPU trends)
  cocossim  — double-buffered, per-tile re-fill charged (slightly slower)
  scalesim  — no double buffering, serial tile loads (pessimistic)
  zigzag    — pure compute cycles, no fill/memory modeling (optimistic)

Supports only matrix-multiplication regions natively (``supports``); pair
with a roofline fallback through MixedEstimator, as the paper pairs
COCOSSim with an analytical TPU estimator.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from ..ir.graph import OpNode
from ..ir.types import DTYPE_BYTES
from ..registry import register_estimator
from ..slicing.regions import ComputeRegion
from ..systems import System
from .base import ComputeEstimator


@dataclass(frozen=True)
class SystolicPreset:
    name: str
    double_buffer: bool = True
    charge_fill_per_tile: bool = True
    model_memory: bool = True
    utilization: float = 1.0       # sustained/peak derate


PRESETS = {
    "onnxim": SystolicPreset("onnxim", True, False, True, 0.95),
    "cocossim": SystolicPreset("cocossim", True, True, True, 0.90),
    "scalesim": SystolicPreset("scalesim", False, True, True, 0.85),
    "zigzag": SystolicPreset("zigzag", True, False, False, 1.0),
}


def _gemm_dims(op: OpNode) -> tuple[int, int, int, int] | None:
    """(batch, M, N, K) of a dot_general, or None."""
    if op.op != "dot_general" or len(op.operand_types) < 2:
        return None
    lhs, rhs = op.operand_types[0], op.operand_types[1]
    lb = op.attrs.get("lhs_batch", ())
    lc = op.attrs.get("lhs_contract", ())
    rb = op.attrs.get("rhs_batch", ())
    rc = op.attrs.get("rhs_contract", ())
    batch = math.prod(lhs.shape[d] for d in lb) if lb else 1
    k = math.prod(lhs.shape[d] for d in lc) if lc else 1
    m = math.prod(d for i, d in enumerate(lhs.shape)
                  if i not in lb and i not in lc)
    n = math.prod(d for i, d in enumerate(rhs.shape)
                  if i not in rb and i not in rc)
    return batch, m, n, k


@register_estimator("systolic")
class SystolicEstimator(ComputeEstimator):
    """Cycle-approximate MXU model behind the Compute API."""

    def __init__(self, system: System, preset: str = "cocossim"):
        super().__init__(system)
        self.preset = PRESETS[preset]
        self.toolchain = f"systolic-{preset}"

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "SystolicEstimator":
        return cls(system, options.get("preset", "cocossim"))

    def supports(self, region: ComputeRegion) -> bool:
        """Native support: regions whose cost is ≥90% matmul flops."""
        mat = sum(op_flops for op in region.ops
                  for op_flops in [self._matmul_flops(op)])
        total = region.cost.flops
        return total > 0 and mat / total >= 0.9

    @staticmethod
    def _matmul_flops(op: OpNode) -> float:
        dims = _gemm_dims(op)
        if dims is None:
            total = 0.0
            for r in op.regions:
                for sub in r:
                    total += SystolicEstimator._matmul_flops(sub)
            return total * max(op.trip_count, 1)
        b, m, n, k = dims
        return 2.0 * b * m * n * k

    def gemm_latency(self, m: int, n: int, k: int, batch: int = 1,
                     dtype: str = "bf16") -> float:
        p = self.preset
        s = self.system
        rows, cols = s.mxu_rows, s.mxu_cols
        tiles_m = math.ceil(m / rows)
        tiles_n = math.ceil(n / cols)
        fill = rows + cols - 2
        if p.charge_fill_per_tile:
            cycles_per_tile = k + fill
        else:
            # fill amortized across the tile stream (pipelined drain)
            cycles_per_tile = k
        tiles = tiles_m * tiles_n * batch
        compute_cycles = tiles * cycles_per_tile / s.n_mxu + fill
        compute_t = compute_cycles / (s.clock_hz * p.utilization)

        if not p.model_memory:
            return compute_t + s.kernel_overhead_s
        eb = DTYPE_BYTES.get(dtype, 2)
        bytes_moved = batch * (m * k + k * n + m * n) * eb
        mem_t = bytes_moved / s.mem_bw
        if p.double_buffer:
            t = max(compute_t, mem_t)
        else:
            t = compute_t + mem_t
        return t + s.kernel_overhead_s

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        total = 0.0
        for op in region.ops:
            total += self._op_latency(op)
        return total

    def _op_latency(self, op: OpNode) -> float:
        dims = _gemm_dims(op)
        if dims is not None:
            b, m, n, k = dims
            dtype = op.operand_types[0].dtype if op.operand_types else "bf16"
            return self.gemm_latency(m, n, k, batch=b, dtype=dtype)
        total = 0.0
        for r in op.regions:
            for sub in r:
                total += self._op_latency(sub)
        return total * max(op.trip_count, 1)
