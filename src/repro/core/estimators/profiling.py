"""Profiling estimator (paper §IV-C2).

Each compute region is re-emitted as a standalone StableHLO module,
compiled with the in-process XLA client for the host platform, and executed
with synthetic inputs; the measured median runtime is the region latency.
This mirrors ``hlo_runner_main``-based profiling, including its
characteristic bias: compilation scope is truncated at region boundaries,
so cross-region fusion/global optimization is lost — the profiling path is
systematically pessimistic (paper §V-A).

When the profiled platform differs from the target system, latencies are
rescaled by the roofline ratio of the two systems for the region's dominant
resource (a pragmatic cross-platform projection; flagged in results as
``projected=True``).
"""
from __future__ import annotations

import statistics
import time

import numpy as np

from ..ir.graph import Program
from ..registry import register_estimator
from ..slicing.emit import RegionEmitError, region_to_module
from ..slicing.regions import ComputeRegion
from ..systems import System, host_system
from .analytical import RooflineEstimator
from .base import ComputeEstimator

_F_DTYPES = {"f16": np.float16, "f32": np.float32, "f64": np.float64}
_I_DTYPES = {"s8": np.int8, "s16": np.int16, "s32": np.int32,
             "s64": np.int64, "u8": np.uint8, "u16": np.uint16,
             "u32": np.uint32, "u64": np.uint64, "i1": np.bool_,
             "pred": np.bool_}


def _synthetic(t) -> np.ndarray:
    if t.dtype == "bf16":
        try:
            import ml_dtypes
            return np.random.default_rng(0).standard_normal(
                t.shape, dtype=np.float32).astype(ml_dtypes.bfloat16)
        except ImportError:
            return np.random.default_rng(0).standard_normal(
                t.shape, dtype=np.float32)
    if t.dtype in _F_DTYPES:
        return np.random.default_rng(0).standard_normal(t.shape).astype(
            _F_DTYPES[t.dtype])
    if t.dtype in _I_DTYPES:
        if t.dtype in ("i1", "pred"):
            return np.zeros(t.shape, np.bool_)
        return np.zeros(t.shape, _I_DTYPES[t.dtype])
    return np.zeros(t.shape, np.float32)


@register_estimator("profiling")
class ProfilingEstimator(ComputeEstimator):
    toolchain = "xla-host"

    def __init__(self, system: System | None = None, program: Program | None = None,
                 runs: int = 5, target_system: System | None = None):
        """``system``: platform actually profiled (defaults to host).
        ``target_system``: if set, results are roofline-projected onto it.
        ``program``: the source program (needed for region re-emission)."""
        super().__init__(system or host_system())
        self.program = program
        self.runs = runs
        self.target_system = target_system
        self._backend = None
        self.fallback = RooflineEstimator(self.system, mode="per-op",
                                          include_overheads=True)
        self.emit_failures = 0

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "ProfilingEstimator":
        """Spec form: profile on the host, roofline-projecting onto the
        grid system — unless the grid system *is* the host (ground-truth
        mode, no projection)."""
        target = None if context.system_name == "host" else system
        return cls(program=context.program,
                   runs=int(options.get("runs", 3)),
                   target_system=target)

    # Compute API
    def get_compile_args(self) -> dict:
        return {"backend": "cpu", "num_partitions": 1}

    def get_exec_args(self) -> dict:
        return {"runs": self.runs, "reduction": "median"}

    def _get_backend(self):
        if self._backend is None:
            import jax
            self._backend = jax.devices("cpu")[0].client
        return self._backend

    def _compile(self, module_text: str):
        from jax._src import compiler
        from jax._src.interpreters import mlir as jmlir
        from jax._src.lib.mlir import ir
        backend = self._get_backend()
        with jmlir.make_ir_context():
            module = ir.Module.parse(module_text)
        opts = compiler.get_compile_options(num_replicas=1, num_partitions=1)
        if hasattr(compiler, "backend_compile_and_load"):  # jax >= 0.6
            try:
                from jaxlib._jax import DeviceList
            except ImportError:
                from jaxlib.xla_extension import DeviceList
            dl = DeviceList(tuple(backend.devices()[:1]))
            return compiler.backend_compile_and_load(
                backend, module, dl, opts, [])
        # 0.4.x compat shim: drop this branch (keep only
        # backend_compile_and_load) when the jax floor moves to >= 0.6
        return compiler.backend_compile(backend, module, opts, [])

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        if self.program is None:
            return self.fallback.get_run_time_estimate(region)
        try:
            module_text, in_types = region_to_module(
                region.ops, self.program, name="profiled_region")
            exe = self._compile(module_text)
        except Exception:
            self.emit_failures += 1
            return self.fallback.get_run_time_estimate(region)
        backend = self._get_backend()
        bufs = [backend.buffer_from_pyval(_synthetic(t)) for t in in_types]
        try:
            exe.execute(bufs)  # warmup
            times = []
            for _ in range(self.runs):
                t0 = time.perf_counter()
                out = exe.execute(bufs)
                for o in out:
                    o.block_until_ready()
                times.append(time.perf_counter() - t0)
            measured = statistics.median(times)
        except Exception:
            self.emit_failures += 1
            return self.fallback.get_run_time_estimate(region)
        return self._project(region, measured)

    def _project(self, region: ComputeRegion, host_seconds: float) -> float:
        """Project a host-measured latency onto the target system."""
        if self.target_system is None:
            return host_seconds
        src, dst = self.system, self.target_system
        dtype = "f32"
        for op in region.ops:
            if op.result_types:
                dtype = op.result_types[0].dtype
                break
        compute_ratio = src.flops_for(dtype) / dst.flops_for(dtype)
        memory_ratio = src.mem_bw / dst.mem_bw
        # dominant resource on the *target* decides the scaling
        c_t = region.cost.flops / dst.flops_for(dtype)
        m_t = (region.boundary_in_bytes + region.boundary_out_bytes) / dst.mem_bw
        ratio = compute_ratio if c_t >= m_t else memory_ratio
        return host_seconds * ratio

    @property
    def cache_hw_key(self) -> str:
        tgt = self.target_system.name if self.target_system else "native"
        return f"{self.system.name}->{tgt}"

    @property
    def cache_config_key(self) -> str:
        return f"runs{self.runs}"
