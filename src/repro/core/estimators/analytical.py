"""Analytical roofline estimator (paper §IV-C1).

Per-operator roofline using peak FLOP/s and peak memory bandwidth, selecting
the dominant bottleneck.  Fused regions are modeled as a single compute
region: memory traffic is accounted only at the region boundaries while the
full compute cost of all constituent operators is preserved — this lets
*optimized* StableHLO inputs be consumed directly and is what makes the
analytical path consistently optimistic relative to hardware.
"""
from __future__ import annotations

import numpy as np

from ..ir.arrays import RegionArrays
from ..ir.opcost import op_cost
from ..registry import register_estimator
from ..slicing.regions import ComputeRegion
from ..systems import System
from .base import ComputeEstimator


@register_estimator("roofline")
class RooflineEstimator(ComputeEstimator):
    toolchain = "roofline"

    def __init__(self, system: System, mode: str = "region",
                 include_overheads: bool = False):
        """mode: 'region' (boundary-bytes; optimistic, for optimized IR) or
        'per-op' (per-operator max(compute, memory) summed; for raw IR)."""
        super().__init__(system)
        assert mode in ("region", "per-op")
        self.mode = mode
        self.include_overheads = include_overheads

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "RooflineEstimator":
        return cls(system, mode=options.get("mode", "region"),
                   include_overheads=bool(
                       options.get("include_overheads", False)))

    @property
    def cache_config_key(self) -> str:
        return self.mode + ("+ovh" if self.include_overheads else "")

    def _dtype_of(self, region: ComputeRegion) -> str:
        # dominant dtype by output bytes across matmul-ish ops, else first op
        best, best_bytes = "bf16", -1.0
        for op in region.ops:
            for t in op.result_types:
                if t.nbytes > best_bytes:
                    best, best_bytes = t.dtype, t.nbytes
        return best

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        sysm = self.system
        if self.mode == "region":
            dtype = self._dtype_of(region)
            compute_t = region.cost.flops / sysm.flops_for(dtype)
            mem_bytes = region.boundary_in_bytes + region.boundary_out_bytes
            memory_t = mem_bytes / sysm.mem_bw
            t = max(compute_t, memory_t)
            if self.include_overheads:
                t += sysm.kernel_overhead_s
            return t
        total = 0.0
        for op in region.ops:
            c = op_cost(op)
            dtype = (op.result_types[0].dtype if op.result_types else "bf16")
            t = max(c.flops / sysm.flops_for(dtype), c.bytes / sysm.mem_bw)
            if self.include_overheads and (c.flops > 0 or c.bytes > 0):
                t += sysm.kernel_overhead_s
            total += t
        return total

    def evaluate_batch(self, arrays: RegionArrays) -> list[float]:
        """All regions of a plan in a handful of vectorized expressions.

        Bit-identical to calling :meth:`get_run_time_estimate` per region:
        every value is the same float64 operation sequence — numpy's
        elementwise divide/maximum are IEEE double ops, the overhead add
        happens after the max exactly as the scalar path orders it, and
        per-op mode sums each region's op latencies left-to-right in
        Python (``sum`` over a numpy slice would not preserve the scalar
        loop's associativity).  In per-op mode the overhead lands only on
        active ops via the precomputed 0/1 mask (``t + 0.0 == t`` for the
        non-negative latencies involved)."""
        sysm = self.system
        peak = np.array([sysm.flops_for(dt) for dt in arrays.dtype_table],
                        dtype=np.float64)
        if self.mode == "region":
            t = np.maximum(arrays.flops / peak[arrays.dtype_idx],
                           arrays.boundary_bytes / sysm.mem_bw)
            if self.include_overheads:
                t = t + sysm.kernel_overhead_s
            return t.tolist()
        op_t = np.maximum(arrays.op_flops / peak[arrays.op_dtype_idx],
                          arrays.op_bytes / sysm.mem_bw)
        if self.include_overheads:
            op_t = op_t + arrays.op_active * sysm.kernel_overhead_s
        vals = op_t.tolist()
        offs = arrays.op_offsets.tolist()
        out = []
        for r in range(arrays.num_regions):
            total = 0.0
            for v in vals[offs[r]:offs[r + 1]]:
                total += v
            out.append(total)
        return out
