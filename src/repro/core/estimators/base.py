"""The Compute API (paper §III-B(c)).

Every estimator implements the same minimal interface so they can be mixed
within one workload (e.g. systolic for GEMM regions + analytical for the
rest) while preserving a single point of latency collection:

  * ``get_run_time_estimate(region)`` -> seconds
  * ``get_compile_args()``  (optional) -> compiler flags/config
  * ``get_exec_args()``     (optional) -> runtime flags (#runs, ...)
"""
from __future__ import annotations

import abc

from ..registry import register_estimator
from ..slicing.regions import ComputeRegion
from ..systems import System


class ComputeEstimator(abc.ABC):
    """Base class of the Compute API."""

    #: identifies the 'compilation toolchain' C in the (H, C, R) cache key
    toolchain: str = "none"

    def __init__(self, system: System):
        self.system = system

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "ComputeEstimator":
        """Build from a campaign-spec options dict (the registry builder
        protocol — see :mod:`repro.core.registry`).  The default assumes
        options map straight onto constructor keywords; backends with
        richer wiring (sub-estimators, source programs) override this."""
        return cls(system, **options)

    @abc.abstractmethod
    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        """Estimated latency of one execution of the region, in seconds."""

    def get_run_time_estimates(self, regions: list[ComputeRegion],
                               arrays=None) -> list[float]:
        """Batched form of :meth:`get_run_time_estimate`.

        The evaluate phase hands every compute region of a plan over in
        one call; plain estimators just loop, while
        :class:`~repro.core.estimators.cache.CachedEstimator` overrides
        this to fetch all cached latencies in a single store round-trip.

        ``arrays`` is the plan's precomputed
        :class:`~repro.core.ir.arrays.RegionArrays` for the same regions
        in the same order.  Estimators that implement
        ``evaluate_batch(arrays)`` (a vectorized pass producing values
        bit-identical to the per-region method) are dispatched through
        it; everything else ignores ``arrays`` and loops.  An
        ``evaluate_batch`` may return None to decline a batch its
        vector path cannot replay exactly (e.g. the systolic model on
        plans with GEMMs inside nested control flow) — declined batches
        fall back to the scalar loop.
        """
        if arrays is not None:
            batch = getattr(self, "evaluate_batch", None)
            if batch is not None:
                values = batch(arrays)
                if values is not None:
                    return values
        return [self.get_run_time_estimate(r) for r in regions]

    def get_compile_args(self) -> dict:
        return {}

    def get_exec_args(self) -> dict:
        return {}

    def supports(self, region: ComputeRegion) -> bool:
        """Whether this estimator can evaluate the region natively.

        Narrow estimators (e.g. systolic-array simulators that only model
        matrix multiplication) return False for regions outside their scope;
        the pipeline then falls back to a paired estimator — the paper's
        mixed-estimator mechanism.
        """
        return True

    @property
    def cache_hw_key(self) -> str:
        return self.system.name

    @property
    def cache_config_key(self) -> str:
        """Estimator configuration that can change the latency value.

        Folded into the cache key alongside (H, C, R): two differently
        configured instances of the same estimator class (e.g. roofline
        region vs per-op mode) must not serve each other's entries when
        they share one store."""
        return ""


@register_estimator("mixed")
class MixedEstimator(ComputeEstimator):
    """Primary estimator + fallback for unsupported regions (paper §III-B(c))."""

    def __init__(self, primary: ComputeEstimator, fallback: ComputeEstimator):
        super().__init__(primary.system)
        self.primary = primary
        self.fallback = fallback
        self.toolchain = f"{primary.toolchain}+{fallback.toolchain}"

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "MixedEstimator":
        """Spec form: systolic primary + roofline fallback (the paper's
        COCOSSim-plus-analytical pairing); ``preset`` configures the
        primary."""
        from .analytical import RooflineEstimator
        from .systolic import SystolicEstimator
        return cls(
            SystolicEstimator(system, options.get("preset", "cocossim")),
            RooflineEstimator(system))

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        if self.primary.supports(region):
            return self.primary.get_run_time_estimate(region)
        return self.fallback.get_run_time_estimate(region)

    def supports(self, region: ComputeRegion) -> bool:
        return True

    @property
    def cache_config_key(self) -> str:
        return f"{self.primary.cache_config_key}+{self.fallback.cache_config_key}"
