"""(H × C × R) latency cache (paper §III-B(c)).

Key = (target hardware H, compilation toolchain C, compute region R);
changing any of the three can change latency, nothing else can.  Stacked
transformer blocks produce identical region fingerprints, so an L-layer
model pays for one evaluation per distinct block — the mechanism behind
the paper's 89.7 % (Llama-3) / 26.8 % (ResNet) evaluation-time savings.

Two layers:

  * :class:`CachedEstimator` — the in-run memo wrapping any estimator.
  * :class:`PersistentCache`  — an on-disk store of the same keyed entries
    that campaigns, benchmarks, and repeated runs share across processes,
    extending the within-run savings to across-run savings.

The on-disk format is a *file-locked append log* (JSONL): line 1 is a
version header, every further line is one ``{"k": key, "v": seconds,
"c": eval_cost_seconds}`` record.  Appends take an exclusive ``flock``
(batched: :meth:`PersistentCache.put_many` writes a whole evaluate
phase's fresh entries under one lock); readers take a shared one and
:meth:`PersistentCache.refresh` absorbs only the log tail written since
the last read, skipping the lock entirely while a cheap ``stat`` shows
the file unchanged — which is what lets
process-pool campaign workers sharing one cache path observe each
other's freshly computed entries *mid-campaign* instead of a startup
snapshot.  Each entry also carries the wall-clock cost of the estimator
evaluation that produced it, so a later run (or another process) that
hits the entry can account the time it *avoided* — making
``CacheStats.time_saving_fraction`` meaningful across runs, not just
within one.

Alongside the log lives an *offset index* sidecar (``<path>.idx``):
``{"k": key, "o": byte_offset}`` lines mapping each key to its latest
log record, plus ``{"c": offset}`` coverage markers recording how far
into the log the index is complete.  The sidecar is written under the
**same** flock round-trip as the log lines it describes (the log file's
lock is the single synchronization point for both files), so it costs no
extra lock traffic and can never get ahead of the log.  It buys point
lookups: :meth:`get_many` resolves keys absent from memory by seeking
straight to their records — O(1) per key, ``scan_bytes`` counts only the
record lines actually read — instead of absorbing the whole unread log
tail; only keys the index does not cover fall back to tailing the
uncovered suffix.  A stale, torn, or missing index is never trusted
blindly — coverage markers bound what it may be believed about, the
header generation ties it to one log compaction, and
:meth:`rebuild_index` (called automatically by the next ``put_many``)
regenerates it from the log, which remains the single source of truth.
``lazy=True`` construction reads just the header and the index, deferring
all record I/O to lookups — the cold-start mode for processes that touch
a handful of keys from a large shared store.

The format is versioned: ``SCHEMA_VERSION`` guards the file layout and
``FINGERPRINT_VERSION`` guards the region-fingerprint algorithm (the R
of the key).  Bumping either invalidates stale files on load instead of
silently serving latencies keyed by an incompatible fingerprint.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from dataclasses import dataclass, field
from typing import MutableMapping

from ..slicing.regions import ComputeRegion
from .base import ComputeEstimator

#: bump when the on-disk layout changes (2 = JSONL append log + costs)
SCHEMA_VERSION = 2
#: bump when slicing.regions.region_fingerprint changes what it hashes
FINGERPRINT_VERSION = 1

try:
    import fcntl

    def _lock_sh(f):
        fcntl.flock(f.fileno(), fcntl.LOCK_SH)

    def _lock_ex(f):
        fcntl.flock(f.fileno(), fcntl.LOCK_EX)

    def _unlock(f):
        fcntl.flock(f.fileno(), fcntl.LOCK_UN)
except ImportError:  # non-POSIX: degrade to unlocked (single-process) use
    def _lock_sh(f):
        pass

    def _lock_ex(f):
        pass

    def _unlock(f):
        pass


@dataclass
class CacheStats:
    """Hit/miss counters plus the paper's evaluation-time accounting.

    ``saved_seconds`` is the estimator wall time *avoided* by hits: for a
    key this run computed itself it is the measured cost of that first
    evaluation; for a key served from a shared/persistent store it is the
    cost persisted by whichever run computed it."""
    hits: int = 0
    misses: int = 0
    saved_seconds: float = 0.0     # estimator wall-time avoided (measured)
    miss_cost_seconds: float = 0.0  # wall-time actually spent on misses
    per_key_cost: dict = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def time_saving_fraction(self) -> float:
        """Fraction of evaluation time avoided by caching (paper's metric)."""
        would_be = self.saved_seconds + self.miss_cost_seconds
        return self.saved_seconds / would_be if would_be > 0 else 0.0


class PersistentCache:
    """On-disk (H, C, R) -> (seconds, eval cost) store shared across runs
    *and live processes*.

    Thread-safe within one process.  Across processes the backing file is
    an append log guarded by ``flock``: :meth:`append` writes through
    immediately (absorbing any lines other processes appended first) and
    :meth:`refresh` tails the log, so two workers pointed at one path see
    each other's fresh entries mid-run.  :meth:`save` compacts the log
    (atomic tmp + rename).  Entries are deterministic per key for a given
    estimator, so last-writer-wins races are harmless.
    """

    #: test-only crash-simulation hook (``repro.serve.faults``): called
    #: after a batch is flushed but before index maintenance; returning
    #: True skips the index step (a writer that died between the two).
    fault_hook = None

    def __init__(self, path: str | None = None, lazy: bool = False):
        self.path = path
        self.entries: dict[str, float] = {}
        self.costs: dict[str, float] = {}
        self.loaded_entries = 0
        self.lock_roundtrips = 0  # flock acquisitions (I/O cost accounting)
        self.scan_bytes = 0       # log bytes actually read (records only)
        self.point_reads = 0      # single-record reads served by the index
        self._lock = threading.Lock()
        self._offset = 0          # bytes of the log already absorbed
        self._header_ok = False   # file exists with a matching header
        self._gen: str | None = None  # header generation id last seen
        self._stat: tuple | None = None  # (ino, size, mtime_ns) last synced
        self._idx: dict[str, int] = {}  # key -> log offset of latest record
        self._idx_cover = 0       # log bytes the index fully describes
        self._idx_offset = 0      # sidecar bytes already absorbed
        self._idx_bad = False     # sidecar torn/foreign: rebuild before use
        if path:
            self.load(path, lazy=lazy)

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def __getitem__(self, key: str) -> float:
        return self.entries[key]

    def __setitem__(self, key: str, value: float) -> None:
        with self._lock:
            self.entries[key] = value

    def get(self, key: str, default=None):
        return self.entries.get(key, default)

    def cost(self, key: str) -> float:
        """Persisted estimator wall cost of the evaluation behind ``key``
        (0.0 when the producing run predates cost persistence)."""
        return self.costs.get(key, 0.0)

    def stats_dict(self) -> dict:
        """The store's live accounting, JSON-ready — what long-lived
        holders (the serve daemon's ``/stats``, campaign summaries)
        surface without reaching into internals."""
        with self._lock:
            return {
                "path": self.path,
                "entries": len(self.entries),
                "loaded_entries": self.loaded_entries,
                "persisted_cost_seconds": round(
                    sum(self.costs.values()), 6),
                "lock_roundtrips": self.lock_roundtrips,
                "scan_bytes": self.scan_bytes,
                "point_reads": self.point_reads,
                "index_keys": len(self._idx),
            }

    # ------------------------------ log I/O ------------------------------

    def _absorb_line(self, line: str) -> int:
        """Parse one log line into memory; returns 1 for a new entry."""
        line = line.strip()
        if not line:
            return 0
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            return 0  # torn tail of a crashed writer — ignorable
        if not isinstance(rec, dict) or "k" not in rec:
            return 0
        key = str(rec["k"])
        new = key not in self.entries
        self.entries[key] = float(rec.get("v", 0.0))
        if rec.get("c"):
            self.costs[key] = float(rec["c"])
        return 1 if new else 0

    @staticmethod
    def _parse_header_gen(line: str) -> str | None:
        """The generation id of a valid v2 header, None for foreign/stale."""
        try:
            h = json.loads(line)
        except json.JSONDecodeError:
            return None
        if not (isinstance(h, dict)
                and h.get("schema") == SCHEMA_VERSION
                and h.get("fingerprint") == FINGERPRINT_VERSION):
            return None
        return str(h.get("gen", ""))

    def _sync_locked(self, f) -> tuple[bool, int]:
        """With the flock *and* ``self._lock`` held: validate the header,
        detect compaction, absorb every unread record.

        Compaction by another process is detected via the header's
        generation id (every :meth:`save` writes a fresh one), not file
        size — a compacted log that regrew past the old offset would
        otherwise be tailed from a stale mid-record position.  Returns
        ``(valid_file, newly_seen_keys)``.
        """
        st = os.fstat(f.fileno())
        size = st.st_size
        self._stat = (st.st_ino, st.st_size, st.st_mtime_ns)
        if size == 0:
            self._offset = 0
            self._header_ok = False
            return True, 0
        f.seek(0)
        gen = self._parse_header_gen(f.readline())
        if gen is None:
            self._header_ok = False
            return False, 0
        header_end = f.tell()
        if gen != self._gen:
            # compaction rewrote the log: every indexed byte offset is
            # stale; drop the index and re-absorb the sidecar (or rebuild)
            self._reset_index_locked()
        if (gen != self._gen or not self._header_ok
                or self._offset < header_end or self._offset > size):
            self._gen = gen
            self._header_ok = True
            self._offset = header_end
        f.seek(self._offset)
        new = 0
        for line in f:
            self.scan_bytes += len(line)
            new += self._absorb_line(line)
        self._offset = f.tell()
        return True, new

    # ----------------------------- offset index -----------------------------

    def _reset_index_locked(self) -> None:
        self._idx.clear()
        self._idx_cover = 0
        self._idx_offset = 0
        self._idx_bad = False

    def _read_index_locked(self, gen: str) -> None:
        """Absorb unread sidecar lines (``self._lock`` held; caller holds
        the log flock, which also guards the sidecar).

        The header must tie the sidecar to the log generation ``gen``;
        a foreign/torn sidecar is flagged for rebuild, never trusted.
        Garbled lines (a torn batch from a crashed writer) are skipped —
        coverage markers only advance on intact batches, so whatever the
        crash left unindexed stays inside the uncovered suffix."""
        if self._idx_bad or not self.path:
            return
        try:
            with open(self.path + ".idx") as fi:
                fi.seek(self._idx_offset)
                if self._idx_offset == 0:
                    first = fi.readline()
                    if not first:
                        return
                    try:
                        h = json.loads(first)
                    except json.JSONDecodeError:
                        h = None
                    if not (isinstance(h, dict)
                            and h.get("schema") == SCHEMA_VERSION
                            and h.get("fingerprint") == FINGERPRINT_VERSION
                            and str(h.get("gen", "")) == gen):
                        self._idx_bad = True
                        return
                for line in fi:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue
                    if not isinstance(rec, dict):
                        continue
                    if "k" in rec and "o" in rec:
                        self._idx[str(rec["k"])] = int(rec["o"])
                    elif "c" in rec:
                        self._idx_cover = max(self._idx_cover, int(rec["c"]))
                self._idx_offset = fi.tell()
        except (OSError, ValueError, TypeError):
            return

    def _append_index_locked(self, offs: dict[str, int], cover: int) -> None:
        """Append fresh key->offset lines plus a coverage marker (under
        the log flock — the sidecar shares the log's lock)."""
        try:
            with open(self.path + ".idx", "a") as fi:
                for k, o in offs.items():
                    fi.write(json.dumps({"k": k, "o": o},
                                        separators=(",", ":")) + "\n")
                fi.write(json.dumps({"c": cover},
                                    separators=(",", ":")) + "\n")
                fi.flush()
                self._idx_offset = fi.tell()
        except OSError:
            return
        self._idx.update(offs)
        self._idx_cover = max(self._idx_cover, cover)

    def _write_index_locked(self, gen: str, offs: dict[str, int],
                            cover: int) -> None:
        """Atomically replace the sidecar (tmp + rename) with a fresh
        header tied to ``gen`` plus the full key->offset map."""
        ip = self.path + ".idx"
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(ip) or ".",
                                   prefix=".cacheidx-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fi:
                fi.write(json.dumps(
                    {"schema": SCHEMA_VERSION,
                     "fingerprint": FINGERPRINT_VERSION, "gen": gen}) + "\n")
                for k, o in offs.items():
                    fi.write(json.dumps({"k": k, "o": o},
                                        separators=(",", ":")) + "\n")
                fi.write(json.dumps({"c": cover},
                                    separators=(",", ":")) + "\n")
                size = fi.tell()
            os.replace(tmp, ip)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        self._idx = dict(offs)
        self._idx_cover = cover
        self._idx_offset = size
        self._idx_bad = False

    def _rebuild_index_locked(self, f) -> int:
        """Regenerate the sidecar from the log — the single source of
        truth.  Returns the number of indexed keys.  Caller holds the log
        flock and ``self._lock``."""
        f.seek(0)
        gen = self._parse_header_gen(f.readline())
        if gen is None:
            return 0
        self._reset_index_locked()
        offs: dict[str, int] = {}
        pos = f.tell()
        for line in iter(f.readline, ""):
            self.scan_bytes += len(line)
            s = line.strip()
            if s:
                try:
                    rec = json.loads(s)
                    if isinstance(rec, dict) and "k" in rec:
                        offs[str(rec["k"])] = pos
                except json.JSONDecodeError:
                    pass
            pos = f.tell()
        self._write_index_locked(gen, offs, pos)
        return len(offs)

    def rebuild_index(self) -> int:
        """Regenerate ``<path>.idx`` from the log (crash recovery / manual
        repair); also happens automatically on the next :meth:`put_many`
        that finds the sidecar missing, torn, or trailing the log.
        Returns #indexed keys."""
        if not self.path or not os.path.exists(self.path):
            return 0
        with open(self.path, "a+") as f:
            _lock_ex(f)
            self.lock_roundtrips += 1
            try:
                with self._lock:
                    return self._rebuild_index_locked(f)
            finally:
                _unlock(f)

    def _lookup_missing(self, keys: list[str]) -> int:
        """Resolve keys absent from memory via index point-reads.

        One shared-flock round-trip for the whole batch: validate the log
        header, absorb any fresh sidecar lines, then seek straight to each
        indexed key's record — ``scan_bytes`` grows by just those record
        lines.  Keys the index does not know fall back to tailing only the
        log suffix past the index's coverage marker.  A point-read of a
        record another process since superseded is harmless: entries are
        deterministic per key, and full absorption stays idempotent
        (``self._offset`` is never advanced here).  Skipped entirely —
        zero I/O, zero locks — while a ``stat`` shows the log unchanged
        since the last *full* sync, because then absent-in-memory means
        absent-on-disk.  Returns the number of newly resolved keys."""
        if not self.path or not os.path.exists(self.path):
            return 0
        try:
            st = os.stat(self.path)
            if (self._header_ok and self._stat is not None
                    and (st.st_ino, st.st_size, st.st_mtime_ns)
                    == self._stat):
                return 0
        except OSError:
            return 0
        new = 0
        try:
            with open(self.path) as f:
                _lock_sh(f)
                self.lock_roundtrips += 1
                try:
                    with self._lock:
                        f.seek(0)
                        gen = self._parse_header_gen(f.readline())
                        if gen is None:
                            return 0
                        header_end = f.tell()
                        if gen != self._gen:
                            self._reset_index_locked()
                            self._gen = gen
                            self._offset = header_end
                        self._header_ok = True
                        self._read_index_locked(gen)
                        unresolved = []
                        for k in keys:
                            if k in self.entries:
                                continue
                            o = self._idx.get(k)
                            if o is None:
                                unresolved.append(k)
                                continue
                            f.seek(o)
                            line = f.readline()
                            self.scan_bytes += len(line)
                            self.point_reads += 1
                            new += self._absorb_line(line)
                            if k not in self.entries:
                                unresolved.append(k)
                        if unresolved:
                            # tail only the suffix the index does not
                            # cover; don't advance _offset — this is not
                            # a contiguous absorb from it
                            start = max(self._idx_cover, header_end,
                                        self._offset)
                            f.seek(start)
                            for line in f:
                                self.scan_bytes += len(line)
                                new += self._absorb_line(line)
                finally:
                    _unlock(f)
        except OSError:
            return new
        return new

    def load(self, path: str, lazy: bool = False) -> int:
        """Load a cache log; stale/foreign files are discarded, not errors.

        ``lazy=True`` reads only the header and the offset-index sidecar —
        no records — leaving all entry I/O to later :meth:`get_many` point
        lookups (or a full :meth:`refresh`)."""
        self.path = path
        if not os.path.exists(path):
            return 0
        try:
            with open(path) as f:
                _lock_sh(f)
                self.lock_roundtrips += 1
                try:
                    with self._lock:
                        if lazy:
                            f.seek(0)
                            gen = self._parse_header_gen(f.readline())
                            if gen is not None:
                                if gen != self._gen:
                                    self._gen = gen
                                    self._offset = f.tell()
                                self._header_ok = True
                                self._read_index_locked(gen)
                            return 0
                        ok, new = self._sync_locked(f)
                        if ok:
                            self.loaded_entries = new
                finally:
                    _unlock(f)
        except OSError:
            return 0
        return self.loaded_entries

    def refresh(self) -> int:
        """Absorb log records other processes wrote since the last read.

        Cheap when nothing changed: one ``stat`` — the locked tail-read is
        skipped entirely while the log's (inode, size, mtime) triple
        matches the state last synced, so hot lookup paths pay no flock
        when no other process has written.  Compaction by another process
        is detected via the header generation id and triggers a full
        re-read (in-memory entries are kept — absorption only
        adds/overwrites).  Returns the number of previously unseen keys.
        """
        if not self.path or not os.path.exists(self.path):
            return 0
        try:
            st = os.stat(self.path)
            if (self._header_ok and self._stat is not None
                    and (st.st_ino, st.st_size, st.st_mtime_ns)
                    == self._stat):
                return 0
        except OSError:
            return 0
        try:
            with open(self.path) as f:
                _lock_sh(f)
                self.lock_roundtrips += 1
                try:
                    with self._lock:
                        ok, new = self._sync_locked(f)
                finally:
                    _unlock(f)
        except OSError:
            return 0
        return new if ok else 0

    def append(self, key: str, value: float, cost: float = 0.0) -> None:
        """Record one entry and write it through to the shared log."""
        self.put_many({key: (value, cost)})

    def get_many(self, keys: list[str]) -> dict[str, float]:
        """Look up a batch of keys in one store round-trip.

        A path-backed store touches the shared log at most *once* for the
        whole batch (and only when some key is absent in memory) instead
        of once per key — the lock-amortized lookup the evaluate phase
        uses.  Inside that single round-trip, indexed keys are resolved
        by seeking straight to their records (:meth:`_lookup_missing`)
        rather than absorbing the whole unread tail.  Returns only the
        keys present."""
        if self.path:
            missing = [k for k in keys if k not in self.entries]
            if missing:
                self._lookup_missing(missing)
        with self._lock:
            return {k: self.entries[k] for k in keys if k in self.entries}

    def put_many(self, records: MutableMapping) -> None:
        """Record a batch of entries and write them through to the shared
        log under a *single* exclusive lock round-trip.

        ``records`` maps key -> seconds or key -> (seconds, cost).  Holds
        the lock across (absorb others' records, write own lines), so
        concurrent appenders interleave cleanly and this process's offset
        stays coherent with the file."""
        norm: dict[str, tuple[float, float]] = {}
        for key, v in records.items():
            if isinstance(v, (tuple, list)):
                norm[key] = (float(v[0]), float(v[1]) if len(v) > 1 else 0.0)
            else:
                norm[key] = (float(v), 0.0)
        with self._lock:
            for key, (value, cost) in norm.items():
                self.entries[key] = value
                if cost:
                    self.costs[key] = cost
        if not self.path or not norm:
            return
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        with open(self.path, "a+") as f:
            _lock_ex(f)
            self.lock_roundtrips += 1
            try:
                with self._lock:
                    ok, _ = self._sync_locked(f)
                    if not ok:
                        return  # foreign/stale file: leave it alone
                    if not self._header_ok:  # empty file: initialize it
                        import uuid
                        self._gen = uuid.uuid4().hex
                        f.write(json.dumps(
                            {"schema": SCHEMA_VERSION,
                             "fingerprint": FINGERPRINT_VERSION,
                             "gen": self._gen}) + "\n")
                        self._header_ok = True
                    batch_start = f.tell()
                    offs: dict[str, int] = {}
                    for key, (value, cost) in norm.items():
                        offs[key] = f.tell()
                        f.write(json.dumps(
                            {"k": key, "v": value, "c": cost or 0.0},
                            separators=(",", ":")) + "\n")
                    f.flush()
                    self._offset = f.tell()
                    st = os.fstat(f.fileno())
                    self._stat = (st.st_ino, st.st_size, st.st_mtime_ns)
                    if (PersistentCache.fault_hook is not None
                            and PersistentCache.fault_hook(self, f)):
                        return  # simulated writer crash: no index step
                    # index maintenance, same flock: append when the
                    # sidecar provably covers everything before this
                    # batch, else regenerate it from the log.  The
                    # coverage check is what keeps a crashed writer's
                    # unindexed records from ever being overclaimed.
                    self._read_index_locked(self._gen)
                    if self._idx_bad or self._idx_cover != batch_start:
                        self._rebuild_index_locked(f)
                    else:
                        self._append_index_locked(offs, self._offset)
            finally:
                _unlock(f)

    def merge(self, entries: MutableMapping) -> int:
        """Fold in entries computed elsewhere; returns #new keys.

        Values may be plain seconds or ``(seconds, cost)`` pairs (the
        form campaign workers ship back)."""
        with self._lock:
            new = sum(1 for k in entries if k not in self.entries)
            for k, v in entries.items():
                if isinstance(v, (tuple, list)):
                    self.entries[k] = float(v[0])
                    if len(v) > 1 and v[1]:
                        self.costs[k] = float(v[1])
                else:
                    self.entries[k] = float(v)
        return new

    def save(self, path: str | None = None) -> str:
        """Compact the log: absorb any concurrent records, then atomically
        rewrite header + one line per entry (tmp + rename), so readers
        never see a torn file.  The rewritten header carries a fresh
        generation id; other live processes notice it on their next
        refresh/append and re-read instead of tailing a stale offset.

        Compaction is meant for run *end* (the campaign runner saves
        once, after workers exit).  A line another process appends in
        the instant between the absorb and the rename lands in the
        replaced inode — that process still holds the entry in memory
        and re-adds it at its own save."""
        import uuid

        path = path or self.path
        if not path:
            raise ValueError("PersistentCache.save: no path configured")
        if self.path == path:
            self.refresh()
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".cache-", suffix=".tmp")
        try:
            with self._lock:
                self._gen = uuid.uuid4().hex
                offs: dict[str, int] = {}
                with os.fdopen(fd, "w") as f:
                    f.write(json.dumps(
                        {"schema": SCHEMA_VERSION,
                         "fingerprint": FINGERPRINT_VERSION,
                         "gen": self._gen}) + "\n")
                    for k, v in self.entries.items():
                        offs[k] = f.tell()
                        f.write(json.dumps(
                            {"k": k, "v": v, "c": self.costs.get(k, 0.0)},
                            separators=(",", ":")) + "\n")
                os.replace(tmp, path)
                st = os.stat(path)
                self._offset = st.st_size
                self._stat = (st.st_ino, st.st_size, st.st_mtime_ns)
                self._header_ok = True
                # the compacted log gets a matching sidecar: offsets were
                # recorded during the rewrite, so no second log scan
                self._write_index_locked(self._gen, offs, st.st_size)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path


class CachedEstimator(ComputeEstimator):
    """Memoizing wrapper; optionally backed by a shared/persistent store.

    ``store`` may be a plain dict shared between several CachedEstimator
    instances (the campaign runner's in-process mode) or a
    :class:`PersistentCache` (cross-run / cross-process mode).  With a
    path-backed PersistentCache, misses are written through to the shared
    log immediately and lookups that miss in memory first tail the log —
    so a concurrent process's fresh entries become hits here mid-run.
    ``new_entries`` records ``key -> (value, cost)`` for the keys this
    instance computed itself, so a parallel worker can ship only its
    fresh results back to the coordinating process.
    """

    def __init__(self, inner: ComputeEstimator,
                 persist_path: str | None = None,
                 store: MutableMapping[str, float] | PersistentCache | None = None):
        super().__init__(inner.system)
        self.inner = inner
        self.toolchain = inner.toolchain
        self.persist_path = persist_path
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self.new_entries: dict[str, tuple[float, float]] = {}
        if store is not None:
            self._mem = store
        elif persist_path:
            self._mem = PersistentCache(persist_path)
        else:
            self._mem = {}

    @property
    def _key_prefix(self) -> str:
        """The (H, C, config) part of the cache key, fingerprint-ready."""
        return (f"{self.inner.cache_hw_key}|{self.inner.toolchain}"
                f"|{self.inner.cache_config_key}|")

    def _key(self, region: ComputeRegion) -> str:
        """The (H, C, config, R) cache key for ``region``."""
        return self._key_prefix + region.fingerprint

    def _hit_cost(self, key: str) -> float:
        """Evaluation cost avoided by a hit on ``key``: measured locally
        if this instance computed it, else the store's persisted cost."""
        local = self.stats.per_key_cost.get(key)
        if local is not None:
            return local
        if isinstance(self._mem, PersistentCache):
            return self._mem.cost(key)
        return 0.0

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        import time
        key = self._key(region)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                self.stats.saved_seconds += self._hit_cost(key)
                return self._mem[key]
        # miss in memory: a concurrent process may have evaluated the key
        # since our last look at the shared log — one indexed point read
        # (or an uncovered-suffix tail) before paying for an evaluation
        if isinstance(self._mem, PersistentCache) and self._mem.path:
            self._mem.get_many([key])
            with self._lock:
                if key in self._mem:
                    self.stats.hits += 1
                    self.stats.saved_seconds += self._hit_cost(key)
                    return self._mem[key]
        t0 = time.perf_counter()
        value = self.inner.get_run_time_estimate(region)
        dt = time.perf_counter() - t0
        with self._lock:
            if isinstance(self._mem, PersistentCache):
                self._mem.append(key, value, cost=dt)
            else:
                self._mem[key] = value
            self.new_entries[key] = (value, dt)
            self.stats.misses += 1
            self.stats.miss_cost_seconds += dt
            self.stats.per_key_cost[key] = dt
        return value

    def get_run_time_estimates(self, regions: list[ComputeRegion],
                               arrays=None) -> list[float]:
        """Batched lookup: all regions of one evaluate phase in a single
        store round-trip.

        Per-region counters (hits, misses, saved/miss cost, per-key cost,
        ``new_entries``) are updated exactly as the equivalent sequence of
        :meth:`get_run_time_estimate` calls would — a duplicate
        fingerprint later in the batch is a hit on the earlier miss — but
        a path-backed :class:`PersistentCache` is tailed at most once for
        the whole batch and all fresh entries are written through in one
        exclusive-lock round-trip (:meth:`PersistentCache.put_many`)
        instead of one per miss.

        With the plan's :class:`~repro.core.ir.arrays.RegionArrays` (same
        regions, same order), keys come from the memoized per-prefix key
        table instead of per-region string formatting, and the two common
        grid shapes skip per-region work entirely while producing the
        same values and hit/miss counts as the loop below:

        * **warm** — every key already cached: one pass over the key list;
        * **cold** — no key cached and no in-batch duplicates, with an
          inner ``evaluate_batch``: one vectorized inner evaluation (the
          measured wall cost is attributed uniformly across the batch's
          per-key cost accounting).

        Mixed batches (and inner estimators without ``evaluate_batch``)
        take the per-region loop."""
        import time
        keys = (arrays.keys_for(self._key_prefix) if arrays is not None
                else [self._key(r) for r in regions])
        if isinstance(self._mem, PersistentCache) and self._mem.path:
            # one get_many tails the log at most once for the whole
            # batch; absorbed entries serve the per-key loop below
            self._mem.get_many(keys)
        if arrays is not None:
            fast = self._estimates_from_arrays(keys, arrays)
            if fast is not None:
                return fast
        out: list[float] = []
        pending: dict[str, tuple[float, float]] = {}
        try:
            for key, region in zip(keys, regions):
                with self._lock:
                    if key in self._mem:
                        self.stats.hits += 1
                        self.stats.saved_seconds += self._hit_cost(key)
                        out.append(self._mem[key])
                        continue
                t0 = time.perf_counter()
                value = self.inner.get_run_time_estimate(region)
                dt = time.perf_counter() - t0
                with self._lock:
                    if isinstance(self._mem, PersistentCache):
                        # memory-only for now: in-batch duplicates must
                        # hit; the log write is one put_many at the end
                        self._mem.merge({key: (value, dt)})
                        pending[key] = (value, dt)
                    else:
                        self._mem[key] = value
                    self.new_entries[key] = (value, dt)
                    self.stats.misses += 1
                    self.stats.miss_cost_seconds += dt
                    self.stats.per_key_cost[key] = dt
                out.append(value)
        finally:
            # flush even when the estimator raises mid-batch: entries
            # already computed must reach the shared log (the per-region
            # path wrote each through immediately)
            if pending and isinstance(self._mem, PersistentCache) \
                    and self._mem.path:
                self._mem.put_many(pending)
        return out

    def _estimates_from_arrays(self, keys: list[str],
                               arrays) -> list[float] | None:
        """The warm / cold vector paths; None means 'take the loop'."""
        import time
        with self._lock:
            n_cached = sum(1 for k in set(keys) if k in self._mem)
        if n_cached == len(set(keys)):            # warm: all keys present
            out = []
            with self._lock:
                for key in keys:
                    self.stats.hits += 1
                    self.stats.saved_seconds += self._hit_cost(key)
                    out.append(self._mem[key])
            return out
        batch = getattr(self.inner, "evaluate_batch", None)
        if n_cached == 0 and batch is not None \
                and len(set(keys)) == len(keys):  # cold, all distinct
            t0 = time.perf_counter()
            values = batch(arrays)
            dt = time.perf_counter() - t0
            if values is None:
                # inner estimator declined the batch (its vector path
                # cannot replay these regions exactly): take the loop
                return None
            each = dt / len(keys) if keys else 0.0
            records = {k: (v, each) for k, v in zip(keys, values)}
            with self._lock:
                if isinstance(self._mem, PersistentCache):
                    self._mem.merge(records)
                else:
                    for k, (v, _) in records.items():
                        self._mem[k] = v
                self.new_entries.update(records)
                self.stats.misses += len(keys)
                self.stats.miss_cost_seconds += dt
                for k in keys:
                    self.stats.per_key_cost[k] = each
            if isinstance(self._mem, PersistentCache) and self._mem.path:
                self._mem.put_many(records)
            return list(values)
        return None

    def supports(self, region: ComputeRegion) -> bool:
        return self.inner.supports(region)

    def flush(self) -> None:
        """Persist the store to ``persist_path`` (no-op without one)."""
        if not self.persist_path:
            return
        if isinstance(self._mem, PersistentCache):
            self._mem.save(self.persist_path)
        else:
            pc = PersistentCache()
            pc.merge(self._mem)
            pc.save(self.persist_path)
