"""(H × C × R) latency cache (paper §III-B(c)).

Key = (target hardware H, compilation toolchain C, compute region R);
changing any of the three can change latency, nothing else can.  Stacked
transformer blocks produce identical region fingerprints, so an L-layer
model pays for one evaluation per distinct block — the mechanism behind
the paper's 89.7 % (Llama-3) / 26.8 % (ResNet) evaluation-time savings.
"""
from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field

from ..slicing.regions import ComputeRegion
from .base import ComputeEstimator


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    saved_seconds: float = 0.0     # estimator wall-time avoided (measured)
    miss_cost_seconds: float = 0.0  # wall-time actually spent on misses
    per_key_cost: dict = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def time_saving_fraction(self) -> float:
        """Fraction of evaluation time avoided by caching (paper's metric)."""
        would_be = self.saved_seconds + self.miss_cost_seconds
        return self.saved_seconds / would_be if would_be > 0 else 0.0


class CachedEstimator(ComputeEstimator):
    def __init__(self, inner: ComputeEstimator,
                 persist_path: str | None = None):
        super().__init__(inner.system)
        self.inner = inner
        self.toolchain = inner.toolchain
        self.persist_path = persist_path
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._mem: dict[str, float] = {}
        if persist_path and os.path.exists(persist_path):
            try:
                with open(persist_path) as f:
                    self._mem = json.load(f)
            except (json.JSONDecodeError, OSError):
                self._mem = {}

    def _key(self, region: ComputeRegion) -> str:
        return f"{self.inner.cache_hw_key}|{self.inner.toolchain}|{region.fingerprint}"

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        import time
        key = self._key(region)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                self.stats.saved_seconds += self.stats.per_key_cost.get(key, 0.0)
                return self._mem[key]
        t0 = time.perf_counter()
        value = self.inner.get_run_time_estimate(region)
        dt = time.perf_counter() - t0
        with self._lock:
            self._mem[key] = value
            self.stats.misses += 1
            self.stats.miss_cost_seconds += dt
            self.stats.per_key_cost[key] = dt
        return value

    def supports(self, region: ComputeRegion) -> bool:
        return self.inner.supports(region)

    def flush(self) -> None:
        if self.persist_path:
            os.makedirs(os.path.dirname(self.persist_path) or ".",
                        exist_ok=True)
            with open(self.persist_path, "w") as f:
                json.dump(self._mem, f)
