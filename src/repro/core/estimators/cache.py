"""(H × C × R) latency cache (paper §III-B(c)).

Key = (target hardware H, compilation toolchain C, compute region R);
changing any of the three can change latency, nothing else can.  Stacked
transformer blocks produce identical region fingerprints, so an L-layer
model pays for one evaluation per distinct block — the mechanism behind
the paper's 89.7 % (Llama-3) / 26.8 % (ResNet) evaluation-time savings.

Two layers:

  * :class:`CachedEstimator` — the in-run memo wrapping any estimator.
  * :class:`PersistentCache`  — an on-disk store of the same keyed entries
    that campaigns, benchmarks, and repeated runs share across processes,
    extending the within-run savings to across-run savings.

The on-disk format is versioned: ``SCHEMA_VERSION`` guards the file layout
and ``FINGERPRINT_VERSION`` guards the region-fingerprint algorithm (the R
of the key).  Bumping either invalidates stale files on load instead of
silently serving latencies keyed by an incompatible fingerprint.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from dataclasses import dataclass, field
from typing import MutableMapping

from ..slicing.regions import ComputeRegion
from .base import ComputeEstimator

#: bump when the on-disk JSON layout changes
SCHEMA_VERSION = 1
#: bump when slicing.regions.region_fingerprint changes what it hashes
FINGERPRINT_VERSION = 1


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    saved_seconds: float = 0.0     # estimator wall-time avoided (measured)
    miss_cost_seconds: float = 0.0  # wall-time actually spent on misses
    per_key_cost: dict = field(default_factory=dict)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    @property
    def time_saving_fraction(self) -> float:
        """Fraction of evaluation time avoided by caching (paper's metric)."""
        would_be = self.saved_seconds + self.miss_cost_seconds
        return self.saved_seconds / would_be if would_be > 0 else 0.0


class PersistentCache:
    """On-disk (H, C, R) -> seconds store shared across runs and processes.

    Thread-safe for concurrent readers/writers within one process; across
    processes, workers return their freshly computed entries and the owning
    process merges + saves (last-writer-wins on identical keys is harmless
    because entries are deterministic per key for a given estimator).
    """

    def __init__(self, path: str | None = None):
        self.path = path
        self.entries: dict[str, float] = {}
        self.loaded_entries = 0
        self._lock = threading.Lock()
        if path:
            self.load(path)

    def __len__(self) -> int:
        return len(self.entries)

    def __contains__(self, key: str) -> bool:
        return key in self.entries

    def __getitem__(self, key: str) -> float:
        return self.entries[key]

    def __setitem__(self, key: str, value: float) -> None:
        with self._lock:
            self.entries[key] = value

    def get(self, key: str, default=None):
        return self.entries.get(key, default)

    def load(self, path: str) -> int:
        """Load a cache file; stale/foreign files are discarded, not errors."""
        self.path = path
        if not os.path.exists(path):
            return 0
        try:
            with open(path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError):
            return 0
        if not isinstance(data, dict):
            return 0
        if (data.get("schema") != SCHEMA_VERSION
                or data.get("fingerprint") != FINGERPRINT_VERSION):
            return 0  # versioned invalidation: stale layout or algorithm
        entries = data.get("entries")
        if not isinstance(entries, dict):
            return 0
        with self._lock:
            self.entries.update({str(k): float(v)
                                 for k, v in entries.items()})
            self.loaded_entries = len(entries)
        return self.loaded_entries

    def merge(self, entries: MutableMapping[str, float]) -> int:
        """Fold in entries computed elsewhere; returns #new keys."""
        with self._lock:
            new = sum(1 for k in entries if k not in self.entries)
            self.entries.update(entries)
        return new

    def save(self, path: str | None = None) -> str:
        """Atomic write (tmp + rename) so concurrent readers never see a
        torn file."""
        path = path or self.path
        if not path:
            raise ValueError("PersistentCache.save: no path configured")
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with self._lock:
            payload = {"schema": SCHEMA_VERSION,
                       "fingerprint": FINGERPRINT_VERSION,
                       "entries": dict(self.entries)}
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".",
                                   prefix=".cache-", suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)
        return path


class CachedEstimator(ComputeEstimator):
    """Memoizing wrapper; optionally backed by a shared/persistent store.

    ``store`` may be a plain dict shared between several CachedEstimator
    instances (the campaign runner's in-process mode) or a
    :class:`PersistentCache` (cross-run mode).  ``new_entries`` records the
    keys this instance computed itself, so a parallel worker can ship only
    its fresh results back to the coordinating process.
    """

    def __init__(self, inner: ComputeEstimator,
                 persist_path: str | None = None,
                 store: MutableMapping[str, float] | PersistentCache | None = None):
        super().__init__(inner.system)
        self.inner = inner
        self.toolchain = inner.toolchain
        self.persist_path = persist_path
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self.new_entries: dict[str, float] = {}
        if store is not None:
            self._mem = store
        elif persist_path:
            self._mem = PersistentCache(persist_path)
        else:
            self._mem = {}

    def _key(self, region: ComputeRegion) -> str:
        return (f"{self.inner.cache_hw_key}|{self.inner.toolchain}"
                f"|{self.inner.cache_config_key}|{region.fingerprint}")

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        import time
        key = self._key(region)
        with self._lock:
            if key in self._mem:
                self.stats.hits += 1
                self.stats.saved_seconds += self.stats.per_key_cost.get(key, 0.0)
                return self._mem[key]
        t0 = time.perf_counter()
        value = self.inner.get_run_time_estimate(region)
        dt = time.perf_counter() - t0
        with self._lock:
            self._mem[key] = value
            self.new_entries[key] = value
            self.stats.misses += 1
            self.stats.miss_cost_seconds += dt
            self.stats.per_key_cost[key] = dt
        return value

    def supports(self, region: ComputeRegion) -> bool:
        return self.inner.supports(region)

    def flush(self) -> None:
        if not self.persist_path:
            return
        if isinstance(self._mem, PersistentCache):
            self._mem.save(self.persist_path)
        else:
            pc = PersistentCache()
            pc.merge(self._mem)
            pc.save(self.persist_path)
