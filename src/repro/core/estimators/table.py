"""Recorded-profile replay estimator (the ``table`` kind).

The paper's profiling estimator pays a real execution per distinct
region; this backend replays latencies that were *already measured* —
per-fingerprint seconds recorded into a profile JSON — so a profiling
run done once (on real hardware, or by any other estimator) keeps its
fidelity forever without re-measurement, in the spirit of Daydream-style
offline profiling.

It is also the worked example of the open backend vocabulary: the class
registers itself through the same public ``@register_estimator``
decorator a third-party plugin would use, and campaign specs reach it
with ``{"kind": "table", "options": {"path": "profile.json"}}`` — no
``repro`` internals edited (see ``docs/extending.md``).

Profile JSON is either a flat ``{fingerprint: seconds}`` map or the
richer ``{"version": 1, "meta": {...}, "entries": {fingerprint:
seconds}}`` form that :func:`save_profile` writes.
"""
from __future__ import annotations

import hashlib
import json

from ..registry import register_estimator
from ..slicing.regions import ComputeRegion
from ..systems import System
from .base import ComputeEstimator

PROFILE_VERSION = 1


def load_profile(path: str) -> dict[str, float]:
    """Read a profile JSON (flat or versioned) into fingerprint -> seconds."""
    with open(path) as f:
        raw = json.load(f)
    entries = raw.get("entries", raw) if isinstance(raw, dict) else None
    if not isinstance(entries, dict):
        raise ValueError(
            f"profile {path!r}: expected a fingerprint -> seconds map "
            "(optionally under an 'entries' key)")
    return {str(k): float(v) for k, v in entries.items()}


def save_profile(path: str, table: dict[str, float],
                 meta: dict | None = None) -> str:
    """Write a versioned profile JSON; inverse of :func:`load_profile`."""
    with open(path, "w") as f:
        json.dump({"version": PROFILE_VERSION, "meta": meta or {},
                   "entries": table}, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def record_profile(regions: list[ComputeRegion],
                   estimator: ComputeEstimator) -> dict[str, float]:
    """Measure every distinct region fingerprint once through
    ``estimator`` — the recording side of the replay loop (profile a
    plan's regions once, replay them on every later campaign)."""
    table: dict[str, float] = {}
    for r in regions:
        if r.fingerprint not in table:
            table[r.fingerprint] = estimator.get_run_time_estimate(r)
    return table


@register_estimator("table")
class TableEstimator(ComputeEstimator):
    """Replay per-fingerprint latencies from a recorded profile.

    ``scale`` rescales every entry (e.g. a clock-ratio projection onto a
    different system); ``default`` is the latency for fingerprints the
    profile does not cover — without it an uncovered region raises, or
    pair with a fallback through ``mixed``-style composition
    (:meth:`supports` returns False for uncovered regions)."""

    toolchain = "table"

    def __init__(self, system: System, table: dict[str, float], *,
                 source: str = "<memory>", scale: float = 1.0,
                 default: float | None = None):
        super().__init__(system)
        self.table = {str(k): float(v) for k, v in table.items()}
        self.source = source
        self.scale = float(scale)
        self.default = None if default is None else float(default)

    @classmethod
    def from_profile(cls, system: System, path: str,
                     **kw) -> "TableEstimator":
        return cls(system, load_profile(path), source=path, **kw)

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "TableEstimator":
        path = options.get("path")
        if not path:
            raise ValueError(
                "table estimator needs options.path — a profile JSON "
                "of fingerprint -> seconds (see docs/extending.md)")
        if context is not None and getattr(context, "base_dir", None):
            path = context.resolve_path(path)
        return cls.from_profile(
            system, path, scale=float(options.get("scale", 1.0)),
            default=options.get("default"))

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        t = self.table.get(region.fingerprint)
        if t is not None:
            return t * self.scale
        if self.default is not None:
            # scale applies to the default too ("scale rescales every
            # entry"): a scaled cross-system projection must not serve
            # unscaled latencies for uncovered fingerprints.  The cache
            # config digest already covers both fields, so fixed values
            # can never be served from entries cached under the old
            # behavior's identical key — the key was always correct.
            return self.default * self.scale
        raise KeyError(
            f"table estimator ({self.source}): no recorded latency for "
            f"region fingerprint {region.fingerprint!r} "
            f"({len(self.table)} entries) — re-record the profile or set "
            "options.default")

    def supports(self, region: ComputeRegion) -> bool:
        return region.fingerprint in self.table or self.default is not None

    @property
    def cache_config_key(self) -> str:
        """Content digest: two different profiles (or scales) must not
        serve each other's entries from a shared (H, C, R) store."""
        h = hashlib.sha256()
        for k in sorted(self.table):
            h.update(f"{k}={self.table[k]!r};".encode())
        h.update(f"scale={self.scale!r};default={self.default!r}".encode())
        return f"table-{h.hexdigest()[:12]}"
