"""Learned cross-system fidelity tier (the ``learned`` kind).

The ``table`` estimator replays recorded per-fingerprint latencies on
the system that recorded them; this backend *generalizes* a recorded
profile in the spirit of Daydream-style offline profiling (arXiv
2002.06790) and the multi-GPU universal model of Lin et al. (arXiv
2404.12674): it fits one least-squares regression per **op family**
(matmul / elementwise / movement / other, classified from the region's
op mix) over region fingerprint features — flops, bytes moved, boundary
bytes, op-mix counts — and can then predict

* regions the profile never recorded (same family, new shapes), and
* **systems the profile never ran on**: every feature is expressed in
  time units on the *recording* system (flops / peak FLOP/s, bytes /
  memory bandwidth, counts x kernel overhead), so the fitted
  coefficients are dimensionless multipliers and transfer amounts to
  rescaling each feature by the target system's compute / bandwidth /
  overhead constants from the ``specs/systems/*.json`` catalog.

Every prediction carries an **uncertainty estimate**: a residual-based
relative interval widened when the region's raw features fall outside
the fitted range or when the target system differs from the recording
system, plus an ``extrapolated`` flag.  The campaign pipeline surfaces
these as per-prediction row fields (``uncertainty_s``,
``uncertainty_rel``, ``extrapolated``, ``extrapolated_regions``) via
the ``prediction_quality`` hook (see ``repro.core.pipeline``).

Wire-up mirrors ``table``: record with :func:`record_profile` (any
estimator, or real hardware), fit with :func:`fit_model`, persist with
:func:`save_model` / :func:`load_model` (versioned model JSON), and
reach it from campaign specs with ``{"kind": "learned", "options":
{"model": "models/m.json"}}`` — relative paths resolve against the spec
file.  ``tools/fit_learned_model.py`` is the record -> fit -> save CLI.
"""
from __future__ import annotations

import hashlib
import json
import math
from dataclasses import dataclass, field

from ..ir.opcost import _MOVEMENT
from ..registry import register_estimator
from ..slicing.regions import ComputeRegion
from ..systems import System
from .base import ComputeEstimator

MODEL_VERSION = 1

#: op names whose flops dominate a region -> the ``matmul`` family
_MATMUL_OPS = ("dot_general", "dot", "convolution")

#: feature vector layout; every entry is time-shaped (seconds on the
#: system the features are computed against), so fitted coefficients
#: are dimensionless and transfer across systems by recomputing the
#: features with the target system's catalog constants
FEATURE_NAMES = ("overhead", "compute", "bytes", "boundary",
                 "n_matmul", "n_elementwise", "n_movement")

#: raw (system-independent) quantities whose fitted min/max define the
#: interpolation envelope; outside it predictions flag ``extrapolated``
RANGE_NAMES = ("flops", "bytes", "boundary_bytes")

#: relative-residual floor: a perfect fit (e.g. an exactly linear
#: recorder) still reports a non-degenerate interval
MIN_REL_STD = 0.005
#: half-width = Z * rel_std (~95% under a normal residual assumption)
INTERVAL_Z = 2.0
#: widening factor applied when raw features leave the fitted range
RANGE_WIDEN = 3.0
#: ridge term (relative to the Gram diagonal) keeping the normal
#: equations solvable for degenerate training sets (e.g. all-GEMM)
RIDGE = 1e-6


def region_family(region: ComputeRegion) -> str:
    """The op family a region's mix assigns it to.

    ``matmul`` when any contraction op contributes flops; else
    ``elementwise`` when any op contributes flops; else ``movement``
    when only data-movement bytes remain; else ``other``."""
    by_op = region.cost.by_op
    if any(by_op.get(op) for op in _MATMUL_OPS):
        return "matmul"
    if region.cost.flops > 0:
        return "elementwise"
    if region.cost.bytes > 0 or region.cost.by_op:
        return "movement"
    return "other"


def _op_mix_counts(region: ComputeRegion) -> tuple[float, float, float]:
    """(matmul, elementwise, movement-or-other) op counts — the op-mix
    portion of the feature vector, from the per-op cost breakdown."""
    n_mm = n_ew = n_mv = 0.0
    names = set(region.cost.by_op) | set(region.cost.bytes_by_op)
    for name in names:
        if name in _MATMUL_OPS:
            n_mm += 1.0
        elif name in _MOVEMENT:
            n_mv += 1.0
        elif region.cost.by_op.get(name):
            n_ew += 1.0
        else:
            n_mv += 1.0
    return n_mm, n_ew, n_mv


def _dominant_dtype(region: ComputeRegion) -> str:
    """Dominant dtype by result bytes (same rule as the roofline)."""
    best, best_bytes = "bf16", -1.0
    for op in region.ops:
        for t in op.result_types:
            if t.nbytes > best_bytes:
                best, best_bytes = t.dtype, t.nbytes
    return best


def region_features(region: ComputeRegion, system: System) -> list[float]:
    """The time-shaped feature vector of ``region`` on ``system``, in
    :data:`FEATURE_NAMES` order."""
    ovh = system.kernel_overhead_s
    compute = region.cost.flops / system.flops_for(_dominant_dtype(region))
    mem = region.cost.bytes / system.mem_bw
    boundary = (region.boundary_in_bytes
                + region.boundary_out_bytes) / system.mem_bw
    n_mm, n_ew, n_mv = _op_mix_counts(region)
    return [ovh, compute, mem, boundary,
            n_mm * ovh, n_ew * ovh, n_mv * ovh]


def _raw_ranges(region: ComputeRegion) -> tuple[float, float, float]:
    return (region.cost.flops, region.cost.bytes,
            region.boundary_in_bytes + region.boundary_out_bytes)


def _solve(a: list[list[float]], b: list[float]) -> list[float]:
    """Gaussian elimination with partial pivoting (stdlib-only)."""
    n = len(b)
    m = [row[:] + [b[i]] for i, row in enumerate(a)]
    for col in range(n):
        piv = max(range(col, n), key=lambda r: abs(m[r][col]))
        if abs(m[piv][col]) < 1e-300:
            continue                       # ridge keeps this unreachable
        m[col], m[piv] = m[piv], m[col]
        for r in range(n):
            if r == col:
                continue
            f = m[r][col] / m[col][col]
            for c in range(col, n + 1):
                m[r][c] -= f * m[col][c]
    out = []
    for i in range(n):
        out.append(m[i][n] / m[i][i] if abs(m[i][i]) > 1e-300 else 0.0)
    return out


@dataclass
class FamilyModel:
    """One op family's fitted regression: dimensionless coefficients
    over :data:`FEATURE_NAMES`, the relative residual spread, and the
    raw-feature envelope the fit covered."""
    coef: list[float]
    rel_residual_std: float
    n_samples: int
    ranges: dict = field(default_factory=dict)  # name -> [min, max]

    def in_range(self, raw: tuple[float, float, float]) -> bool:
        for name, v in zip(RANGE_NAMES, raw):
            lo, hi = self.ranges.get(name, (0.0, math.inf))
            if not lo <= v <= hi:
                return False
        return True


@dataclass
class LearnedModel:
    """A fitted, transferable latency model (the on-disk unit)."""
    families: dict                       # family -> FamilyModel
    source: dict                         # recording system's constants
    meta: dict = field(default_factory=dict)
    version: int = MODEL_VERSION

    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "meta": self.meta,
            "source": self.source,
            "families": {
                fam: {
                    "coef": dict(zip(FEATURE_NAMES, fm.coef)),
                    "rel_residual_std": fm.rel_residual_std,
                    "n_samples": fm.n_samples,
                    "ranges": fm.ranges,
                } for fam, fm in sorted(self.families.items())
            },
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LearnedModel":
        if not isinstance(d, dict) or "families" not in d:
            raise ValueError(
                "learned model JSON must carry a 'families' map "
                "(write one with save_model / tools/fit_learned_model.py)")
        version = int(d.get("version", 0))
        if version != MODEL_VERSION:
            raise ValueError(
                f"learned model version {version} != supported "
                f"{MODEL_VERSION} — re-fit with tools/fit_learned_model.py")
        fams = {}
        for fam, f in d["families"].items():
            coef = f["coef"]
            if isinstance(coef, dict):
                coef = [float(coef.get(n, 0.0)) for n in FEATURE_NAMES]
            fams[fam] = FamilyModel(
                coef=[float(c) for c in coef],
                rel_residual_std=float(f.get("rel_residual_std",
                                             MIN_REL_STD)),
                n_samples=int(f.get("n_samples", 0)),
                ranges={k: [float(v[0]), float(v[1])]
                        for k, v in f.get("ranges", {}).items()})
        return cls(families=fams, source=dict(d.get("source", {})),
                   meta=dict(d.get("meta", {})), version=version)

    def digest(self) -> str:
        payload = json.dumps(self.to_dict(), sort_keys=True)
        return hashlib.sha256(payload.encode()).hexdigest()[:12]


class _SourceConstants:
    """Duck-typed stand-in for :class:`System` built from the model's
    recorded catalog constants (enough for :func:`region_features`)."""

    def __init__(self, source: dict):
        self.peak_flops = {k: float(v)
                           for k, v in source.get("peak_flops", {}).items()}
        self.mem_bw = float(source.get("mem_bw", 1.0))
        self.kernel_overhead_s = float(source.get("kernel_overhead_s", 0.0))

    def flops_for(self, dtype: str) -> float:
        if dtype in self.peak_flops:
            return self.peak_flops[dtype]
        if dtype in ("bf16", "f16"):
            return self.peak_flops.get(
                "bf16", self.peak_flops.get(
                    "f16", self.peak_flops.get("f32", 1.0)))
        return self.peak_flops.get(
            "f32", max(self.peak_flops.values()) if self.peak_flops else 1.0)


def _system_constants(system: System) -> dict:
    return {
        "name": system.name,
        "peak_flops": {k: float(v) for k, v in system.peak_flops.items()},
        "mem_bw": float(system.mem_bw),
        "kernel_overhead_s": float(system.kernel_overhead_s),
    }


def fit_model(regions: list[ComputeRegion], profile: dict[str, float],
              system: System, *, meta: dict | None = None) -> LearnedModel:
    """Fit per-op-family regressions from a recorded profile.

    ``profile`` maps region fingerprints to measured seconds (the
    :func:`repro.core.estimators.table.record_profile` form); ``regions``
    supply the fingerprint features and ``system`` the recording
    system's catalog constants the features are normalized by.  Each
    distinct fingerprint contributes one sample."""
    samples: dict[str, list[tuple[list[float], float,
                                  tuple[float, float, float]]]] = {}
    seen: set[str] = set()
    for r in regions:
        t = profile.get(r.fingerprint)
        if t is None or r.fingerprint in seen:
            continue
        seen.add(r.fingerprint)
        samples.setdefault(region_family(r), []).append(
            (region_features(r, system), float(t), _raw_ranges(r)))
    if not samples:
        raise ValueError(
            "fit_model: no profile entry matches any region fingerprint "
            "— record the profile from the same plan you fit on")
    families = {}
    for fam, rows in sorted(samples.items()):
        families[fam] = _fit_family(rows)
    return LearnedModel(
        families=families, source=_system_constants(system),
        meta={"entries_fitted": len(seen), **(meta or {})})


def _fit_family(rows: list) -> FamilyModel:
    """Ridge-regularized least squares over one family's samples."""
    k = len(FEATURE_NAMES)
    gram = [[0.0] * k for _ in range(k)]
    rhs = [0.0] * k
    for x, y, _ in rows:
        for i in range(k):
            rhs[i] += x[i] * y
            for j in range(k):
                gram[i][j] += x[i] * x[j]
    trace = sum(gram[i][i] for i in range(k))
    lam = RIDGE * (trace / k if trace > 0 else 1.0)
    for i in range(k):
        gram[i][i] += lam
    coef = _solve(gram, rhs)
    rel_sq = 0.0
    for x, y, _ in rows:
        pred = sum(c * v for c, v in zip(coef, x))
        rel_sq += ((pred - y) / y) ** 2 if y > 0 else 0.0
    rel_std = max(math.sqrt(rel_sq / len(rows)), MIN_REL_STD)
    ranges = {}
    for idx, name in enumerate(RANGE_NAMES):
        vals = [raw[idx] for _, _, raw in rows]
        ranges[name] = [min(vals), max(vals)]
    return FamilyModel(coef=coef, rel_residual_std=rel_std,
                       n_samples=len(rows), ranges=ranges)


def save_model(path: str, model: LearnedModel) -> str:
    """Write the versioned model JSON; inverse of :func:`load_model`."""
    with open(path, "w") as f:
        json.dump(model.to_dict(), f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def load_model(path: str) -> LearnedModel:
    with open(path) as f:
        try:
            raw = json.load(f)
        except json.JSONDecodeError as e:
            raise ValueError(f"learned model {path!r}: not JSON ({e})")
    try:
        return LearnedModel.from_dict(raw)
    except ValueError as e:
        raise ValueError(f"learned model {path!r}: {e}")


@register_estimator("learned")
class LearnedEstimator(ComputeEstimator):
    """Predict region latencies from a fitted :class:`LearnedModel`.

    The target system is ``self.system`` (the grid system the campaign
    builds the estimator for); the model remembers the system it was
    recorded on, and the prediction *transfers* by recomputing the
    time-shaped features with the target's catalog constants.  Every
    prediction carries a residual-based interval and an
    ``extrapolated`` flag (out-of-envelope features, or any cross-system
    transfer); :meth:`prediction_quality` aggregates them into the
    campaign row fields."""

    toolchain = "learned"

    def __init__(self, system: System, model: LearnedModel, *,
                 source: str = "<memory>"):
        super().__init__(system)
        self.model = model
        self.source = source
        self._src = _SourceConstants(model.source)
        # cross-system widening: how far the target's compute/bandwidth
        # ratios sit from the recording system's (1.0 = same system)
        rc = self._ratio(system.flops_for("bf16"),
                         self._src.flops_for("bf16"))
        rb = self._ratio(system.mem_bw, self._src.mem_bw)
        self._transfer_widen = math.sqrt(max(rc, 1.0 / rc)
                                         * max(rb, 1.0 / rb))
        self._transferred = (
            model.source.get("name") not in ("", None, system.name))

    @staticmethod
    def _ratio(a: float, b: float) -> float:
        return a / b if a > 0 and b > 0 else 1.0

    @classmethod
    def from_model(cls, system: System, path: str) -> "LearnedEstimator":
        return cls(system, load_model(path), source=path)

    @classmethod
    def from_spec(cls, options: dict, system: System,
                  context) -> "LearnedEstimator":
        path = options.get("model")
        if not path:
            raise ValueError(
                "learned estimator needs options.model — a fitted model "
                "JSON (record + fit one with tools/fit_learned_model.py; "
                "see docs/extending.md)")
        if context is not None and getattr(context, "base_dir", None):
            path = context.resolve_path(path)
        return cls.from_model(system, path)

    # ------------------------------ predict ------------------------------

    def _family_model(self, region: ComputeRegion) -> tuple[str, FamilyModel]:
        fam = region_family(region)
        fm = self.model.families.get(fam)
        if fm is None:
            raise KeyError(
                f"learned estimator ({self.source}): no fitted model for "
                f"op family {fam!r} (have "
                f"{sorted(self.model.families)}) — re-fit on a profile "
                "covering this family, or compose with a fallback "
                "estimator (supports() returns False here)")
        return fam, fm

    def get_run_time_estimate(self, region: ComputeRegion) -> float:
        _, fm = self._family_model(region)
        x = region_features(region, self.system)
        return max(sum(c * v for c, v in zip(fm.coef, x)), 0.0)

    def predict_with_uncertainty(self, region: ComputeRegion) -> dict:
        """Point prediction plus the residual-based interval.

        ``low``/``high`` bound the prediction at ``INTERVAL_Z`` relative
        residual standard deviations, widened by :data:`RANGE_WIDEN`
        outside the fitted feature envelope and by the compute/bandwidth
        ratio distance on cross-system transfer."""
        fam, fm = self._family_model(region)
        t = self.get_run_time_estimate(region)
        out_of_range = not fm.in_range(_raw_ranges(region))
        widen = self._transfer_widen * (RANGE_WIDEN if out_of_range else 1.0)
        half = INTERVAL_Z * fm.rel_residual_std * widen
        return {
            "seconds": t,
            "low": max(t * (1.0 - half), 0.0),
            "high": t * (1.0 + half),
            "rel_half_width": half,
            "family": fam,
            "extrapolated": bool(out_of_range or self._transferred),
        }

    def prediction_quality(self, regions: list[ComputeRegion]) -> dict:
        """Aggregate per-prediction uncertainty into campaign row fields
        (the pipeline merges this dict into the result row)."""
        total = half_abs = 0.0
        extrapolated = 0
        for r in regions:
            if not self.supports(r):
                continue
            p = self.predict_with_uncertainty(r)
            total += p["seconds"]
            half_abs += p["seconds"] * p["rel_half_width"]
            extrapolated += bool(p["extrapolated"])
        return {
            "uncertainty_s": half_abs,
            "uncertainty_rel": half_abs / total if total > 0 else 0.0,
            "extrapolated": bool(extrapolated),
            "extrapolated_regions": extrapolated,
            "model_source_system": self.model.source.get("name", "?"),
        }

    def supports(self, region: ComputeRegion) -> bool:
        return region_family(region) in self.model.families

    @property
    def cache_config_key(self) -> str:
        """Content digest — two different fitted models must not share
        entries in one (H, C, R) store."""
        return f"learned-{self.model.digest()}"
