"""Network topologies for the system-simulation stage (paper §IV-C4).

Three families cover the paper's experiments plus the TPU pod target:

  * AllToAllNode — NVLink-connected GPU node (4 GPUs, paper Fig 6/7);
  * Dragonfly   — hierarchical NVLink-intranode + Slingshot-internode
                  system (16–128 GPUs, paper Fig 8/9);
  * Torus       — TPU ICI 2D/3D torus (TPUv3 slice, v5e pod, multi-pod
                  over DCN).

A topology answers two questions for the collective models:
  - bisection/ring bandwidth available to a group of participants,
  - per-hop latency and hop counts.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

from ..registry import register_topology


@dataclass(frozen=True)
class PathProfile:
    """Effective bandwidth/latency seen by a ring (or tree) spanning a group."""
    ring_bw: float          # bytes/s per direction around the ring's slowest link
    latency: float          # per-hop latency, seconds
    hops: int               # hops around the ring
    bidirectional: bool = True


class Topology:
    name: str = "abstract"
    num_devices: int = 0

    def ring(self, group_size: int) -> PathProfile:
        raise NotImplementedError

    def point_to_point(self, size_bytes: float) -> float:
        p = self.ring(2)
        return p.latency + size_bytes / p.ring_bw

    @classmethod
    def from_spec(cls, params: dict, system, context) -> "Topology":
        """Build from a campaign-spec params dict (the registry builder
        protocol — see :mod:`repro.core.registry`).  The default maps
        params straight onto constructor keywords, turning list-valued
        params (JSON arrays, e.g. torus ``dims``) into tuples."""
        return cls(**{k: (tuple(v) if isinstance(v, list) else v)
                      for k, v in params.items()})


@register_topology("a2a")
@dataclass
class AllToAllNode(Topology):
    """Fully connected NVLink node: every pair has a direct link."""
    num_devices: int = 4
    link_bw: float = 100e9
    link_latency: float = 0.7e-6
    name: str = "nvlink-a2a"

    def ring(self, group_size: int) -> PathProfile:
        g = min(group_size, self.num_devices)
        return PathProfile(ring_bw=self.link_bw, latency=self.link_latency,
                           hops=max(g - 1, 1), bidirectional=True)


@register_topology("dragonfly")
@dataclass
class Dragonfly(Topology):
    """Two-level system: NVLink all-to-all inside a node, dragonfly between
    nodes (paper Fig 8: nodes/router, routers/group, groups)."""
    num_nodes: int = 32
    gpus_per_node: int = 4
    nodes_per_router: int = 4
    routers_per_group: int = 4
    groups: int = 2
    intra_bw: float = 150e9          # NVLink
    inter_bw: float = 25e9           # Slingshot per-node injection
    intra_latency: float = 0.7e-6
    inter_latency: float = 2.0e-6
    name: str = "dragonfly"

    @property
    def num_devices(self) -> int:  # type: ignore[override]
        return self.num_nodes * self.gpus_per_node

    def ring(self, group_size: int) -> PathProfile:
        if group_size <= self.gpus_per_node:
            return PathProfile(ring_bw=self.intra_bw,
                               latency=self.intra_latency,
                               hops=max(group_size - 1, 1))
        # ring spanning nodes: bottleneck is the internode injection bw;
        # average hop latency blends intra (within node) and inter hops
        nodes = math.ceil(group_size / self.gpus_per_node)
        inter_hops = nodes
        intra_hops = max(group_size - nodes, 0)
        total_hops = max(group_size - 1, 1)
        avg_lat = (inter_hops * self.inter_latency
                   + intra_hops * self.intra_latency) / max(
                       inter_hops + intra_hops, 1)
        return PathProfile(ring_bw=self.inter_bw, latency=avg_lat,
                           hops=total_hops)

    def hierarchical_levels(self, group_size: int) -> list[tuple[int, "PathProfile"]]:
        """(participants, profile) per level for hierarchical collectives."""
        levels = []
        intra = min(group_size, self.gpus_per_node)
        if intra > 1:
            levels.append((intra, PathProfile(
                ring_bw=self.intra_bw, latency=self.intra_latency,
                hops=intra - 1)))
        nodes = math.ceil(group_size / self.gpus_per_node)
        if nodes > 1:
            levels.append((nodes, PathProfile(
                ring_bw=self.inter_bw, latency=self.inter_latency,
                hops=nodes)))
        return levels


@register_topology("torus")
@dataclass
class Torus(Topology):
    """TPU ICI torus.  dims=(16,16) is a v5e pod; wrap links double ring bw.

    A ring mapped along one torus axis uses that axis's wrap ring; a group
    larger than one axis snakes over multiple axes (still a hamiltonian
    ring on a torus — every hop is a physical link)."""
    dims: tuple[int, ...] = (16, 16)
    link_bw: float = 50e9
    link_latency: float = 1.0e-6
    name: str = "ici-torus"

    @property
    def num_devices(self) -> int:  # type: ignore[override]
        return math.prod(self.dims)

    def ring(self, group_size: int) -> PathProfile:
        return PathProfile(ring_bw=self.link_bw, latency=self.link_latency,
                           hops=max(group_size - 1, 1), bidirectional=True)

    def axis_rings(self, group_size: int) -> int:
        """Independent bidirectional rings usable by one collective.

        On a torus, a collective along a mesh axis can stripe payload over
        both directions; with wraparound links each participant has 2 links
        per axis, so an axis-aligned ring sustains 2×link_bw."""
        return 2


@register_topology("multipod")
@dataclass
class MultiPod(Topology):
    """Pods of ``pod_topology`` connected by a data-center network (DCN)."""
    pod: Torus = field(default_factory=Torus)
    num_pods: int = 2
    dcn_bw_per_host: float = 12.5e9   # 100 Gb/s NIC
    hosts_per_pod: int = 64           # v5e: 4 chips/host
    dcn_latency: float = 10e-6
    name: str = "multipod"

    @property
    def num_devices(self) -> int:  # type: ignore[override]
        return self.pod.num_devices * self.num_pods

    def ring(self, group_size: int) -> PathProfile:
        if group_size <= self.pod.num_devices:
            return self.pod.ring(group_size)
        # cross-pod ring: DCN is the bottleneck, but all hosts inject in
        # parallel — aggregate DCN bw divided by participating chips
        chips_per_pod = self.pod.num_devices
        agg_dcn = self.dcn_bw_per_host * self.hosts_per_pod
        per_chip = agg_dcn / chips_per_pod
        return PathProfile(ring_bw=per_chip, latency=self.dcn_latency,
                           hops=self.num_pods, bidirectional=True)

    def hierarchical_levels(self, group_size: int) -> list[tuple[int, PathProfile]]:
        levels = []
        intra = min(group_size, self.pod.num_devices)
        if intra > 1:
            levels.append((intra, self.pod.ring(intra)))
        pods = math.ceil(group_size / self.pod.num_devices)
        if pods > 1:
            chips_per_pod = self.pod.num_devices
            agg = self.dcn_bw_per_host * self.hosts_per_pod
            levels.append((pods, PathProfile(
                ring_bw=agg / chips_per_pod, latency=self.dcn_latency,
                hops=pods)))
        return levels

    @classmethod
    def from_spec(cls, params: dict, system, context) -> "MultiPod":
        """Spec form: the nested ``pod`` params dict builds the Torus."""
        p = dict(params)
        pod = p.pop("pod", None)
        if pod is not None:
            p["pod"] = Torus.from_spec(dict(pod), system, context)
        return cls(**p)


@register_topology("auto")
class AutoTopology:
    """Derive the topology family from the grid system's interconnect
    record — the cross-architecture axis: one grid, per-system native
    fabric (all-to-all node for GPUs, torus for TPUs).

    Not a topology itself: ``from_spec`` *returns* the derived
    :class:`AllToAllNode`/:class:`Torus`.  Only num_devices/link_bw come
    from the system so the numbers match a hand-built topology with
    class defaults."""

    @classmethod
    def from_spec(cls, params: dict, system, context) -> Topology:
        ic = system.interconnect
        n = int(params.get("num_devices", 4))
        if ic.kind in ("torus2d", "torus3d"):
            dims = tuple(ic.params.get("dims", (2, 2)))
            if "num_devices" in params and n != math.prod(dims):
                # a torus fabric is fixed by the system's dims; silently
                # simulating a different device count than requested
                # would corrupt the cross-architecture comparison
                raise ValueError(
                    f"topology 'auto' on system {system.name!r}: requested "
                    f"num_devices={n} but the system's "
                    f"{ic.kind} interconnect has dims={dims} "
                    f"({math.prod(dims)} devices) — drop num_devices to "
                    "use the system fabric, or use an explicit 'torus' "
                    "topology with your own dims")
            return Torus(dims=dims, link_bw=ic.link_bw)
        return AllToAllNode(num_devices=n, link_bw=ic.link_bw)
