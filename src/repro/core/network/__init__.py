from .collective_models import collective_time
from .scheduler import ScheduleResult, simulate
from .topology import AllToAllNode, Dragonfly, MultiPod, PathProfile, Topology, Torus

__all__ = [
    "collective_time", "ScheduleResult", "simulate",
    "AllToAllNode", "Dragonfly", "MultiPod", "PathProfile", "Topology", "Torus",
]
