"""Analytical collective-communication cost models (ASTRA-sim-analytical
class backend, paper §IV-C4).

Standard algorithm costs on a ring of g participants with per-direction
bandwidth B, payload S per participant, per-hop latency α:

  all_reduce      : 2·(g-1)/g · S / B_eff + 2·(g-1)·α   (RS + AG)
  all_gather      : (g-1)/g · S_out / B_eff + (g-1)·α
  reduce_scatter  : (g-1)/g · S / B_eff + (g-1)·α
  all_to_all      : (g-1)/g · S / B_bisect + (g-1)·α
  collective_perm : S / B + α

B_eff doubles on bidirectional rings (both directions carry half the
payload); hierarchical topologies run one phase per level.
"""
from __future__ import annotations

from ..ir.collectives import CommSpec
from .topology import PathProfile, Topology


def _ring_time(size: float, g: int, p: PathProfile, passes: float) -> float:
    if g <= 1 or size <= 0:
        return 0.0
    bw = p.ring_bw * (2 if p.bidirectional else 1)
    steps = (g - 1) * passes
    return passes * (g - 1) / g * size / bw + steps * p.latency


def collective_time(spec: CommSpec, topo: Topology,
                    compression: float = 1.0) -> float:
    """Seconds for one collective.  ``compression`` scales payload (e.g.
    0.25 for int8-quantized gradient all-reduce)."""
    g = spec.group_size
    if g <= 1:
        return 0.0
    size = spec.algo_bytes * compression
    levels = getattr(topo, "hierarchical_levels", None)

    if spec.kind == "all_reduce":
        if levels:
            total = 0.0
            remaining = size
            lv = levels(g)
            # hierarchical: RS at each level inward, AG back out
            for i, (parts, prof) in enumerate(lv):
                total += _ring_time(remaining, parts, prof, passes=2.0)
                remaining = remaining / parts
            return total
        return _ring_time(size, g, topo.ring(g), passes=2.0)

    if spec.kind in ("all_gather", "reduce_scatter"):
        if levels:
            total, remaining = 0.0, size
            for parts, prof in levels(g):
                total += _ring_time(remaining, parts, prof, passes=1.0)
            return total
        return _ring_time(size, g, topo.ring(g), passes=1.0)

    if spec.kind in ("all_to_all", "ragged_all_to_all"):
        p = topo.ring(g)
        bw = p.ring_bw * (2 if p.bidirectional else 1)
        return (g - 1) / g * size / bw + p.latency * 2

    if spec.kind in ("collective_permute", "send", "recv",
                     "collective_broadcast"):
        p = topo.ring(min(g, 2))
        return size / p.ring_bw + p.latency

    # unknown collective: conservative ring all-reduce cost
    return _ring_time(size, g, topo.ring(g), passes=2.0)
