"""Event-driven trace scheduler (the ASTRA-sim role, paper §III-B(d)).

Consumes a Chakra-style Trace and a Topology, schedules COMP nodes on the
device's compute stream and COMM nodes on the network stream, honoring data
dependencies.  Two scheduling modes:

  * ``overlap=False`` — collectives serialize with compute (paper's
    synchronous-collective configuration: async collective passes are
    disabled in its pipeline);
  * ``overlap=True``  — a COMM node may run concurrently with COMP nodes it
    does not depend on (what the dependency-aware slicer exposes).

Also models straggler injection (per-device slowdown factor; SPMD
collectives finish at the *slowest* participant — the classic straggler
amplification at scale) and gradient-compression payload scaling.
"""
from __future__ import annotations

import heapq
from dataclasses import dataclass, field

from ..trace.chakra import Trace
from .collective_models import collective_time
from .topology import Topology


@dataclass
class ScheduleResult:
    makespan_s: float
    compute_busy_s: float
    comm_busy_s: float
    exposed_comm_s: float          # comm time NOT hidden behind compute
    node_finish: dict = field(default_factory=dict)
    breakdown: dict = field(default_factory=dict)

    @property
    def comm_fraction(self) -> float:
        return self.exposed_comm_s / self.makespan_s if self.makespan_s else 0.0


def simulate(trace: Trace, topo: Topology, *, overlap: bool = False,
             straggler_factor: float = 1.0, compression: float = 1.0,
             comm_type_breakdown: bool = True) -> ScheduleResult:
    """Schedule the trace; returns makespan and utilization breakdown.

    ``straggler_factor`` ≥ 1 stretches every collective (the slowest
    participant gates the group) — a single slow node's effect on an SPMD
    program.  Compute durations are per-device estimates and already
    reflect the modeled device.
    """
    nodes = trace.nodes
    n = len(nodes)
    indeg = [0] * n
    children: list[list[int]] = [[] for _ in range(n)]
    for node in nodes:
        for d in node.data_deps:
            indeg[node.id] += 1
            children[d].append(node.id)

    durations = [0.0] * n
    comm_busy = 0.0
    comp_busy = 0.0
    breakdown: dict[str, float] = {}
    for node in nodes:
        if node.node_type == "COMM_COLL_NODE":
            from ..ir.collectives import CommSpec
            spec = CommSpec(
                kind=node.comm_type.lower(), bytes_in=node.comm_size,
                bytes_out=node.comm_size, group_size=node.group_size,
                num_groups=node.num_groups)
            t = collective_time(spec, topo, compression) * straggler_factor
            durations[node.id] = t
            comm_busy += t
            if comm_type_breakdown:
                breakdown[node.comm_type] = breakdown.get(node.comm_type, 0.0) + t
        else:
            durations[node.id] = node.duration_us * 1e-6
            comp_busy += durations[node.id]
            if comm_type_breakdown:
                breakdown["COMP"] = breakdown.get("COMP", 0.0) + durations[node.id]

    # two resources: compute stream, network stream
    comp_free = 0.0
    net_free = 0.0
    finish = [0.0] * n
    ready: list[tuple[int, int]] = []  # (id, id) min-heap keeps trace order
    remaining = 0
    for node in nodes:
        if indeg[node.id] == 0:
            heapq.heappush(ready, (node.id, node.id))
        remaining += 1

    deps_finish = [0.0] * n
    processed = 0
    while ready:
        _, nid = heapq.heappop(ready)
        node = nodes[nid]
        start_after = deps_finish[nid]
        if node.node_type == "COMM_COLL_NODE":
            if overlap:
                start = max(start_after, net_free)
                net_free = start + durations[nid]
            else:
                start = max(start_after, comp_free, net_free)
                net_free = start + durations[nid]
                comp_free = net_free
        else:
            start = max(start_after, comp_free)
            comp_free = start + durations[nid]
        finish[nid] = start + durations[nid]
        processed += 1
        for ch in children[nid]:
            deps_finish[ch] = max(deps_finish[ch], finish[nid])
            indeg[ch] -= 1
            if indeg[ch] == 0:
                heapq.heappush(ready, (ch, ch))

    if processed != n:
        raise ValueError(
            f"trace has a dependency cycle: scheduled {processed}/{n}")

    makespan = max(finish) if finish else 0.0
    exposed = max(makespan - comp_busy, 0.0)
    return ScheduleResult(
        makespan_s=makespan, compute_busy_s=comp_busy,
        comm_busy_s=comm_busy, exposed_comm_s=exposed,
        node_finish={i: finish[i] for i in range(min(n, 0))},
        breakdown=breakdown)
