"""Chakra-style execution trace (paper §III-B(d)).

The performance-annotated sliced program is mapped to a version-controlled
trace-graph format: vertices are COMP or COMM nodes, edges are data
dependencies.  We mirror the MLCommons Chakra ET node vocabulary
(COMP_NODE / COMM_COLL_NODE, comm_type, comm_size, ctrl/data deps) in JSON,
one trace per (workload × system); the network scheduler consumes this.
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field

COMM_TYPE = {
    "all_reduce": "ALL_REDUCE", "all_gather": "ALL_GATHER",
    "reduce_scatter": "REDUCE_SCATTER", "all_to_all": "ALL_TO_ALL",
    "collective_permute": "COLLECTIVE_PERMUTE", "send": "SEND", "recv": "RECV",
    "collective_broadcast": "BROADCAST", "ragged_all_to_all": "ALL_TO_ALL",
}


@dataclass
class TraceNode:
    id: int
    node_type: str                  # "COMP_NODE" | "COMM_COLL_NODE"
    name: str
    duration_us: float = 0.0        # COMP: filled by the compute estimator
    comm_type: str = ""             # COMM only
    comm_size: float = 0.0          # COMM only: per-participant payload bytes
    group_size: int = 1
    num_groups: int = 1
    data_deps: list[int] = field(default_factory=list)
    attrs: dict = field(default_factory=dict)


@dataclass
class Trace:
    nodes: list[TraceNode] = field(default_factory=list)
    meta: dict = field(default_factory=dict)

    def add_comp(self, name: str, duration_us: float,
                 deps: list[int] | None = None, **attrs) -> int:
        nid = len(self.nodes)
        self.nodes.append(TraceNode(
            id=nid, node_type="COMP_NODE", name=name,
            duration_us=duration_us, data_deps=sorted(deps or []),
            attrs=attrs))
        return nid

    def add_comm(self, kind: str, size_bytes: float, group_size: int,
                 num_groups: int = 1, deps: list[int] | None = None,
                 name: str = "", **attrs) -> int:
        nid = len(self.nodes)
        self.nodes.append(TraceNode(
            id=nid, node_type="COMM_COLL_NODE", name=name or kind,
            comm_type=COMM_TYPE.get(kind, kind.upper()),
            comm_size=size_bytes, group_size=group_size,
            num_groups=num_groups, data_deps=sorted(deps or []),
            attrs=attrs))
        return nid

    # ---------------- (de)serialization ----------------
    def to_json(self) -> str:
        return json.dumps(
            {"schema": "repro-chakra-et/1", "meta": self.meta,
             "nodes": [asdict(n) for n in self.nodes]}, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "Trace":
        d = json.loads(text)
        t = cls(meta=d.get("meta", {}))
        for n in d["nodes"]:
            t.nodes.append(TraceNode(**n))
        return t

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "Trace":
        with open(path) as f:
            return cls.from_json(f.read())

    # ---------------- stats ----------------
    @property
    def total_comp_us(self) -> float:
        return sum(n.duration_us for n in self.nodes
                   if n.node_type == "COMP_NODE")

    @property
    def total_comm_bytes(self) -> float:
        return sum(n.comm_size for n in self.nodes
                   if n.node_type == "COMM_COLL_NODE")

    def validate(self) -> None:
        ids = {n.id for n in self.nodes}
        for n in self.nodes:
            for d in n.data_deps:
                if d not in ids:
                    raise ValueError(f"node {n.id} depends on missing {d}")
                if d >= n.id:
                    raise ValueError(f"node {n.id} has forward dep {d}")
