from .chakra import COMM_TYPE, Trace, TraceNode

__all__ = ["COMM_TYPE", "Trace", "TraceNode"]
