"""Hardware system descriptions (paper Table IV + Fig 5, extended).

Every estimator and the network simulator read from these records, so a
workload can be re-costed on a different system by swapping one object —
the paper's cross-architecture axis.

The records themselves are *data*, not code: the shipped catalog lives
in ``specs/systems/*.json`` (one file per system) and loads through
:class:`~repro.core.catalog.SystemRegistry`, which also accepts user
catalogs (``--systems`` on the CLI, ``Session(systems=[...])`` in the
API).  This module keeps the :class:`System`/:class:`Interconnect`
dataclasses, the calibrated host-CPU system, and — as a back-compat
shim — the historical module-level names (``A100`` … ``TPU_V5E``,
``SYSTEMS``, ``get_system``), all of which now resolve from the catalog.
"""
from __future__ import annotations

import time
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Interconnect:
    kind: str                 # "all_to_all" | "dragonfly" | "torus2d" | "torus3d" | "host"
    link_bw: float            # bytes/s per link per direction
    link_latency: float = 1e-6
    links_per_device: int = 1
    params: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        """JSON-ready dict form (tuple params become lists)."""
        d = asdict(self)
        d["params"] = {k: (list(v) if isinstance(v, tuple) else v)
                       for k, v in self.params.items()}
        if not d["params"]:
            del d["params"]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Interconnect":
        """Inverse of :meth:`to_dict`; list params (e.g. torus ``dims``)
        become tuples so round-trips compare equal."""
        d = dict(d)
        params = {k: (tuple(v) if isinstance(v, list) else v)
                  for k, v in (d.pop("params", None) or {}).items()}
        return cls(params=params, **d)


@dataclass(frozen=True)
class System:
    name: str
    peak_flops: dict          # dtype -> FLOP/s (dense)
    mem_bw: float             # bytes/s HBM
    mem_capacity: float       # bytes
    interconnect: Interconnect
    # systolic-array geometry (TPU-class; GPUs get tensor-core-equivalent)
    mxu_rows: int = 128
    mxu_cols: int = 128
    n_mxu: int = 2
    clock_hz: float = 940e6
    vmem_bytes: float = 128 * 2**20
    # fixed per-kernel launch/dispatch overhead observed on the platform
    kernel_overhead_s: float = 2e-6
    # TCO model (optional catalog fields, per device): None = unpriced —
    # cost/power report columns are simply absent for such systems
    cost_per_hour: float | None = None   # USD per device-hour (on-demand)
    tdp_watts: float | None = None       # board TDP, watts per device

    def flops_for(self, dtype: str) -> float:
        if dtype in self.peak_flops:
            return self.peak_flops[dtype]
        if dtype in ("f16", "bf16"):
            return self.peak_flops.get("bf16", self.peak_flops.get(
                "f16", self.peak_flops["f32"]))
        return self.peak_flops.get("f32", max(self.peak_flops.values()))

    def to_dict(self) -> dict:
        """JSON-ready dict form — the catalog record format (minus the
        catalog ``id``, which is the file stem / registration key)."""
        d = asdict(self)
        d["interconnect"] = self.interconnect.to_dict()
        # optional TCO fields stay absent (not null) when unpriced, so
        # pre-cost-model catalog records round-trip byte-identically
        for k in ("cost_per_hour", "tdp_watts"):
            if d[k] is None:
                del d[k]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "System":
        """Inverse of :meth:`to_dict`:
        ``System.from_dict(s.to_dict()) == s`` for any system, including
        after a JSON round-trip."""
        d = dict(d)
        d.pop("id", None)
        d["interconnect"] = Interconnect.from_dict(d["interconnect"])
        d["peak_flops"] = {k: float(v) for k, v in d["peak_flops"].items()}
        return cls(**d)


_G = 1e9

# ---- host CPU (ground-truth platform for profiling validation) ----
_HOST_CACHE: dict[str, float] = {}


def _measure_host_matmul_flops() -> float:
    """Calibrate host peak FLOP/s with a jitted bf16 GEMM burst.

    bf16 is what our workloads run in; on CPU it is emulated, so an f32
    numpy calibration would overstate the achievable rate ~4×."""
    import jax
    import jax.numpy as jnp
    n = 512
    a = jnp.ones((n, n), jnp.bfloat16)
    b = jnp.ones((n, n), jnp.bfloat16)
    f = jax.jit(lambda x, y: x @ y)
    f(a, b).block_until_ready()  # compile + warmup
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 2 * n**3 / best


def host_system(calibrate: bool = True) -> System:
    """The container's CPU, as a System (used as profiling ground truth)."""
    if "flops" not in _HOST_CACHE:
        _HOST_CACHE["flops"] = (
            _measure_host_matmul_flops() if calibrate else 50e9)
    f = _HOST_CACHE["flops"]
    return System(
        name="host-cpu",
        peak_flops={"f32": f, "bf16": f, "f16": f, "f64": f / 2},
        mem_bw=20e9, mem_capacity=16 * _G,
        interconnect=Interconnect("host", link_bw=10e9),
        mxu_rows=8, mxu_cols=8, n_mxu=1, clock_hz=3e9,
        vmem_bytes=32 * 2**20, kernel_overhead_s=5e-6,
    )


def get_system(name: str) -> System:
    """Resolve a catalog id (or ``host``) from the default catalog.

    Back-compat shim over
    :meth:`repro.core.catalog.SystemRegistry.get`; sessions with their
    own catalogs resolve through ``session.systems.get`` instead."""
    from .catalog import default_registry
    return default_registry().get(name)


#: historical module-level constant -> catalog id (PEP 562 re-exports)
_CATALOG_NAMES = {
    "A100": "a100", "H100": "h100", "H200": "h200", "B200": "b200",
    "GH200": "gh200", "H100_PAPER": "h100-paper",
    "H200_PAPER": "h200-paper", "B200_PAPER": "b200-paper",
    "TPU_V3_CORE": "tpu-v3", "TPU_V5E": "tpu-v5e",
}


def __getattr__(name: str):
    """Back-compat: the Table-IV literals that used to live here resolve
    from the shipped catalog (``from repro.core.systems import A100`` and
    ``SYSTEMS`` keep working, and agree with the catalog by construction).
    """
    if name != "SYSTEMS" and name not in _CATALOG_NAMES:
        # reject unknown names (incl. the import machinery's __path__
        # probe) *before* touching catalog — importing it from here on
        # such a probe would be circular
        raise AttributeError(
            f"module {__name__!r} has no attribute {name!r}")
    from .catalog import default_registry
    if name == "SYSTEMS":
        return default_registry().as_dict()
    return default_registry().get(_CATALOG_NAMES[name])
