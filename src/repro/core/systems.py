"""Hardware system descriptions (paper Table IV + Fig 5, extended).

Every estimator and the network simulator read from these records, so a
workload can be re-costed on a different system by swapping one object —
the paper's cross-architecture axis.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class Interconnect:
    kind: str                 # "all_to_all" | "dragonfly" | "torus2d" | "torus3d" | "host"
    link_bw: float            # bytes/s per link per direction
    link_latency: float = 1e-6
    links_per_device: int = 1
    params: dict = field(default_factory=dict)


@dataclass(frozen=True)
class System:
    name: str
    peak_flops: dict          # dtype -> FLOP/s (dense)
    mem_bw: float             # bytes/s HBM
    mem_capacity: float       # bytes
    interconnect: Interconnect
    # systolic-array geometry (TPU-class; GPUs get tensor-core-equivalent)
    mxu_rows: int = 128
    mxu_cols: int = 128
    n_mxu: int = 2
    clock_hz: float = 940e6
    vmem_bytes: float = 128 * 2**20
    # fixed per-kernel launch/dispatch overhead observed on the platform
    kernel_overhead_s: float = 2e-6

    def flops_for(self, dtype: str) -> float:
        if dtype in self.peak_flops:
            return self.peak_flops[dtype]
        if dtype in ("f16", "bf16"):
            return self.peak_flops.get("bf16", self.peak_flops.get(
                "f16", self.peak_flops["f32"]))
        return self.peak_flops.get("f32", max(self.peak_flops.values()))


_T = 1e12
_G = 1e9

# ---- paper Table IV: GPU systems (4-GPU all-to-all NVLink nodes) ----
A100 = System(
    name="A100-40GB-SXM",
    peak_flops={"bf16": 312 * _T, "f16": 312 * _T, "f32": 19.5 * _T},
    mem_bw=1.94e12, mem_capacity=40 * _G,
    interconnect=Interconnect("all_to_all", link_bw=100 * _G),
    mxu_rows=16, mxu_cols=16, n_mxu=432, clock_hz=1.41e9,
    vmem_bytes=40 * 2**20, kernel_overhead_s=4e-6,
)
H100 = System(
    name="H100-80GB-SXM",
    peak_flops={"bf16": 1979 * _T / 2, "f16": 1979 * _T / 2,
                "f32": 67 * _T, "f8e4m3fn": 1979 * _T},
    mem_bw=3.35e12, mem_capacity=80 * _G,
    interconnect=Interconnect("all_to_all", link_bw=150 * _G),
    mxu_rows=16, mxu_cols=16, n_mxu=528, clock_hz=1.83e9,
    vmem_bytes=50 * 2**20, kernel_overhead_s=3e-6,
)
# The paper's Table IV lists the sparse/marketing 1979 TFLOP/s for H100/H200;
# we keep a separate "paper-faithful" variant used when reproducing its plots.
H100_PAPER = replace(H100, name="H100-paper",
                     peak_flops={"bf16": 1979 * _T, "f16": 1979 * _T,
                                 "f32": 67 * _T})
H200 = System(
    name="H200-141GB-SXM",
    peak_flops={"bf16": 1979 * _T / 2, "f16": 1979 * _T / 2, "f32": 67 * _T},
    mem_bw=4.8e12, mem_capacity=141 * _G,
    interconnect=Interconnect("all_to_all", link_bw=150 * _G),
    mxu_rows=16, mxu_cols=16, n_mxu=528, clock_hz=1.83e9,
    vmem_bytes=50 * 2**20, kernel_overhead_s=3e-6,
)
H200_PAPER = replace(H200, name="H200-paper",
                     peak_flops={"bf16": 1979 * _T, "f16": 1979 * _T,
                                 "f32": 67 * _T})
B200 = System(
    name="B200-180GB-HGX",
    peak_flops={"bf16": 2250 * _T, "f16": 2250 * _T, "f32": 80 * _T},
    mem_bw=7.7e12, mem_capacity=180 * _G,
    interconnect=Interconnect("all_to_all", link_bw=300 * _G),
    mxu_rows=16, mxu_cols=16, n_mxu=592, clock_hz=1.9e9,
    vmem_bytes=60 * 2**20, kernel_overhead_s=3e-6,
)
B200_PAPER = replace(B200, name="B200-paper",
                     peak_flops={"bf16": 4500 * _T, "f16": 4500 * _T,
                                 "f32": 80 * _T})
GH200 = System(  # paper §V-B scale-out node GPU
    name="GH200",
    peak_flops={"bf16": 990 * _T, "f16": 990 * _T, "f32": 67 * _T},
    mem_bw=4.9e12, mem_capacity=96 * _G,
    interconnect=Interconnect("all_to_all", link_bw=150 * _G),
    mxu_rows=16, mxu_cols=16, n_mxu=528, clock_hz=1.83e9,
    vmem_bytes=50 * 2**20, kernel_overhead_s=3e-6,
)

# ---- TPUs ----
TPU_V3_CORE = System(  # paper Fig 5 (per-core, from xprof)
    name="TPUv3-core",
    peak_flops={"bf16": 61.4 * _T, "f32": 15.4 * _T},
    mem_bw=450e9, mem_capacity=16 * _G,
    interconnect=Interconnect("torus2d", link_bw=70 * _G,
                              links_per_device=4,
                              params={"dims": (4, 2)}),
    mxu_rows=128, mxu_cols=128, n_mxu=2, clock_hz=940e6,
    vmem_bytes=16 * 2**20, kernel_overhead_s=2e-6,
)
# Roofline-target chip for this repo's dry-run mesh (constants mandated by
# the deliverable: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
TPU_V5E = System(
    name="TPUv5e",
    peak_flops={"bf16": 197 * _T, "f32": 49 * _T, "s8": 394 * _T},
    mem_bw=819e9, mem_capacity=16 * _G,
    interconnect=Interconnect("torus2d", link_bw=50 * _G,
                              links_per_device=4,
                              params={"dims": (16, 16)}),
    mxu_rows=128, mxu_cols=128, n_mxu=4, clock_hz=1.74e9,
    vmem_bytes=128 * 2**20, kernel_overhead_s=1e-6,
)

# ---- host CPU (ground-truth platform for profiling validation) ----
_HOST_CACHE: dict[str, float] = {}


def _measure_host_matmul_flops() -> float:
    """Calibrate host peak FLOP/s with a jitted bf16 GEMM burst.

    bf16 is what our workloads run in; on CPU it is emulated, so an f32
    numpy calibration would overstate the achievable rate ~4×."""
    import jax
    import jax.numpy as jnp
    n = 512
    a = jnp.ones((n, n), jnp.bfloat16)
    b = jnp.ones((n, n), jnp.bfloat16)
    f = jax.jit(lambda x, y: x @ y)
    f(a, b).block_until_ready()  # compile + warmup
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        f(a, b).block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return 2 * n**3 / best


def host_system(calibrate: bool = True) -> System:
    """The container's CPU, as a System (used as profiling ground truth)."""
    if "flops" not in _HOST_CACHE:
        _HOST_CACHE["flops"] = (
            _measure_host_matmul_flops() if calibrate else 50e9)
    f = _HOST_CACHE["flops"]
    return System(
        name="host-cpu",
        peak_flops={"f32": f, "bf16": f, "f16": f, "f64": f / 2},
        mem_bw=20e9, mem_capacity=16 * _G,
        interconnect=Interconnect("host", link_bw=10e9),
        mxu_rows=8, mxu_cols=8, n_mxu=1, clock_hz=3e9,
        vmem_bytes=32 * 2**20, kernel_overhead_s=5e-6,
    )


SYSTEMS = {
    "a100": A100, "h100": H100, "h200": H200, "b200": B200, "gh200": GH200,
    "h100-paper": H100_PAPER, "h200-paper": H200_PAPER,
    "b200-paper": B200_PAPER,
    "tpu-v3": TPU_V3_CORE, "tpu-v5e": TPU_V5E,
}


def get_system(name: str) -> System:
    if name == "host":
        return host_system()
    return SYSTEMS[name.lower()]
