"""Open extension registries for estimator and topology kinds.

The paper's central claim is that one StableHLO representation fans out
to *many* performance models across *many* architectures — so the
estimator and topology vocabularies must be open, not if/elif chains.
A :class:`Registry` maps a kind name (the string a campaign spec uses)
to a *backend class* carrying a ``from_spec(options, system, context)``
classmethod; ``repro.campaign.builders`` materializes grid points by
registry lookup and ``CampaignSpec.validate`` queries the same registry,
so the validator and the runner can never disagree about what exists.

Built-in kinds are registered *lazily*: the registry knows their names
and home modules up front (so ``python -m repro.campaign validate`` can
check a spec in an environment without numpy/jax), but only imports the
module — whose ``@register_estimator`` / ``@register_topology``
decorators then fire — when a class is actually requested.

Third-party backends register through the same decorators::

    from repro.api import register_estimator

    @register_estimator("my-sim")
    class MySimEstimator(ComputeEstimator):
        @classmethod
        def from_spec(cls, options, system, context):
            return cls(system, **options)

or, scoped to one :class:`repro.api.Session`, via
``session.register_estimator("my-sim")`` — session registries overlay
the global ones without mutating them.

A pip-installed package can skip the explicit import entirely by
declaring an ``importlib.metadata`` entry point in the
``repro.backends`` group::

    [project.entry-points."repro.backends"]
    my-sim = "mypkg.repro_backend"

The named module is imported (its decorators then fire) the first time
a kind lookup misses the known vocabulary or the vocabulary is listed —
once per process, never at ``import repro`` time, so minimal
environments stay import-light.  A plugin that fails to import (or
collides with an existing kind) is reported as a warning and recorded
in :func:`plugin_status`; it never takes down the host process.
"""
from __future__ import annotations

import difflib
import importlib
import sys
import threading
import warnings
from dataclasses import dataclass


@dataclass
class BuildContext:
    """What a backend's ``from_spec`` may need beyond (options, system).

    ``system_name`` is the campaign-spec system id (``"host"`` is how the
    profiling estimator detects ground-truth mode); ``program`` is the
    parsed source program when the caller has one (profiling re-emits
    regions from it); the three registries let composite backends build
    sub-backends through the same open vocabulary that built them.
    """
    system_name: str = ""
    program: object | None = None
    estimators: "Registry | None" = None
    topologies: "Registry | None" = None
    systems: object | None = None   # repro.core.catalog.SystemRegistry
    base_dir: str | None = None     # spec file's directory, for relative paths

    def resolve_path(self, path: str) -> str:
        """Resolve a spec-relative path against the spec file's dir."""
        import os
        if self.base_dir and not os.path.isabs(path):
            return os.path.join(self.base_dir, path)
        return path


#: the importlib.metadata entry-point group third-party distributions
#: use to expose backend modules for auto-discovery
PLUGIN_GROUP = "repro.backends"

_plugins_scanned = False
_plugins_lock = threading.Lock()
_plugin_modules: dict[str, str] = {}    # entry-point name -> module loaded
_plugin_errors: dict[str, str] = {}     # entry-point name -> why it failed


def discover_plugins(*, force: bool = False) -> dict[str, str]:
    """Import every ``repro.backends`` entry point, once per process.

    Each entry point names a module whose import self-registers its
    kinds through the usual decorators.  Returns the successfully loaded
    ``{entry-point name: module}`` mapping.  A plugin that raises on
    import — or whose registration collides with an existing kind — is
    skipped with a :class:`RuntimeWarning` and recorded in
    :func:`plugin_status`; one bad distribution must not break every
    other backend on the machine.  ``force=True`` rescans (tests and
    long-lived processes that just installed a package)."""
    global _plugins_scanned
    if _plugins_scanned and not force:
        return dict(_plugin_modules)
    # the multithreaded serve daemon hits this from concurrent lookups:
    # mark scanned only after the scan, under a lock, so a racing
    # Registry.get/__contains__ blocks here instead of observing a
    # half-populated vocabulary and raising unknown-kind
    with _plugins_lock:
        if _plugins_scanned and not force:
            return dict(_plugin_modules)
        import importlib.metadata as _md
        try:
            eps = _md.entry_points(group=PLUGIN_GROUP)
        except TypeError:   # pragma: no cover — legacy dict API (<3.10)
            eps = _md.entry_points().get(PLUGIN_GROUP, [])
        for ep in eps:
            if ep.name in _plugin_modules:
                continue
            try:
                ep.load()
            except Exception as e:  # noqa: BLE001 — isolate broken plugins
                _plugin_errors[ep.name] = f"{type(e).__name__}: {e}"
                warnings.warn(
                    f"repro backend plugin {ep.name!r} ({ep.value}) failed "
                    f"to load and was skipped: {_plugin_errors[ep.name]}",
                    RuntimeWarning, stacklevel=2)
            else:
                _plugin_modules[ep.name] = ep.value
                _plugin_errors.pop(ep.name, None)
        _plugins_scanned = True
        return dict(_plugin_modules)


def plugin_status() -> dict:
    """What entry-point discovery has done so far in this process."""
    return {"scanned": _plugins_scanned,
            "loaded": dict(_plugin_modules),
            "errors": dict(_plugin_errors)}


class Registry:
    """Name -> backend-class registry with lazy builtins and scoping.

    * ``kinds()`` / ``in`` work without importing any backend module —
      validation stays usable in minimal environments;
    * ``get(kind)`` resolves lazily registered builtins by importing
      their home module (the module's own decorator registers the class);
    * ``scope()`` returns a child registry that falls back to this one
      for lookups but keeps its own registrations local — the mechanism
      behind per-:class:`repro.api.Session` backends;
    * unknown kinds raise with the live vocabulary and a did-you-mean
      suggestion derived from it.
    """

    def __init__(self, label: str, builtins: dict[str, str] | None = None,
                 parent: "Registry | None" = None):
        self.label = label
        self.parent = parent
        self._entries: dict[str, type] = {}
        self._builtins: dict[str, str] = dict(builtins or {})

    # ------------------------------ queries ------------------------------

    def kinds(self) -> tuple[str, ...]:
        """Every known kind name (registered + lazy builtins + parents),
        builtins first in declaration order, then extensions by name.
        Listing the vocabulary triggers entry-point discovery, so
        pip-installed plugin kinds show up without an import."""
        discover_plugins()
        seen: dict[str, None] = {}
        root: Registry | None = self
        chain = []
        while root is not None:
            chain.append(root)
            root = root.parent
        for reg in reversed(chain):          # globals first, scopes after
            for k in reg._builtins:
                seen.setdefault(k)
        extras = set()
        for reg in chain:
            extras.update(k for k in reg._entries if k not in seen)
        for k in sorted(extras):
            seen.setdefault(k)
        return tuple(seen)

    def __contains__(self, kind: str) -> bool:
        if (kind in self._entries or kind in self._builtins
                or (self.parent is not None and kind in self.parent)):
            return True
        # unknown so far: maybe an installed-but-unimported plugin
        discover_plugins()
        return (kind in self._entries or kind in self._builtins
                or (self.parent is not None and kind in self.parent))

    def unknown_message(self, kind) -> str:
        """The error text for an unknown kind: live vocabulary plus a
        did-you-mean derived from it."""
        have = self.kinds()
        msg = f"unknown {self.label} kind {kind!r}; have {have}"
        close = difflib.get_close_matches(str(kind), have, n=1)
        if close:
            msg += f" — did you mean {close[0]!r}?"
        return msg

    # ---------------------------- registration ----------------------------

    def register(self, kind: str, obj: type | None = None, *,
                 replace: bool = False):
        """Register ``obj`` under ``kind``; usable as a decorator.

        Duplicate kinds are an error unless ``replace=True`` — except the
        self-registration a lazy builtin's home module performs when it
        is first imported, which *fulfils* the pending entry."""
        def _do(cls: type) -> type:
            if not replace:
                owner = self._builtin_owner(kind)
                fulfils = (owner is not None
                           and getattr(cls, "__module__", None) == owner)
                if (kind in self._entries
                        or (owner is not None and not fulfils)
                        or (owner is None and self.parent is not None
                            and kind in self.parent)):
                    raise ValueError(
                        f"{self.label} kind {kind!r} is already registered "
                        f"(by {self._describe(kind)}); pass replace=True to "
                        "override it")
            if not callable(getattr(cls, "from_spec", None)):
                raise TypeError(
                    f"{self.label} backend for {kind!r} needs a "
                    "from_spec(options, system, context) classmethod "
                    f"(got {cls!r})")
            self._entries[kind] = cls
            return cls

        return _do if obj is None else _do(obj)

    def _builtin_owner(self, kind: str) -> str | None:
        reg: Registry | None = self
        while reg is not None:
            if kind in reg._builtins:
                return reg._builtins[kind]
            reg = reg.parent
        return None

    def _describe(self, kind: str) -> str:
        reg: Registry | None = self
        while reg is not None:
            if kind in reg._entries:
                cls = reg._entries[kind]
                return f"{cls.__module__}.{cls.__qualname__}"
            if kind in reg._builtins:
                return reg._builtins[kind]
            reg = reg.parent
        return "<unknown>"

    # ------------------------------ lookups ------------------------------

    def get(self, kind: str) -> type:
        """The backend class for ``kind`` (resolving lazy builtins and,
        on a miss, rescanning installed entry-point plugins once)."""
        cls = self._resolve(kind)
        if cls is None:
            discover_plugins()
            cls = self._resolve(kind)
        if cls is None:
            raise ValueError(self.unknown_message(kind))
        return cls

    def _resolve(self, kind: str) -> type | None:
        """One lookup pass: local entries, lazy builtins, then parents."""
        cls = self._entries.get(kind)
        if cls is not None:
            return cls
        module = self._builtins.get(kind)
        if module is not None:
            importlib.import_module(module)
            cls = self._entries.get(kind)
            if cls is None:
                raise ImportError(
                    f"module {module!r} did not register {self.label} "
                    f"kind {kind!r} on import")
            return cls
        if self.parent is not None:
            return self.parent._resolve(kind)
        return None

    # ------------------------------ scoping ------------------------------

    def scope(self) -> "Registry":
        """A child registry: local registrations, parent fallback."""
        return Registry(self.label, parent=self)

    def local_entries(self) -> dict[str, type]:
        """This registry's own (non-inherited) resolved entries — what a
        session ships to process-pool campaign workers."""
        return dict(self._entries)

    def portability_errors(self) -> list[str]:
        """Why this scope's classes could NOT cross a process boundary.

        Backend *classes* pickle by reference (``module.QualName``), so
        shipping a session's scoped registrations to worker processes —
        the campaign process executor, a serve fleet — requires each
        class to be importable at module level from the worker.  Returns
        one actionable message per offending class ([] when all are
        portable).  Systems ship by value and are never checked."""
        errs = []
        for kind, cls in self._entries.items():
            where = f"{getattr(cls, '__module__', '?')}." \
                    f"{getattr(cls, '__qualname__', '?')}"
            fix = (f"define the class at the top level of an importable "
                   f"module, or keep the work in-process (executor="
                   f"'serial'/'thread', a single-worker daemon)")
            if "<locals>" in getattr(cls, "__qualname__", ""):
                errs.append(
                    f"{self.label} kind {kind!r} is registered with "
                    f"{where}, a class defined inside a function — it "
                    f"cannot be pickled by reference into a worker "
                    f"process; {fix}")
                continue
            mod = sys.modules.get(getattr(cls, "__module__", ""))
            obj = mod
            for part in getattr(cls, "__qualname__", "?").split("."):
                obj = getattr(obj, part, None)
            if obj is not cls:
                errs.append(
                    f"{self.label} kind {kind!r} is registered with "
                    f"{where}, which is not reachable as a module "
                    f"attribute — a worker process cannot re-import it "
                    f"(did you register a dynamically created or "
                    f"shadowed class?); {fix}")
        return errs


#: the global estimator vocabulary; builtin kinds resolve lazily from
#: their home modules (each module self-registers via the decorator)
ESTIMATORS = Registry("estimator", builtins={
    "roofline": "repro.core.estimators.analytical",
    "systolic": "repro.core.estimators.systolic",
    "mixed": "repro.core.estimators.base",
    "profiling": "repro.core.estimators.profiling",
    "table": "repro.core.estimators.table",
    "learned": "repro.core.estimators.learned",
})

#: the global topology vocabulary
TOPOLOGIES = Registry("topology", builtins={
    "auto": "repro.core.network.topology",
    "a2a": "repro.core.network.topology",
    "dragonfly": "repro.core.network.topology",
    "torus": "repro.core.network.topology",
    "multipod": "repro.core.network.topology",
})


def register_estimator(kind: str, cls: type | None = None, *,
                       registry: Registry | None = None,
                       replace: bool = False):
    """Register an estimator backend class under ``kind`` (decorator).

    The class must carry ``from_spec(options, system, context)``
    returning a :class:`~repro.core.estimators.base.ComputeEstimator`.
    Without ``registry`` the global vocabulary is extended."""
    return (registry or ESTIMATORS).register(kind, cls, replace=replace)


def register_topology(kind: str, cls: type | None = None, *,
                      registry: Registry | None = None,
                      replace: bool = False):
    """Register a topology backend class under ``kind`` (decorator).

    The class must carry ``from_spec(params, system, context)`` returning
    a :class:`~repro.core.network.topology.Topology`."""
    return (registry or TOPOLOGIES).register(kind, cls, replace=replace)
