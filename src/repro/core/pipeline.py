"""End-to-end evaluation pipeline (paper Fig 2):

    workload export -> optimization -> slicing -> compute estimation
                    -> trace construction -> network simulation

One :class:`Workload` (a StableHLO/HLO text pair exported from a jitted
step) can be driven through any combination of slicer × estimator ×
topology — the cross-fidelity, cross-architecture axis of the paper.

Execution is split into two phases:

* **plan** — parse + slice, producing a :class:`PredictionPlan`.  A plan
  depends only on ``(workload, fidelity, slicer)``, so one plan serves
  every grid point that shares those axes (the campaign engine builds
  each plan exactly once and fans it out);
* **evaluate** — estimator + trace + network simulation against a plan.
  All region latencies are fetched through the estimator's *batched*
  API, so a shared cache store pays one lock round-trip per plan
  evaluation instead of one per region.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .estimators.base import ComputeEstimator
from .estimators.cache import CachedEstimator, CacheStats
from .ir.arrays import RegionArrays, build_region_arrays
from .ir.graph import Program
from .ir.parser import parse
from .network.scheduler import ScheduleResult, simulate
from .network.topology import Topology
from .slicing.depaware import dependency_aware_split
from .slicing.linear import linear_split
from .slicing.regions import Segment
from .trace.chakra import Trace

#: evaluate phase default: feed plans' precomputed RegionArrays to the
#: estimator batch API (vectorized where the estimator supports it; the
#: values are bit-identical either way — see tests/test_campaign_diff.py)
DEFAULT_VECTORIZE = True


@dataclass
class Workload:
    """An exported workload: raw StableHLO and/or optimized HLO text."""
    name: str
    stablehlo_text: str | None = None
    hlo_text: str | None = None
    meta: dict = field(default_factory=dict)

    def program(self, fidelity: str = "optimized") -> Program:
        if fidelity == "optimized" and self.hlo_text:
            return parse(self.hlo_text)
        if self.stablehlo_text is None:
            raise ValueError(f"workload {self.name}: no stablehlo text")
        return parse(self.stablehlo_text)


def export_workload(jitted, *specs, name: str = "workload",
                    compile_workload: bool = True, **kw) -> Workload:
    """Export a jitted function's StableHLO + optimized HLO (paper stage a).

    ``jitted`` must be a ``jax.jit`` result; ``specs`` are
    ShapeDtypeStructs (sharded or not) — no device allocation happens.
    """
    lowered = jitted.lower(*specs, **kw)
    w = Workload(name=name, stablehlo_text=lowered.as_text())
    if compile_workload:
        compiled = lowered.compile()
        w.hlo_text = compiled.as_text()
        try:
            ca = compiled.cost_analysis()
            # jax <= 0.4.x returns a one-element list of dicts.
            # 0.4.x compat shim: drop the list handling when the jax
            # floor moves to >= 0.6
            if isinstance(ca, (list, tuple)):
                ca = ca[0] if ca else {}
            w.meta["cost_analysis"] = dict(ca or {})
        except Exception:
            pass
        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                w.meta["memory_analysis"] = {
                    "argument_size_in_bytes": ma.argument_size_in_bytes,
                    "output_size_in_bytes": ma.output_size_in_bytes,
                    "temp_size_in_bytes": ma.temp_size_in_bytes,
                    "generated_code_size_in_bytes": ma.generated_code_size_in_bytes,
                }
        except Exception:
            pass
    return w


@dataclass
class PredictionPlan:
    """The reusable product of the pipeline's *plan* phase.

    Everything that depends only on ``(workload, fidelity, slicer)`` —
    the parsed :class:`Program`, the slicer's segments (with region
    fingerprints already computed by ``finalize_region``), and the
    dependency map for the dependency-aware slicer.  Plans are plain
    picklable data: the campaign engine builds each one once, shares it
    across every grid point with the same key, and ships it to process
    workers instead of raw IR text.
    """
    name: str
    fidelity: str
    slicer: str
    program: Program
    segments: list[Segment]
    dep_map: dict[int, set[int]] | None = None
    #: evaluation-ready array-of-structs view of the COMP regions, in
    #: segment order (built once at plan time; numpy + interned tables,
    #: picklable like the rest of the plan)
    arrays: RegionArrays | None = None

    @property
    def key(self) -> tuple[str, str, str]:
        """The identity under which this plan is shared."""
        return (self.name, self.fidelity, self.slicer)

    @property
    def compute_regions(self) -> list:
        """The COMP regions, in segment order (the estimator batch)."""
        return [s.region for s in self.segments if s.kind == "COMP"]

    @property
    def fingerprints(self) -> set[str]:
        """Distinct region fingerprints — the plan's cache-key surface."""
        return {s.region.fingerprint for s in self.segments
                if s.kind == "COMP"}


def build_plan(program: Program, *, slicer: str = "linear",
               name: str = "workload",
               fidelity: str = "raw") -> PredictionPlan:
    """Run the plan phase: slice ``program`` once into a reusable plan
    (segments plus the evaluation-ready :class:`RegionArrays`)."""
    if slicer == "linear":
        segments, dep_map = linear_split(program), None
    elif slicer in ("dep", "dependency-aware"):
        segments, dep_map = dependency_aware_split(program)
    else:
        raise ValueError(f"unknown slicer {slicer!r}")
    arrays = build_region_arrays(
        [s.region for s in segments if s.kind == "COMP"])
    return PredictionPlan(name=name, fidelity=fidelity, slicer=slicer,
                          program=program, segments=segments,
                          dep_map=dep_map, arrays=arrays)


@dataclass
class Prediction:
    workload: str
    system: str
    estimator: str
    slicer: str
    step_time_s: float
    compute_s: float
    comm_s: float
    exposed_comm_s: float
    num_segments: int
    num_comm: int
    simulation_wall_s: float
    cache_stats: CacheStats | None = None
    schedule: ScheduleResult | None = None
    breakdown: dict = field(default_factory=dict)
    #: estimator-reported per-prediction quality fields (a learned-tier
    #: estimator's uncertainty interval + extrapolation flags); merged
    #: verbatim into the result row
    quality: dict | None = None

    def to_row(self) -> dict:
        """Flat, JSON/CSV-serializable view (drops the schedule object)."""
        row = {
            "workload": self.workload,
            "system": self.system,
            "estimator": self.estimator,
            "slicer": self.slicer,
            "step_time_s": self.step_time_s,
            "compute_s": self.compute_s,
            "comm_s": self.comm_s,
            "exposed_comm_s": self.exposed_comm_s,
            "num_segments": self.num_segments,
            "num_comm": self.num_comm,
            "simulation_wall_s": self.simulation_wall_s,
        }
        if self.quality:
            row.update(self.quality)
        if self.cache_stats is not None:
            row["cache_hits"] = self.cache_stats.hits
            row["cache_misses"] = self.cache_stats.misses
            row["cache_hit_rate"] = self.cache_stats.hit_rate
            row["cache_saved_s"] = self.cache_stats.saved_seconds
            row["cache_miss_cost_s"] = self.cache_stats.miss_cost_seconds
        return row


def _trace_from_linear(segments: list[Segment], durations: list[float],
                       name: str) -> Trace:
    """Sequential trace; loop groups are unrolled preserving group order."""
    trace = Trace(meta={"workload": name, "slicer": "linear"})
    prev: int | None = None

    def emit(seg: Segment, dur: float) -> None:
        nonlocal prev
        deps = [prev] if prev is not None else []
        if seg.kind == "COMM":
            nid = trace.add_comm(
                seg.comm.kind, seg.comm.algo_bytes, seg.comm.group_size,
                seg.comm.num_groups, deps=deps, name=seg.comm.label)
        else:
            nid = trace.add_comp(
                seg.region.label or "region", dur * 1e6, deps=deps,
                flops=seg.region.cost.flops)
        prev = nid

    i = 0
    while i < len(segments):
        seg = segments[i]
        if seg.repeat <= 1:
            emit(seg, durations[i])
            i += 1
            continue
        # contiguous run with the same group repeats together, in order
        j = i
        while (j < len(segments) and segments[j].group == seg.group
               and segments[j].repeat == seg.repeat):
            j += 1
        for _ in range(seg.repeat):
            for k in range(i, j):
                emit(segments[k], durations[k])
        i = j
    return trace


def _trace_from_dep(segments: list[Segment], deps: dict[int, set[int]],
                    durations: list[float], name: str) -> Trace:
    trace = Trace(meta={"workload": name, "slicer": "dependency-aware"})
    for idx, seg in enumerate(segments):
        d = sorted(deps.get(idx, set()))
        if seg.kind == "COMM":
            trace.add_comm(seg.comm.kind, seg.comm.algo_bytes,
                           seg.comm.group_size, seg.comm.num_groups,
                           deps=d, name=seg.comm.label)
        else:
            trace.add_comp(seg.region.label or "region",
                           durations[idx] * 1e6, deps=d,
                           flops=seg.region.cost.flops)
    return trace


@dataclass
class PredictionJob:
    """One (plan × estimator × topology × knobs) prediction, reified.

    This is the unit the campaign engine schedules: constructing the job
    is cheap and side-effect free; :meth:`run` executes stages (b)-(d) of
    the methodology as two phases — :meth:`build_plan` (parse/slice,
    skipped entirely when a prebuilt ``plan`` is supplied) and
    :meth:`evaluate` (estimator + network simulation).  ``cache_store``
    lets many jobs (and many estimators — the (H, C, config, R) key
    disambiguates, including estimator configuration) share one latency
    store, in-process or persistent; ``cached`` exposes the wrapper after
    the run so callers can collect ``new_entries`` for cross-process
    merging.  ``batch_cache=False`` forces one store round-trip per
    region (the pre-plan behavior; kept for parity testing and
    benchmarking against the batched default).
    """
    program: Program | None = None
    estimator: ComputeEstimator = None
    topology: Topology = None
    slicer: str = "linear"
    overlap: bool = False
    straggler_factor: float = 1.0
    compression: float = 1.0
    name: str = "workload"
    use_cache: bool = True
    system_name: str | None = None
    cache_store: object | None = None   # MutableMapping | PersistentCache
    plan: PredictionPlan | None = None  # prebuilt plan (skips parse/slice)
    batch_cache: bool = True
    #: None = module default (DEFAULT_VECTORIZE); False forces the scalar
    #: per-region estimator path (parity testing / benchmarking)
    vectorize: bool | None = None
    cached: CachedEstimator | None = field(default=None, init=False)

    def build_plan(self) -> PredictionPlan:
        """The plan phase for this job's (program, slicer)."""
        if self.program is None:
            raise ValueError(f"job {self.name!r}: no program and no plan")
        return build_plan(self.program, slicer=self.slicer, name=self.name)

    def evaluate(self, plan: PredictionPlan) -> Prediction:
        """The evaluate phase: cost ``plan``'s regions (one batched cache
        operation), build the trace, and simulate the network."""
        if self.estimator is None or self.topology is None:
            raise ValueError(
                f"job {self.name!r}: estimator and topology are required")
        t0 = time.perf_counter()
        self.cached = (CachedEstimator(self.estimator, store=self.cache_store)
                       if self.use_cache else None)
        est = self.cached or self.estimator

        vectorize = (DEFAULT_VECTORIZE if self.vectorize is None
                     else self.vectorize)
        arrays = plan.arrays if vectorize else None
        segments = plan.segments
        if self.batch_cache:
            costed = iter(est.get_run_time_estimates(plan.compute_regions,
                                                     arrays=arrays))
            durations = [next(costed) if s.kind == "COMP" else 0.0
                         for s in segments]
        else:
            durations = [est.get_run_time_estimate(s.region)
                         if s.kind == "COMP" else 0.0 for s in segments]
        if plan.slicer == "linear":
            trace = _trace_from_linear(segments, durations, self.name)
        else:
            trace = _trace_from_dep(segments, plan.dep_map, durations,
                                    self.name)

        trace.validate()
        sched = simulate(trace, self.topology, overlap=self.overlap,
                         straggler_factor=self.straggler_factor,
                         compression=self.compression)
        # optional estimator hook: per-prediction quality fields (the
        # learned tier's uncertainty interval + extrapolation flags) ride
        # into the result row.  Queried on the bare estimator — cache
        # hits don't change what the model knows about its confidence.
        quality_fn = getattr(self.estimator, "prediction_quality", None)
        quality = (dict(quality_fn(plan.compute_regions))
                   if quality_fn is not None else None)
        wall = time.perf_counter() - t0
        return Prediction(
            workload=self.name,
            system=self.system_name or self.estimator.system.name,
            estimator=self.estimator.toolchain,
            slicer=self.slicer,
            step_time_s=sched.makespan_s,
            compute_s=sched.compute_busy_s,
            comm_s=sched.comm_busy_s,
            exposed_comm_s=sched.exposed_comm_s,
            num_segments=len(segments),
            num_comm=sum(1 for s in segments if s.kind == "COMM"),
            simulation_wall_s=wall,
            cache_stats=self.cached.stats if self.cached else None,
            schedule=sched,
            breakdown=sched.breakdown,
            quality=quality)

    def run(self) -> Prediction:
        return self.evaluate(self.plan or self.build_plan())


def predict(program: Program, estimator: ComputeEstimator, topology: Topology,
            *, slicer: str = "linear", overlap: bool = False,
            straggler_factor: float = 1.0, compression: float = 1.0,
            name: str = "workload", use_cache: bool = True,
            system_name: str | None = None,
            cache_store: object | None = None) -> Prediction:
    """Run stages (b)-(d) of the methodology on a parsed program.

    Thin wrapper over :class:`PredictionJob` for the single-point case."""
    return PredictionJob(
        program=program, estimator=estimator, topology=topology,
        slicer=slicer, overlap=overlap, straggler_factor=straggler_factor,
        compression=compression, name=name, use_cache=use_cache,
        system_name=system_name, cache_store=cache_store).run()
