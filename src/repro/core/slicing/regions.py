"""Compute/communication segments produced by the slicing stage."""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field

from ..ir.collectives import CommSpec
from ..ir.graph import OpNode, build_def_use
from ..ir.opcost import Cost, op_cost


@dataclass
class ComputeRegion:
    ops: list[OpNode]
    label: str = ""
    cost: Cost = field(default_factory=Cost)
    boundary_in_bytes: float = 0.0
    boundary_out_bytes: float = 0.0
    fingerprint: str = ""

    @property
    def num_ops(self) -> int:
        return len(self.ops)


@dataclass
class Segment:
    """One slice of the program: either compute or communication.

    ``repeat`` carries loop multiplicity (a segment inside a scan body with
    trip count L appears once with repeat=L; the trace builder unrolls it).
    """
    kind: str                        # "COMP" | "COMM"
    region: ComputeRegion | None = None
    comm: CommSpec | None = None
    repeat: int = 1
    group: int = 0                   # loop-nest id: segments sharing a group
    #                                  repeat together, in order


def region_fingerprint(ops: list[OpNode]) -> str:
    """Structural hash: op mnemonics + shapes + key attrs.

    This is the R of the paper's (H × C × R) cache key: two regions with
    identical op sequences and shapes hit the same cache entry (e.g. the 48
    identical transformer blocks of a stacked model).
    """
    h = hashlib.sha256()
    for op in ops:
        h.update(op.op.encode())
        for t in op.operand_types:
            h.update(str(t).encode())
        for t in op.result_types:
            h.update(str(t).encode())
        for key in ("lhs_contract", "rhs_contract", "lhs_batch", "rhs_batch",
                    "feature_group_count", "dim_labels"):
            if key in op.attrs:
                h.update(f"{key}={op.attrs[key]}".encode())
        if op.trip_count > 1:
            h.update(f"trip={op.trip_count}".encode())
        for region in op.regions:
            h.update(region_fingerprint(region).encode())
    return h.hexdigest()[:16]


def finalize_region(region: ComputeRegion, program=None) -> ComputeRegion:
    """Compute aggregate cost, boundary traffic, and fingerprint."""
    cost = Cost()
    for op in region.ops:
        cost += op_cost(op, program)
    region.cost = cost
    defs = build_def_use(region.ops)
    produced = set(defs.keys())
    # inputs: operands whose producer is outside the region
    in_bytes = 0.0
    seen: set[str] = set()
    for op in region.ops:
        for name, t in zip(op.operands, op.operand_types):
            if name not in produced and name not in seen:
                seen.add(name)
                in_bytes += t.nbytes
    # outputs: conservatively, results of ops not consumed inside the region
    consumed = {o for op in region.ops for o in op.operands}
    out_bytes = 0.0
    for op in region.ops:
        for name, t in zip(op.results, op.result_types):
            if name not in consumed:
                out_bytes += t.nbytes
    region.boundary_in_bytes = in_bytes
    region.boundary_out_bytes = out_bytes
    region.fingerprint = region_fingerprint(region.ops)
    if not region.label and region.ops:
        region.label = region.ops[0].attrs.get("op_name", region.ops[0].op)
    return region
