"""Dependency-aware split (paper §III-B(b), second algorithm).

Operates at individual-operator granularity, capturing exact data
dependencies.  Produces smaller compute regions plus an explicit dependency
graph, which lets the network scheduler expose compute–communication
overlap that the linear split's total order hides.

Returns (segments, deps) where deps maps segment index -> set of segment
indices it depends on.  Loop bodies are unrolled; each iteration's entry
segments depend on the previous iteration's tail segments (loop-carried
values have no SSA producer, so name-based deps alone would be unsound).

Zero-cost ops (get_tuple_element, tuple, reshape-free metadata ops) never
become segments, but dependencies must still flow *through* them — they are
treated as aliases: their results inherit the producer set of their operands.
"""
from __future__ import annotations

from ..ir.collectives import comm_spec
from ..ir.graph import OpNode, Program, ZERO_COST_OPS
from .regions import ComputeRegion, Segment, finalize_region

#: calls to :func:`dependency_aware_split` in this process — plan reuse
#: means this grows once per (workload, fidelity) per campaign, not once
#: per job; tests and benchmarks assert on it
SPLIT_CALLS = 0


def _fuse_chains(ops: list[OpNode]) -> list[list[OpNode]]:
    """Group single-consumer chains of cheap ops with their consumer.

    Pure per-op granularity would flood the scheduler with sub-microsecond
    elementwise nodes; fusing producer chains whose only consumer is the next
    op preserves exact dependencies while keeping region count manageable.
    """
    defs: dict[str, int] = {}
    for op in ops:
        for r in op.results:
            defs[r] = op.uid
    n_consumers: dict[int, int] = {op.uid: 0 for op in ops}
    for op in ops:
        for o in set(op.operands):
            if o in defs:
                n_consumers[defs[o]] += 1
    groups: list[list[OpNode]] = []
    current: list[OpNode] = []
    for op in ops:
        current.append(op)
        chainable = (
            n_consumers[op.uid] == 1
            and op.op not in ("dot_general", "convolution", "while", "fusion")
            and not op.is_collective
        )
        if not chainable:
            groups.append(current)
            current = []
    if current:
        groups.append(current)
    return groups


def dependency_aware_split(
    program: Program,
) -> tuple[list[Segment], dict[int, set[int]]]:
    global SPLIT_CALLS
    SPLIT_CALLS += 1
    segments: list[Segment] = []
    deps: dict[int, set[int]] = {}
    producers: dict[str, set[int]] = {}   # SSA name -> producing segment set
    world = program.meta.get("num_partitions", 1)

    def dep_set(op_list: list[OpNode], extra: set[int]) -> set[int]:
        defined = {r for op in op_list for r in op.results}
        d: set[int] = set(extra)
        for op in op_list:
            for o in op.operands:
                if o not in defined:
                    d |= producers.get(o, set())
        return d

    def add_segment(seg: Segment, op_list: list[OpNode],
                    extra: set[int]) -> int:
        idx = len(segments)
        segments.append(seg)
        deps[idx] = {x for x in dep_set(op_list, extra) if x != idx}
        for op in op_list:
            for r in op.results:
                producers[r] = {idx}
        return idx

    def alias(op: OpNode) -> None:
        src: set[int] = set()
        for o in op.operands:
            src |= producers.get(o, set())
        for r in op.results:
            producers[r] = src

    def visit(ops: list[OpNode], chain_from: set[int]) -> set[int]:
        tail: set[int] = set(chain_from)
        first_pending = set(chain_from)
        start_idx = len(segments)

        def take_first() -> set[int]:
            nonlocal first_pending
            d, first_pending = first_pending, set()
            return d

        for group in _fuse_chains(ops):
            comp_ops: list[OpNode] = []
            for op in group:
                if op.op == "optimization_barrier":
                    alias(op)
                    if comp_ops:
                        region = finalize_region(
                            ComputeRegion(ops=comp_ops), program)
                        idx = add_segment(Segment("COMP", region=region),
                                          comp_ops, take_first())
                        tail = {idx}
                        comp_ops = []
                elif op.op in ZERO_COST_OPS or op.is_async_done:
                    alias(op)
                elif op.is_collective:
                    if comp_ops:
                        region = finalize_region(
                            ComputeRegion(ops=comp_ops), program)
                        idx = add_segment(Segment("COMP", region=region),
                                          comp_ops, take_first())
                        tail = {idx}
                        comp_ops = []
                    idx = add_segment(
                        Segment("COMM", comm=comm_spec(op, world)),
                        [op], take_first())
                    tail = {idx}
                elif op.op == "while" and any(
                        o.is_collective for o in op.walk()):
                    if comp_ops:
                        region = finalize_region(
                            ComputeRegion(ops=comp_ops), program)
                        idx = add_segment(Segment("COMP", region=region),
                                          comp_ops, take_first())
                        tail = {idx}
                        comp_ops = []
                    body = op.regions[-1] if op.regions else []
                    iter_tail = tail | take_first() | dep_set([op], set())
                    for _ in range(max(op.trip_count, 1)):
                        iter_tail = visit(body, iter_tail)
                    tail = iter_tail
                    for r in op.results:
                        producers[r] = set(iter_tail)
                else:
                    comp_ops.append(op)
            if comp_ops:
                region = finalize_region(ComputeRegion(ops=comp_ops), program)
                idx = add_segment(Segment("COMP", region=region),
                                  comp_ops, take_first())
                tail = {idx}
        # the iteration's tail must include every SINK segment (segments no
        # later segment of this visit depends on) — otherwise e.g. a
        # collective whose value only feeds the next iteration would not
        # serialize against its successor, and the scheduler could overlap
        # loop iterations unsoundly
        added = range(start_idx, len(segments))
        if start_idx < len(segments):
            consumed: set[int] = set()
            for i in added:
                consumed |= deps.get(i, set())
            sinks = {i for i in added if i not in consumed}
            if sinks:
                tail = sinks
        return tail

    visit(program.entry, set())
    return segments, deps
