from .regions import ComputeRegion, Segment, region_fingerprint
from .linear import linear_split
from .depaware import dependency_aware_split
from .emit import region_to_module

__all__ = [
    "ComputeRegion", "Segment", "region_fingerprint",
    "linear_split", "dependency_aware_split", "region_to_module",
]
