"""Re-emit a sliced compute region as a standalone StableHLO module.

This is what makes the profiling estimator real: each region becomes an
independently compilable/executable program (the paper runs these through
``hlo_runner_main``; we compile them with the in-process XLA client).

Only the ``stablehlo`` dialect supports emission — the paper, likewise,
profiles from the StableHLO export, not from post-compilation HLO.
"""
from __future__ import annotations

import re

from ..ir.graph import OpNode, Program
from ..ir.types import TensorType

_SSA_TOKEN = re.compile(r"(%[\w.\-#]+)")
# definitions: op results at line start, loop/iter binders, block arguments
_DEF_PATTERNS = (
    re.compile(r"(?m)^\s*(%[\w.\-#]+)(?::\d+)?\s*(?:,\s*%[\w.\-#]+\s*)*="),
    re.compile(r"(?m)^\s*%[\w.\-#]+(?::\d+)?\s*(?:,\s*(%[\w.\-#]+)\s*)+="),
    re.compile(r"[(,]\s*(%[\w.\-#]+)\s*="),          # (%iterArg = %init
)
# block-argument lines: ^bb0(%x: tensor<..>, %y: tensor<..>):
_BLOCK_ARG_LINE = re.compile(r"(?m)^\s*\^bb[\w]*\((.*)$")
_BLOCK_ARG_TOKEN = re.compile(r"(%[\w.\-#]+)\s*:")
_CONST_LIKE = {"constant", "iota"}


def _internal_defs(raw_text: str) -> set[str]:
    defs: set[str] = set()
    for pat in _DEF_PATTERNS:
        defs.update(m for m in pat.findall(raw_text))
    for line in _BLOCK_ARG_LINE.findall(raw_text):
        defs.update(_BLOCK_ARG_TOKEN.findall(line))
    return defs


class RegionEmitError(RuntimeError):
    pass


# sharding identities nested in region bodies (while/cond) — resolved at
# the text level, since nested ops are raw lines, not OpNodes
_SDY_IDENTITY = re.compile(
    r"^\s*(%[\w.\-#]+)\s*=\s*\"?(?:sdy\.sharding_constraint"
    r"|stablehlo\.custom_call @Sharding)\"?\s*\(?\s*(%[\w.\-#]+)")


def _strip_sharding_lines(lines: list[str]) -> list[str]:
    """Drop sharding-identity ops and re-route their uses to the operand."""
    alias: dict[str, str] = {}
    kept: list[str] = []
    for line in lines:
        m = _SDY_IDENTITY.match(line)
        if m:
            src = m.group(2)
            alias[m.group(1)] = alias.get(src, src)
        else:
            kept.append(line)
    if not alias:
        return lines
    return [_SSA_TOKEN.sub(lambda m: alias.get(m.group(1), m.group(1)), l)
            for l in kept]


def _mlir_type(t: TensorType) -> str:
    dims = "x".join(str(d) for d in t.shape)
    return f"tensor<{dims}{'x' if dims else ''}{t.dtype}>"


_ARG_SENTINEL = OpNode(uid=-1, results=(), op="parameter", operands=(),
                       operand_types=(), result_types=())


def _global_defs(program: Program) -> dict[str, tuple[OpNode, TensorType | None]]:
    defs: dict[str, tuple[OpNode, TensorType | None]] = {}
    # function arguments (typed from the signature) act as external defs
    for args in program.meta.get("func_args", {}).values():
        for name, t in args:
            defs.setdefault(name, (_ARG_SENTINEL, t))
    for body in program.functions.values():
        for op in body:
            for o in op.walk():
                types = list(o.result_types) or [None]
                for i, r in enumerate(o.results):
                    defs.setdefault(r, (o, types[min(i, len(types) - 1)]))
    return defs


def _referenced_functions(raw_text: str, program: Program,
                          seen: set[str]) -> list[str]:
    out: list[str] = []
    for name in re.findall(r"@([\w.\-]+)", raw_text):
        if name in seen or name == "main" or name not in program.functions:
            continue
        seen.add(name)
        callee_raw = program.meta.get("func_raw", {}).get(name, "")
        out.extend(_referenced_functions(callee_raw, program, seen))
        out.append(name)
    return out


def region_to_module(ops: list[OpNode], program: Program,
                     name: str = "region") -> tuple[str, list[TensorType]]:
    """Build a standalone module for a region.

    Returns (module_text, input_types).  External SSA values become function
    arguments (types resolved from their global defining op); constants and
    iotas referenced from outside are inlined so regions stay self-contained;
    every region-defined value not consumed inside is returned, so XLA cannot
    dead-code-eliminate interior work — mirroring the paper's per-region
    compilation scope (and its loss of cross-region optimization).
    """
    if program.dialect != "stablehlo":
        raise RegionEmitError("region emission requires the stablehlo dialect")

    # sharding annotations reference the module-level sdy.mesh symbol, which a
    # standalone region module does not carry; sharding ops are identities for
    # compute purposes -> alias their results to their operands and drop them.
    alias_map: dict[str, str] = {}
    kept_ops: list[OpNode] = []
    for op in ops:
        is_shard_op = (
            op.op in ("sharding_constraint", "sharding_group", "propagation_barrier")
            or (op.op == "custom_call" and "@Sharding" in op.raw)
        )
        if is_shard_op and op.results and op.operands:
            src = op.operands[0]
            alias_map[op.results[0]] = alias_map.get(src, src)
        else:
            kept_ops.append(op)
    ops = kept_ops
    if not ops:
        raise RegionEmitError("region contains only sharding ops")

    raw_text = "\n".join(op.raw for op in ops)
    if alias_map:
        raw_text = _SSA_TOKEN.sub(
            lambda m: alias_map.get(m.group(1), m.group(1)), raw_text)
    internal = _internal_defs(raw_text)
    gdefs = _global_defs(program)

    inline_lines: list[str] = []
    inputs: list[tuple[str, TensorType]] = []
    seen: set[str] = set()
    for tok in _SSA_TOKEN.findall(raw_text):
        base = tok.split("#")[0]
        if tok in internal or base in internal or tok in seen:
            continue
        seen.add(tok)
        entry = gdefs.get(tok) or gdefs.get(base)
        if entry is None:
            raise RegionEmitError(f"unresolvable external value {tok}")
        def_op, t = entry
        if def_op.op in _CONST_LIKE and "\n" not in def_op.raw:
            inline_lines.append(def_op.raw.strip())
            internal.add(tok)
            internal.add(base)
        else:
            if t is None:
                raise RegionEmitError(f"untyped external value {tok}")
            inputs.append((tok, t))

    # a value is "consumed internally" if referenced anywhere other than its
    # own definition; count occurrences to decide
    occurrence: dict[str, int] = {}
    for tok in _SSA_TOKEN.findall(raw_text):
        occurrence[tok] = occurrence.get(tok, 0) + 1

    outputs: list[tuple[str, TensorType]] = []
    for op in ops:
        types = list(op.result_types) or [None]
        for i, r in enumerate(op.results):
            if "#" in r:
                continue
            t = types[min(i, len(types) - 1)]
            if t is None:
                continue
            multi = any(x.startswith(r + "#") for x in occurrence)
            if occurrence.get(r, 0) <= 1 and not multi:
                outputs.append((r, t))
    if not outputs:
        last = ops[-1]
        outputs = [(r, t) for r, t in zip(last.results, last.result_types)
                   if t is not None and "#" not in r]
    if not outputs:
        raise RegionEmitError("region has no emittable outputs")

    rename = {old: f"%rin{i}" for i, (old, _) in enumerate(inputs)}

    def rewrite(text: str) -> str:
        def sub(m: re.Match) -> str:
            tok = alias_map.get(m.group(1), m.group(1))
            return rename.get(tok, tok)
        return _SSA_TOKEN.sub(sub, text)

    body_lines = [l for op in ops for l in rewrite(op.raw).splitlines()]
    body_lines = _strip_sharding_lines(body_lines)
    inline_block = [rewrite(l) for l in inline_lines]
    args = ", ".join(f"%rin{i}: {_mlir_type(t)}"
                     for i, (_, t) in enumerate(inputs))
    ret_names = ", ".join(r for r, _ in outputs)
    ret_types = ", ".join(_mlir_type(t) for _, t in outputs)

    callee_raws = []
    for fn in _referenced_functions(raw_text, program, set()):
        raw = program.meta.get("func_raw", {}).get(fn)
        if raw is None:
            raise RegionEmitError(f"missing raw text for callee @{fn}")
        callee_raws.append(
            "\n".join(_strip_sharding_lines(raw.splitlines())))

    module = (
        f"module @{name} {{\n"
        + "\n".join(callee_raws)
        + ("\n" if callee_raws else "")
        + f"  func.func public @main({args}) -> ({ret_types}) {{\n"
        + "\n".join("    " + l for l in inline_block + body_lines)
        + f"\n    return {ret_names} : {ret_types}\n"
        + "  }\n}"
    )
    return module, [t for _, t in inputs]
