"""Linear split (paper §III-B(b), first algorithm).

Partitions the program into alternating communication and compute regions
following dependency (textual/execution) order.  All consecutive compute
ops between communication primitives form a single region — large regions,
minimal analysis overhead, maximal compiler scope for profiling estimators.

While loops are descended into only when their bodies contain collectives
(otherwise the whole loop is one compute op inside the current region);
body segments carry the loop trip count as ``repeat``.
"""
from __future__ import annotations

import itertools

from ..ir.graph import OpNode, Program, ZERO_COST_OPS
from .regions import ComputeRegion, Segment, finalize_region

_group_counter = itertools.count(1)

#: calls to :func:`linear_split` in this process — plan reuse means this
#: grows once per (workload, fidelity) per campaign, not once per job;
#: tests and benchmarks assert on it
SPLIT_CALLS = 0


def _has_collective(op: OpNode) -> bool:
    return any(o.is_collective and not o.is_async_done for o in op.walk())


def linear_split(program: Program, min_region_ops: int = 1) -> list[Segment]:
    global SPLIT_CALLS
    SPLIT_CALLS += 1
    segments: list[Segment] = []

    def flush(pending: list[OpNode], repeat: int, group: int) -> None:
        real = [op for op in pending
                if op.op not in ZERO_COST_OPS and not op.is_async_done]
        if not real:
            pending.clear()
            return
        region = finalize_region(ComputeRegion(ops=list(pending)), program)
        segments.append(Segment("COMP", region=region, repeat=repeat, group=group))
        pending.clear()

    def visit(ops: list[OpNode], repeat: int, group: int) -> None:
        from ..ir.collectives import comm_spec
        world = program.meta.get("num_partitions", 1)
        pending: list[OpNode] = []
        for op in ops:
            if op.op == "optimization_barrier":
                # explicit compiler-scope boundary: split without a COMM node
                flush(pending, repeat, group)
            elif op.is_collective and not op.is_async_done:
                flush(pending, repeat, group)
                segments.append(Segment(
                    "COMM", comm=comm_spec(op, world), repeat=repeat, group=group))
            elif op.op == "while" and _has_collective(op):
                flush(pending, repeat, group)
                body = op.regions[-1] if op.regions else []
                inner_group = next(_group_counter)
                visit(body, repeat * max(op.trip_count, 1), inner_group)
            else:
                pending.append(op)
        flush(pending, repeat, group)

    visit(program.entry, 1, 0)
    return segments
