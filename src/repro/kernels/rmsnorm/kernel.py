"""Fused RMSNorm as a Pallas TPU kernel.

Memory-bound op: one pass HBM->VMEM->HBM, the f32 mean-square reduction and
scale fused so x is read once (unfused XLA on raw exports reads it twice).
Grid over row blocks; the feature dimension stays whole in VMEM (d_model
<= 8192 * 4B = 32 KB/row, well within budget at 128-row blocks)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)                # [rows, d]
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_kernel(x: jax.Array, w: jax.Array, *, eps: float = 1e-5,
                   block_rows: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x: [R, D] (callers flatten leading dims); w: [D]."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    assert r % block_rows == 0, (r, block_rows)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, w)
