from .ops import *  # noqa
