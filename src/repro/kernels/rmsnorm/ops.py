"""jit'd fused-RMSNorm wrapper over arbitrary leading dims."""
from __future__ import annotations

from functools import partial

import jax

from .kernel import rmsnorm_kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    lead = x.shape[:-1]
    d = x.shape[-1]
    flat = x.reshape(-1, d)
    rows = flat.shape[0]
    block = 128
    while rows % block and block > 1:
        block //= 2
    out = rmsnorm_kernel(flat, w, eps=eps, block_rows=block,
                         interpret=_should_interpret())
    return out.reshape(*lead, d)
