# Pallas TPU kernels for the framework's compute hot spots.
# Each subpackage: kernel.py (pl.pallas_call + BlockSpec), ops.py (jit'd
# public wrapper, interpret=True off-TPU), ref.py (pure-jnp oracle).
