"""SSD intra-chunk dual form as a Pallas TPU kernel.

The Mamba2 SSD insight: within a chunk of length L, the SSM output is an
attention-like product  Y = (L ∘ (C Bᵀ)) · (dt·X)  plus a contribution from
the inbound state; both are dense matmuls — MXU work — while only the
O(S/L) inter-chunk state recurrence is sequential (left in jnp/lax.scan).

Grid: (batch, heads, chunks).  VMEM blocks per step:
  x (L×P), dt-weighted x (L×P), B/C (L×N), inbound state (P×N) →
  outputs y (L×P) and outbound chunk state (P×N).
L=256, P=64, N=128 → ~400 KB resident; MXU shapes 256×128×64 — aligned.

The host wrapper (ops.py) precomputes the cumulative decays (cheap
elementwise) and runs the inter-chunk scan; the kernel fuses the four
matmul-heavy contractions of the dual form.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, dtx_ref, b_ref, c_ref, dacs_ref, datot_ref, state_ref,
            y_ref, os_ref):
    dacs = dacs_ref[0, 0, :, 0].astype(jnp.float32)        # [L]
    datot = datot_ref[0, 0, 0].astype(jnp.float32)         # scalar
    b = b_ref[0, 0, :, 0, :].astype(jnp.float32)           # [L,N]
    c = c_ref[0, 0, :, 0, :].astype(jnp.float32)           # [L,N]
    dtx = dtx_ref[0, 0, :, 0, :].astype(jnp.float32)       # [L,P]
    state = state_ref[0, 0, 0].astype(jnp.float32)         # [P,N]

    # intra-chunk: scores = (C Bᵀ) ∘ L  where L[i,j] = exp(dacs_i - dacs_j)
    scores = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [L,L]
    l = dacs.shape[0]
    ii = jax.lax.broadcasted_iota(jnp.int32, (l, l), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (l, l), 1)
    decay = jnp.exp(dacs[:, None] - dacs[None, :])
    scores = jnp.where(ii >= jj, scores * decay, 0.0)
    y = jax.lax.dot_general(
        scores, dtx, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [L,P]

    # inbound-state contribution: (C · stateᵀ) scaled by decay-from-start
    y_off = jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)                # [L,P]
    y = y + y_off * jnp.exp(dacs)[:, None]
    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    # outbound chunk state: S_c = Σ_t exp(datot - dacs_t) · dtx_t ⊗ B_t
    w = jnp.exp(datot - dacs)[:, None]                     # [L,1]
    os_ref[0, 0, 0] = jax.lax.dot_general(
        dtx * w, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)                # [P,N]


def ssd_chunk_pallas(x, dtx, b_in, c_in, dacs, datot, states, *,
                     interpret: bool = False):
    """Batched over a (B, H, C) grid.

    x, dtx: [B,C,L,H,P]; b_in, c_in: [B,C,L,H,N]; dacs: [B,C,L,H];
    datot: [B,C,H]; states: [B,C,H,P,N] (inbound state per chunk).
    Returns (y [B,C,L,H,P] f32, chunk local contributions as in kernel)."""
    bsz, nc, l, h, p = x.shape
    n = b_in.shape[-1]

    grid = (bsz, h, nc)
    spec_lp = pl.BlockSpec((1, 1, l, 1, p),
                           lambda bb, hh, cc: (bb, cc, 0, hh, 0))
    spec_ln = pl.BlockSpec((1, 1, l, 1, n),
                           lambda bb, hh, cc: (bb, cc, 0, hh, 0))
    spec_l = pl.BlockSpec((1, 1, l, 1),
                          lambda bb, hh, cc: (bb, cc, 0, hh))
    spec_1 = pl.BlockSpec((1, 1, 1), lambda bb, hh, cc: (bb, cc, hh))
    spec_pn = pl.BlockSpec((1, 1, 1, p, n),
                           lambda bb, hh, cc: (bb, cc, hh, 0, 0))

    y, out_states = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[spec_lp, spec_lp, spec_ln, spec_ln, spec_l, spec_1,
                  spec_pn],
        out_specs=[spec_lp, spec_pn],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, nc, l, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, nc, h, p, n), jnp.float32),
        ],
        interpret=interpret,
    )(x, dtx, b_in, c_in, dacs, datot, states)
    return y, out_states
