"""Pure-jnp oracle for the SSD intra-chunk kernel: sequential recurrence.

y_t = C_t · h_t,   h_t = exp(dt_t · a) · h_{t-1} + dt_t · (B_t ⊗ x_t)

This is the exact (linear-time, sequential) SSM semantics; the chunked
dual form and the Pallas kernel must match it."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
            c_in: jax.Array, initial_state: jax.Array | None = None):
    """x: [B,S,H,P], dt: [B,S,H], a: [H], b_in/c_in: [B,S,G,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    hpg = h // g
    f32 = jnp.float32
    bh = jnp.repeat(b_in, hpg, axis=2).astype(f32)    # [B,S,H,N]
    ch = jnp.repeat(c_in, hpg, axis=2).astype(f32)
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    af = a.astype(f32)

    def step(state, inputs):
        xt, dtt, bt, ct = inputs                      # [B,H,P],[B,H],[B,H,N]
        decay = jnp.exp(dtt * af[None, :])            # [B,H]
        state = state * decay[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhpn", bt, xt * dtt[:, :, None])
        y = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, y

    init = (jnp.zeros((bsz, h, p, n), f32) if initial_state is None
            else initial_state.astype(f32))
    final, ys = jax.lax.scan(
        step, init,
        (xf.transpose(1, 0, 2, 3), dtf.transpose(1, 0, 2),
         bh.transpose(1, 0, 2, 3), ch.transpose(1, 0, 2, 3)))
    y = ys.transpose(1, 0, 2, 3)
    return y.astype(x.dtype), final
