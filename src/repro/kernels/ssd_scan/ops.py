"""Public SSD entry point: chunk the sequence, run the Pallas kernel for
the matmul-heavy intra-chunk work, lax.scan for the inter-chunk state."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_chunk_pallas


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
             c_in: jax.Array, *, chunk: int = 256,
             initial_state: jax.Array | None = None):
    """x: [B,S,H,P], dt: [B,S,H], a: [H], b_in/c_in: [B,S,G,N].

    Returns (y [B,S,H,P], final_state [B,H,P,N]).  Matches
    repro.kernels.ssd_scan.ref.ssd_ref."""
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    hpg = h // g
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    f32 = jnp.float32

    xc = x.reshape(bsz, nc, chunk, h, p)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    bh = jnp.repeat(b_in, hpg, axis=2).reshape(bsz, nc, chunk, h, n)
    ch = jnp.repeat(c_in, hpg, axis=2).reshape(bsz, nc, chunk, h, n)
    da = dtc * a.astype(f32)[None, None, None, :]          # [B,C,L,H]
    dacs = jnp.cumsum(da, axis=2)
    datot = dacs[:, :, -1]                                 # [B,C,H]
    dtx = (xc.astype(f32) * dtc[..., None]).astype(x.dtype)

    # inter-chunk state recurrence (sequential, O(S/L) steps)
    # S_c^in = exp(datot_{c-1}) S_{c-1}^in + S_{c-1}^local
    # we need local chunk states first; compute them with the same kernel by
    # passing zero inbound states, then scan, then re-run for outputs with
    # the true inbound states.  To avoid running the kernel twice, compute
    # local states analytically here (cheap einsum) and give the kernel the
    # resolved inbound states for the fused output pass.
    w = jnp.exp(datot[:, :, None, :] - dacs)               # [B,C,L,H]
    local_states = jnp.einsum(
        "bclhn,bclhp->bchpn", bh.astype(f32),
        dtx.astype(f32) * w[..., None])                    # [B,C,H,P,N]

    def scan_step(carry, inp):
        s_local, da_tot = inp
        new = carry * jnp.exp(da_tot)[:, :, None, None] + s_local
        return new, carry                                  # emit inbound

    init = (jnp.zeros((bsz, h, p, n), f32) if initial_state is None
            else initial_state.astype(f32))
    final, inbound = jax.lax.scan(
        scan_step, init,
        (local_states.transpose(1, 0, 2, 3, 4), datot.transpose(1, 0, 2)))
    inbound = inbound.transpose(1, 0, 2, 3, 4)             # [B,C,H,P,N]

    y, _ = ssd_chunk_pallas(xc, dtx, bh, ch, dacs, datot, inbound,
                            interpret=_should_interpret())
    return y.reshape(bsz, s, h, p).astype(x.dtype), final
