from .ops import *  # noqa
