from .ops import *  # noqa
