"""jit'd public wrapper: GQA-aware flash attention on [B, H, S, D]."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


def _should_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "window", "logit_cap",
                                   "q_offset", "block_q", "block_k"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    logit_cap: float = 0.0, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128) -> jax.Array:
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Skv, D] (GQA: Hq % Hkv == 0)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    if group > 1:
        k = jnp.repeat(k, group, axis=1)
        v = jnp.repeat(v, group, axis=1)
    out = flash_attention_kernel(
        q.reshape(b * hq, sq, d), k.reshape(b * hq, skv, d),
        v.reshape(b * hq, skv, d), causal=causal,
        window=int(window) if isinstance(window, int) else 0,
        logit_cap=logit_cap, q_offset=q_offset,
        block_q=block_q, block_k=block_k,
        interpret=_should_interpret())
    return out.reshape(b, hq, sq, d)
