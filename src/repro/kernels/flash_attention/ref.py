"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int = 0,
                  logit_cap: float = 0.0, q_offset: int = 0) -> jax.Array:
    """q: [B, H, Sq, D]; k, v: [B, H, Skv, D] -> [B, H, Sq, D]."""
    b, h, sq, d = q.shape
    skv = k.shape[2]
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if logit_cap > 0:
        scores = logit_cap * jnp.tanh(scores / logit_cap)
    q_idx = jnp.arange(sq) + q_offset
    k_idx = jnp.arange(skv)
    diff = q_idx[:, None] - k_idx[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    scores = jnp.where(mask[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)
