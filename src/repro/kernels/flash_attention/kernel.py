"""Flash attention as a Pallas TPU kernel.

TPU-native adaptation (not a CUDA port): the online-softmax recurrence is
blocked for VMEM — one (block_q × head_dim) query tile stays resident in
VMEM while (block_k × head_dim) key/value tiles stream HBM→VMEM; the two
matmuls per tile hit the MXU with 128-aligned shapes; running max / sum /
accumulator live in VMEM scratch across the K-grid iterations (TPU grids
execute sequentially over the innermost dimension, which is what makes the
scratch-carry pattern sound).

Grid: (batch·heads, Sq/block_q, Skv/block_k); the K dimension is innermost.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, block_q: int, block_k: int, causal: bool,
                  window: int, logit_cap: float, q_offset: int,
                  kv_steps: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # [bq, d]
    k = k_ref[0].astype(jnp.float32)                  # [bk, d]
    v = v_ref[0].astype(jnp.float32)                  # [bk, d]
    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)           # [bq, bk]
    if logit_cap > 0:
        scores = logit_cap * jnp.tanh(scores / logit_cap)

    q_idx = qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0) + q_offset
    k_idx = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    diff = q_idx - k_idx
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= diff >= 0
    if window > 0:
        mask &= diff < window
    scores = jnp.where(mask, scores, NEG_INF)

    m_prev = m_ref[...]                               # [bq]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, scores.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new[:, None])              # [bq, bk]
    l_new = l_prev * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(ki == kv_steps - 1)
    def _finish():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, window: int = 0,
                           logit_cap: float = 0.0, q_offset: int = 0,
                           block_q: int = 128, block_k: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q: [BH, Sq, D]; k, v: [BH, Skv, D] -> [BH, Sq, D]."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, skv)
    assert sq % block_q == 0 and skv % block_k == 0, (sq, skv)
    q_steps = sq // block_q
    kv_steps = skv // block_k
    grid = (bh, q_steps, kv_steps)
    scale = 1.0 / math.sqrt(d)

    kernel = functools.partial(
        _flash_kernel, scale=scale, block_q=block_q, block_k=block_k,
        causal=causal, window=window, logit_cap=logit_cap,
        q_offset=q_offset, kv_steps=kv_steps)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
            pltpu.VMEM((block_q, d), jnp.float32),    # accumulator
        ],
        interpret=interpret,
    )(q, k, v)
