"""qwen2.5-32b [dense]: 64L d_model=5120 40H (GQA kv=8) d_ff=27648
vocab=152064 — GQA, QKV bias [hf:Qwen]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-32b", family="dense", num_layers=64, d_model=5120,
    num_heads=40, num_kv_heads=8, d_ff=27648, vocab_size=152064,
    qkv_bias=True, rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen2.5-32b-smoke", family="dense", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256, qkv_bias=True,
    remat="none",
)
