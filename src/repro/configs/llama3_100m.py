"""Llama-3-style 100M variant (paper Fig 6/11 workload)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-100m", family="dense", num_layers=12, d_model=768,
    num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32768,
    rope_theta=500000.0, remat="none",
)
SMOKE = CONFIG.scaled(name="llama3-100m-smoke", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
