"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 + shared attention
block (32H kv=32) every 6 layers, ssm_state=64 [arXiv:2411.15242]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    hybrid_attn_every=6,
)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", num_layers=4, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                  chunk_size=32),
    hybrid_attn_every=2, remat="none",
)
