"""hubert-xlarge [audio]: encoder-only, 48L d_model=1280 16H d_ff=5120
vocab=504 (cluster targets) [arXiv:2106.07447].

The convolutional waveform frontend is a STUB: input_specs() provides
precomputed frame embeddings [B, S, d_model]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", num_layers=48, d_model=1280,
    num_heads=16, num_kv_heads=16, d_ff=5120, vocab_size=504,
    causal=False, frontend="stub", act="gelu",
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="audio", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
    causal=False, frontend="stub", act="gelu", remat="none",
)
