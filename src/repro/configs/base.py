"""Model / run configuration system.

One :class:`ModelConfig` describes any architecture in the assigned pool
(dense, MoE, SSM, hybrid, encoder-only, VLM backbone); one
:class:`ShapeConfig` describes a workload shape cell (train_4k, prefill_32k,
decode_32k, long_500k); one :class:`RunConfig` binds them to a mesh and
training hyperparameters.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    d_ff_expert: int = 2048
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V3 multi-head latent attention dims."""
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block dims."""
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    # attention features
    qkv_bias: bool = False
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    sliding_window: int = 0        # >0: SWA width (all layers)
    local_global_pattern: int = 0  # >0: alternate local/global every N layers
    causal: bool = True            # False -> encoder (bidirectional)
    rope_theta: float = 10000.0
    mrope_sections: tuple[int, ...] = ()   # Qwen2-VL M-RoPE (t, h, w) splits
    # substructures
    moe: MoEConfig | None = None
    mla: MLAConfig | None = None
    ssm: SSMConfig | None = None
    # hybrid (zamba2-style): 1 shared attention block every N ssm layers
    hybrid_attn_every: int = 0
    # norm / misc
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "silu"              # silu | gelu
    dtype: str = "bfloat16"
    # modality frontend: "none" means token ids; "stub" means the input is a
    # precomputed [B, S, d_model] embedding (audio frames / vision patches)
    frontend: str = "none"
    remat: str = "full"            # none | full (activation checkpointing)
    attn_impl: str = "chunked"     # dense | chunked | pallas
    attn_chunk: int = 1024
    scan_layers: bool = True       # False: python-unrolled layer stack
    layer_barriers: bool = False   # insert optimization_barrier between
    #                                layers (profiling-slicing boundaries)
    # --- perf knobs (EXPERIMENTS.md §Perf) ---
    loss_vocab_chunk: int = 0      # >0: stream CE over vocab chunks (no
    #                                [B,S,V] f32 logits materialization)
    moe_dispatch_sharding: bool = False  # sharding constraints on the MoE
    #                                dispatch path (keeps token-major
    #                                tensors on the data axis, expert
    #                                buffers on the model axis)
    moe_ep_shardmap: bool = False  # explicit expert-parallel dispatch via
    #                                shard_map (see mlp.moe_forward_ep)
    pad_heads: int = 0             # pad Q heads so (H+pad) divides the TP
    #                                degree; padded head outputs are masked
    #                                before W_o, so the math is EXACT and
    #                                pad-row gradients are identically zero

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def scaled(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # ---- analytical parameter counts (for 6·N·D model flops) ----
    def param_count(self) -> tuple[int, int]:
        """(total_params, active_params). Active differs for MoE."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        emb = V * d * (1 if self.tie_embeddings else 2)
        total = active = emb
        if self.family in ("ssm", "hybrid"):
            s = self.ssm or SSMConfig()
            di = s.d_inner(d)
            nh = s.n_heads(d)
            per = (d * (2 * di + 2 * s.n_groups * s.d_state + nh)  # in_proj
                   + s.d_conv * (di + 2 * s.n_groups * s.d_state)  # conv
                   + di * d                                        # out_proj
                   + 2 * nh + d)                                   # A, D, norm
            n_ssm = L
            attn_per = 0
            if self.family == "hybrid" and self.hybrid_attn_every:
                kvh = self.num_kv_heads
                attn_per = (d * self.num_heads * hd + 2 * d * kvh * hd
                            + self.num_heads * hd * d + d * self.d_ff * 3)
                total += attn_per  # shared block counted once
                active += attn_per
            total += n_ssm * per
            active += n_ssm * per
            return total, active
        kvh = self.num_kv_heads
        if self.mla is not None:
            m = self.mla
            qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
            attn = (d * m.q_lora_rank + m.q_lora_rank * self.num_heads * qk_hd
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.num_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.num_heads * m.v_head_dim * d)
        else:
            attn = (d * self.num_heads * hd + 2 * d * kvh * hd
                    + self.num_heads * hd * d)
        if self.moe is not None:
            mo = self.moe
            ff_dense = 3 * d * mo.d_ff_shared * mo.num_shared_experts
            ff_all = 3 * d * mo.d_ff_expert * mo.num_experts + ff_dense
            ff_active = 3 * d * mo.d_ff_expert * mo.top_k + ff_dense
            router = d * mo.num_experts
            total += L * (attn + ff_all + router + 2 * d)
            active += L * (attn + ff_active + router + 2 * d)
        else:
            ff = 3 * d * self.d_ff
            total += L * (attn + ff + 2 * d)
            active += L * (attn + ff + 2 * d)
        return total, active


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # "train" | "prefill" | "decode"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh_shape: tuple[int, ...] = (16, 16)
    mesh_axes: tuple[str, ...] = ("data", "model")
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    optimizer: str = "adamw"       # adamw | adafactor
    grad_clip: float = 1.0
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    microbatch: int = 0            # 0 = no gradient accumulation
    gradient_compression: bool = False
    seed: int = 0
    # long-context decode: shard the KV cache / SSM chunks along "data"
    sequence_sharded_cache: bool = False
