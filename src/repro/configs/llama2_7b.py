"""Llama-2 7B (paper Fig 9 scale-out workload, ATLAHS configuration)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense", num_layers=32, d_model=4096,
    num_heads=32, num_kv_heads=32, d_ff=11008, vocab_size=32000,
    rope_theta=10000.0,
)
SMOKE = CONFIG.scaled(name="llama2-7b-smoke", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
                      remat="none")
