"""qwen2-vl-7b [vlm]: 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064 — M-RoPE, dynamic resolution [arXiv:2409.12191].

The vision tower is a STUB: the backbone consumes token ids plus
3-stream M-RoPE positions (t/h/w); patch embeddings are precomputed."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b", family="vlm", num_layers=28, d_model=3584,
    num_heads=28, num_kv_heads=4, d_ff=18944, vocab_size=152064,
    qkv_bias=True, mrope_sections=(16, 24, 24), rope_theta=1000000.0,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
    qkv_bias=True, mrope_sections=(4, 2, 2), remat="none",
)
