"""Llama-3-style 500M variant (paper Fig 6/11 workload)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-500m", family="dense", num_layers=16, d_model=1536,
    num_heads=16, num_kv_heads=8, d_ff=4096, vocab_size=32768,
    rope_theta=500000.0, remat="none",
)
SMOKE = CONFIG.scaled(name="llama3-500m-smoke", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
