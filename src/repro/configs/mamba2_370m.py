"""mamba2-370m [ssm]: 48L d_model=1024, attn-free, ssm_state=128 — SSD
(state-space duality) [arXiv:2405.21060]."""
from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-370m", family="ssm", num_layers=48, d_model=1024,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=50280,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, n_groups=1,
                  chunk_size=256),
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", num_layers=2, d_model=64,
    num_heads=0, num_kv_heads=0, d_ff=0, vocab_size=256,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, n_groups=1,
                  chunk_size=32),
    tie_embeddings=True, remat="none",
)
