from .base import (MLAConfig, MoEConfig, ModelConfig, RunConfig, SSMConfig,
                   ShapeConfig, SHAPES, TRAIN_4K, PREFILL_32K, DECODE_32K,
                   LONG_500K)

__all__ = ["MLAConfig", "MoEConfig", "ModelConfig", "RunConfig", "SSMConfig",
           "ShapeConfig", "SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K"]
