"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8), 8 experts top-2
d_ff=16384, SWA 4096, vocab=32768 [arXiv:2401.04088]."""
from .base import MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe", num_layers=56, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=32768,
    sliding_window=4096,
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=16384,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="mixtral-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256,
    sliding_window=16,
    moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64),
    remat="none",
)
