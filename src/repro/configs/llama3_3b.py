"""Llama-3.2-3B-style variant (paper Fig 6/11 workload)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-3b", family="dense", num_layers=28, d_model=3072,
    num_heads=24, num_kv_heads=8, d_ff=8192, vocab_size=32768,
    rope_theta=500000.0, remat="none",
)
SMOKE = CONFIG.scaled(name="llama3-3b-smoke", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=256)
