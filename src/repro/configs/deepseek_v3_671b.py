"""deepseek-v3-671b [moe]: 61L d_model=7168, MLA, 1 shared + 256 routed
top-8 experts d_ff=2048, vocab=129280 [arXiv:2412.19437].

Deviations noted in DESIGN.md: all layers MoE (the real model's first 3
layers are dense); sigmoid top-k routing without the group-limited device
constraint; MTP head omitted."""
from .base import MLAConfig, MoEConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe", num_layers=61, d_model=7168,
    num_heads=128, num_kv_heads=128, d_ff=18432, vocab_size=129280,
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                  qk_rope_head_dim=64, v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_ff_expert=2048,
                  num_shared_experts=1, d_ff_shared=2048,
                  capacity_factor=1.25),
)

SMOKE = ModelConfig(
    name="deepseek-v3-smoke", family="moe", num_layers=2, d_model=64,
    num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=256,
    mla=MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                  qk_rope_head_dim=8, v_head_dim=16),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                  num_shared_experts=1, d_ff_shared=32),
    remat="none",
)
