"""Deterministic fault injection for robustness testing (test-only).

A *fault plan* is a small JSON document describing exactly where and
when the system should fail::

    {"seed": 7,
     "faults": [
       {"site": "campaign_row", "op": "kill", "at": 5, "worker": 0},
       {"site": "evaluate", "op": "error", "at": 3, "times": 1},
       {"site": "evaluate", "op": "hang", "at": [2, 6], "seconds": 120},
       {"site": "stream", "op": "reset", "at": 4},
       {"site": "cache_append", "op": "torn", "at": 2}]}

Sites (each keeps its own 1-based per-process call counter):

``evaluate``
    start of a campaign job's evaluate phase (``runner._execute``).
    Ops: ``error`` (raise :class:`FaultInjected`), ``hang``
    (sleep ``seconds``), ``kill`` (``os._exit(137)`` — a SIGKILL
    stand-in: no cleanup, no atexit, torn file state left as-is).
``campaign_row``
    after a campaign row is flushed to ``results.jsonl``/streamed.
    Ops: ``kill``, ``hang``, ``error``.
``stream``
    after a row is written to an NDJSON campaign response.  Op:
    ``reset`` (the server hard-closes the connection mid-stream).
``cache_append``
    after a cache batch is written but *before* the offset-index
    sidecar is maintained.  Ops: ``torn`` (truncate mid-record and
    skip the index append — the torn-writer crash the sidecar's
    coverage invariant exists for — then carry on), ``kill`` (truncate
    mid-record and die).

Matching knobs per fault: ``at`` (1-based counter value; a two-element
list is resolved to one value from the plan ``seed`` — deterministic
per plan), ``times`` (max fires, default 1), ``worker`` (only in the
process whose ``REPRO_FAULT_WORKER`` matches), ``generation`` (only in
the process whose ``REPRO_FAULT_GENERATION`` matches, default 0 — so a
restarted fleet worker, booted at generation 1, does *not* replay its
predecessor's faults), plus free-form context filters (``workload``,
``system``, ...) compared against the ``fire()`` call's keyword
context.

The plan travels through the environment (``REPRO_FAULT_PLAN`` holds a
path or inline JSON) so it crosses every process boundary we care
about: fleet supervisor -> daemon workers -> process-pool campaign
workers.  ``active()`` re-reads the environment when it changes, which
is what lets tests flip plans on and off with ``monkeypatch.setenv``.

Nothing here runs unless a plan is installed: the hot-path guard is a
single module-level boolean.
"""
from __future__ import annotations

import json
import os
import random
import threading
import time
from dataclasses import dataclass, field

ENV_PLAN = "REPRO_FAULT_PLAN"
ENV_WORKER = "REPRO_FAULT_WORKER"
ENV_GENERATION = "REPRO_FAULT_GENERATION"

SITES = ("evaluate", "campaign_row", "stream", "cache_append")
OPS = ("error", "hang", "kill", "reset", "torn")

#: exit status used by ``op: kill`` — matches the shell's SIGKILL
#: convention so supervisors can't tell it from the real thing.
KILL_STATUS = 137

#: bytes chopped off the final record by ``op: torn`` — enough to
#: leave invalid JSON with no trailing newline, the classic torn tail.
TORN_TAIL_BYTES = 7


class FaultInjected(RuntimeError):
    """Raised by ``op: error`` faults (and carried on error rows)."""


@dataclass
class Fault:
    site: str
    op: str
    at: int
    times: int = 1
    worker: int | None = None
    generation: int | None = 0
    seconds: float = 3600.0
    match: dict = field(default_factory=dict)
    fired: int = 0

    @classmethod
    def parse(cls, raw: dict, rng: random.Random) -> "Fault":
        raw = dict(raw)
        site = raw.pop("site", None)
        if site not in SITES:
            raise ValueError(
                f"fault plan: unknown site {site!r} (one of {SITES})")
        op = raw.pop("op", None)
        if op not in OPS:
            raise ValueError(
                f"fault plan: unknown op {op!r} (one of {OPS})")
        at = raw.pop("at", 1)
        if isinstance(at, (list, tuple)):
            if len(at) != 2:
                raise ValueError("fault plan: 'at' range must be [lo, hi]")
            at = rng.randint(int(at[0]), int(at[1]))
        gen = raw.pop("generation", 0)
        worker = raw.pop("worker", None)
        return cls(site=site, op=op, at=int(at),
                   times=int(raw.pop("times", 1)),
                   worker=None if worker is None else int(worker),
                   generation=None if gen is None else int(gen),
                   seconds=float(raw.pop("seconds", 3600.0)),
                   match=raw)


class FaultPlan:
    """A parsed plan: the fault list plus this process's identity."""

    def __init__(self, doc: dict, *, worker: int | None = None,
                 generation: int = 0):
        if not isinstance(doc, dict):
            raise ValueError("fault plan: top level must be an object")
        self.seed = int(doc.get("seed", 0))
        rng = random.Random(self.seed)
        self.faults = [Fault.parse(f, rng) for f in doc.get("faults", [])]
        self.worker = worker
        self.generation = generation
        self.counters: dict[str, int] = {}
        self.fired: list[dict] = []
        self._lock = threading.Lock()

    def fire(self, site: str, **ctx) -> Fault | None:
        """Advance ``site``'s counter; return the matching fault, if any."""
        with self._lock:
            n = self.counters.get(site, 0) + 1
            self.counters[site] = n
            for f in self.faults:
                if f.site != site or f.fired >= f.times or f.at != n:
                    continue
                if f.worker is not None and f.worker != self.worker:
                    continue
                if (f.generation is not None
                        and f.generation != self.generation):
                    continue
                if any(ctx.get(k) != v for k, v in f.match.items()):
                    continue
                f.fired += 1
                self.fired.append(
                    {"site": site, "op": f.op, "at": n, **ctx})
                return f
        return None

    def stats(self) -> dict:
        with self._lock:
            return {"counters": dict(self.counters),
                    "fired": [dict(f) for f in self.fired]}


# ---------------------------------------------------------------------------
# process-global injector, resolved lazily from the environment

ENABLED = False
_PLAN: FaultPlan | None = None
_ENV_SIG: tuple | None = ()  # () = never resolved; None-able 3-tuple after
_RESOLVE_LOCK = threading.Lock()


def _env_sig() -> tuple | None:
    raw = os.environ.get(ENV_PLAN)
    if not raw:
        return None
    return (raw, os.environ.get(ENV_WORKER), os.environ.get(ENV_GENERATION))


def _install_from_sig(sig: tuple | None) -> None:
    global ENABLED, _PLAN, _ENV_SIG
    if sig is None:
        _PLAN, ENABLED, _ENV_SIG = None, False, None
        _set_cache_hook(False)
        return
    raw, worker, gen = sig
    text = raw if raw.lstrip().startswith("{") else open(raw).read()
    plan = FaultPlan(json.loads(text),
                     worker=None if worker is None else int(worker),
                     generation=int(gen or 0))
    _PLAN, ENABLED, _ENV_SIG = plan, True, sig
    _set_cache_hook(any(f.site == "cache_append" for f in plan.faults))


def install(doc: dict | None, *, worker: int | None = None,
            generation: int = 0) -> FaultPlan | None:
    """Install a plan directly (in-process; tests).  ``None`` uninstalls."""
    global ENABLED, _PLAN, _ENV_SIG
    with _RESOLVE_LOCK:
        if doc is None:
            _PLAN, ENABLED, _ENV_SIG = None, False, _env_sig()
            _set_cache_hook(False)
            return None
        _PLAN = FaultPlan(doc, worker=worker, generation=generation)
        ENABLED, _ENV_SIG = True, _env_sig()
        _set_cache_hook(
            any(f.site == "cache_append" for f in _PLAN.faults))
        return _PLAN


def active() -> bool:
    """Cheap hot-path guard; re-resolves when the environment changed."""
    global _ENV_SIG
    sig = _env_sig()
    if sig != _ENV_SIG:
        with _RESOLVE_LOCK:
            if sig != _ENV_SIG:  # double-checked under the lock
                _install_from_sig(sig)
    return ENABLED


def plan() -> FaultPlan | None:
    active()
    return _PLAN


def stats() -> dict | None:
    p = plan()
    return p.stats() if p is not None else None


def fire(site: str, **ctx) -> Fault | None:
    """Count a pass through ``site``; return the matching fault or None.

    Callers with site-specific ops (``reset``, ``torn``) interpret the
    returned fault themselves; everything generic goes through
    :func:`trip`.
    """
    p = plan()
    return p.fire(site, **ctx) if p is not None else None


def trip(site: str, **ctx) -> Fault | None:
    """Fire ``site`` and carry out the generic ops.

    ``error`` raises :class:`FaultInjected`; ``hang`` sleeps the
    fault's ``seconds`` (relying on a supervisor deadline to cut it
    short); ``kill`` exits the process abruptly via ``os._exit`` —
    the closest in-process stand-in for SIGKILL (no cleanup handlers,
    buffers and locks dropped on the floor).  Other ops are returned
    to the caller.
    """
    f = fire(site, **ctx)
    if f is None:
        return None
    if f.op == "error":
        raise FaultInjected(
            f"injected fault: site={site} at={f.at} ctx={ctx}")
    if f.op == "hang":
        time.sleep(f.seconds)
        return f
    if f.op == "kill":
        os._exit(KILL_STATUS)
    return f


# ---------------------------------------------------------------------------
# cache_append wiring: a class-level hook on PersistentCache, installed
# only while a plan with cache_append faults is live, so the cache has
# zero fault-plan coupling on the normal path.

def _cache_append_hook(cache, f) -> bool:
    """Called by ``PersistentCache.put_many`` after the batch is flushed,
    before index maintenance.  Returns True to skip index maintenance
    (simulating a writer that died between the two)."""
    fault = fire("cache_append", path=cache.path)
    if fault is None:
        return False
    end = f.tell()
    torn = max(0, end - TORN_TAIL_BYTES)
    f.truncate(torn)
    if fault.op == "kill":
        os._exit(KILL_STATUS)
    # op == "torn": leave the torn tail for the next reader/writer to
    # heal, and bring this process's view in line with the file so it
    # keeps running (its in-memory entries still cover the lost batch).
    cache._offset = torn
    st = os.fstat(f.fileno())
    cache._stat = (st.st_ino, st.st_size, st.st_mtime_ns)
    return True


def _set_cache_hook(on: bool) -> None:
    from ..core.estimators.cache import PersistentCache
    # plain function, always reached via class attribute access (no
    # instance binding), so no staticmethod wrapper needed
    PersistentCache.fault_hook = _cache_append_hook if on else None
