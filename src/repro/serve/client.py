"""Thin stdlib client for the ``repro.serve`` prediction daemon.

Everything downstream of the daemon — the CI serve job, the campaign
CLI's ``--server`` mode, benchmarks, notebook what-ifs — talks through
:class:`ServeClient` so the wire format lives in exactly one place.
urllib only; no new dependencies.

Retry policy (the asymmetry is deliberate):

* **GETs** (``/healthz``, ``/stats``) are idempotent, so they retry on
  *any* transient transport error — connect refused, reset, timeout.
  Worst case a retry re-reads a counter snapshot.
* **POSTs** retry only on ``ConnectionRefusedError``: that is the one
  failure mode where the request provably never reached the daemon
  (the socket was never accepted), so a retry cannot double-execute.
  A reset or timeout mid-POST is ambiguous — the daemon may be running
  the campaign right now — and blind re-POSTing would double work and
  double-count every ``/stats`` counter.  Those surface as
  :class:`ServeError` for the caller (or a fleet supervisor, which can
  degrade instead).

Every request carries a socket ``timeout_s`` (so a wedged daemon can't
block the client forever) and forwards it as ``X-Repro-Timeout-S``,
which a fleet supervisor uses as the per-worker deadline budget; an
optional total ``deadline_s`` bounds the whole retry loop.  HTTP-level
errors are never retried; they surface as :class:`ServeError` with the
daemon's status code and error payload.
"""
from __future__ import annotations

import http.client
import json
import socket
import time
import urllib.error
import urllib.request

__all__ = ["ServeClient", "ServeError", "CampaignStream",
           "write_campaign_artifacts"]

#: header carrying the client's per-request budget through the fleet
#: supervisor to the worker talking to it
TIMEOUT_HEADER = "X-Repro-Timeout-S"


class ServeError(RuntimeError):
    """A request the daemon rejected (or a dead daemon).

    ``status`` is the HTTP status (0 when no response arrived at all);
    ``payload`` is the decoded JSON error body when there was one.
    """

    def __init__(self, message: str, *, status: int = 0,
                 payload: dict | None = None):
        super().__init__(message)
        self.status = status
        self.payload = payload or {}


class CampaignStream:
    """An in-flight streamed campaign: iterate rows as the daemon emits
    them; ``summary`` is populated once the stream's final line arrives
    (iterating to exhaustion guarantees it).  A mid-stream server error
    surfaces as :class:`ServeError` from the iterator, as does a broken
    transport (connection reset, timeout) — with ``rows_seen`` telling
    the caller how much of the grid it already holds, enough to resume
    via ``resume_rows``."""

    def __init__(self, resp):
        self._resp = resp
        self.summary: dict | None = None
        self.rows_seen = 0

    def __iter__(self):
        try:
            with self._resp:
                for raw in self._resp:
                    line = raw.strip()
                    if not line:
                        continue
                    obj = json.loads(line)
                    event = obj.get("event")
                    if event == "summary":
                        self.summary = obj["summary"]
                    elif event == "error":
                        raise ServeError(
                            obj.get("error", "campaign failed"),
                            status=500, payload=obj)
                    else:
                        self.rows_seen += 1
                        yield obj
        except (OSError, http.client.HTTPException, ValueError) as e:
            raise ServeError(
                f"campaign stream broke after {self.rows_seen} rows: "
                f"{type(e).__name__}: {e}", status=0) from e
        if self.summary is None:
            raise ServeError(
                f"campaign stream ended without a summary after "
                f"{self.rows_seen} rows (daemon died mid-stream?)",
                status=0)

    def collect(self) -> tuple[list[dict], dict | None]:
        """Drain the stream; returns (rows, summary)."""
        rows = list(self)
        return rows, self.summary


class ServeClient:
    """Client for one daemon URL (e.g. ``http://127.0.0.1:8733``).

    ``timeout_s`` is the per-request socket timeout (and the budget
    advertised to the fleet); ``deadline_s``, when set, caps the total
    time any single logical request may spend across retries."""

    def __init__(self, url: str, *, timeout_s: float = 120.0,
                 connect_retries: int = 5, backoff_s: float = 0.1,
                 deadline_s: float | None = None):
        self.url = url.rstrip("/")
        self.timeout_s = timeout_s
        self.connect_retries = connect_retries
        self.backoff_s = backoff_s
        self.deadline_s = deadline_s

    # ----------------------------- transport -----------------------------

    @staticmethod
    def _transient(e: Exception) -> bool:
        """A transport failure that may heal on retry (vs a sick daemon
        actively answering with errors, which won't)."""
        if isinstance(e, urllib.error.URLError):
            return ServeClient._transient(e.reason) if isinstance(
                e.reason, Exception) else True
        return isinstance(e, (ConnectionError, socket.timeout,
                              TimeoutError, http.client.HTTPException,
                              OSError))

    @staticmethod
    def _never_reached(e: Exception) -> bool:
        """True only when the request provably never reached the daemon
        (connect refused: the socket was never accepted), making a
        retry safe even for non-idempotent POSTs."""
        if isinstance(e, urllib.error.URLError):
            return isinstance(e.reason, ConnectionRefusedError)
        return isinstance(e, ConnectionRefusedError)

    def _request(self, method: str, path: str, body: dict | None = None,
                 *, stream: bool = False, timeout_s: float | None = None):
        data = None if body is None else json.dumps(body).encode()
        timeout = self.timeout_s if timeout_s is None else timeout_s
        headers = {TIMEOUT_HEADER: f"{timeout:g}"}
        if data:
            headers["Content-Type"] = "application/json"
        deadline = (None if self.deadline_s is None
                    else time.monotonic() + self.deadline_s)
        idempotent = method == "GET"
        last: Exception | None = None
        for attempt in range(self.connect_retries + 1):
            per_try = timeout
            if deadline is not None:
                per_try = min(per_try, deadline - time.monotonic())
                if per_try <= 0:
                    raise ServeError(
                        f"deadline ({self.deadline_s:g}s) exceeded "
                        f"before {method} {path} could complete",
                        status=0) from last
            req = urllib.request.Request(self.url + path, data=data,
                                         headers=headers, method=method)
            try:
                resp = urllib.request.urlopen(req, timeout=per_try)
                return resp if stream else json.loads(resp.read())
            except urllib.error.HTTPError as e:
                try:
                    payload = json.loads(e.read())
                except (ValueError, OSError):
                    payload = {}
                raise ServeError(
                    payload.get("error", f"HTTP {e.code} on {path}"),
                    status=e.code, payload=payload) from e
            except Exception as e:  # noqa: BLE001 — classified below
                last = e
                retryable = (self._transient(e) if idempotent
                             else self._never_reached(e))
                if not retryable or attempt >= self.connect_retries:
                    break
                time.sleep(self.backoff_s * (2 ** attempt))
        raise ServeError(f"cannot reach daemon at {self.url}: {last}",
                         status=0) from last

    # ----------------------------- endpoints -----------------------------

    def healthz(self, *, timeout_s: float | None = None) -> dict:
        return self._request("GET", "/healthz", timeout_s=timeout_s)

    def stats(self, *, timeout_s: float | None = None) -> dict:
        return self._request("GET", "/stats", timeout_s=timeout_s)

    def wait_ready(self, timeout_s: float = 30.0,
                   poll_s: float = 0.1) -> dict:
        """Block until the daemon answers ``/healthz`` (boot race helper
        for scripts that just spawned it)."""
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                return self.healthz()
            except ServeError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(poll_s)

    def predict(self, workload, *, system: str = "a100",
                estimator="roofline", topology="auto",
                slicer: str = "linear", fidelity: str | None = None,
                overlap: bool = False, straggler_factor: float = 1.0,
                compression: float = 1.0,
                timeout_s: float | None = None) -> dict:
        """One grid point; returns the result row.  ``workload`` is a
        preloaded name or a workload-spec dict carrying its own source;
        ``estimator``/``topology`` are kind names or spec dicts."""
        body = {"workload": workload, "system": system,
                "estimator": estimator, "topology": topology,
                "slicer": slicer, "overlap": overlap,
                "straggler_factor": straggler_factor,
                "compression": compression}
        if fidelity:
            body["fidelity"] = fidelity
        return self._request("POST", "/predict", body, timeout_s=timeout_s)

    def campaign(self, *, spec: dict | None = None,
                 spec_path: str | None = None, executor: str = "thread",
                 schedule: str = "locality",
                 max_workers: int | None = None,
                 resume_rows: list[dict] | None = None,
                 retries: int | None = None,
                 timeout_s: float | None = None) -> CampaignStream:
        """Run a campaign on the daemon; returns a :class:`CampaignStream`
        yielding result rows as jobs finish.  ``spec`` is an inline
        campaign dict; ``spec_path`` a spec file path *on the daemon's
        filesystem* (they are localhost peers).  ``resume_rows`` replays
        a partial prior run server-side (trusted rows are not
        re-streamed); ``retries`` re-runs evaluate failures."""
        body: dict = {"executor": executor, "schedule": schedule}
        if spec is not None:
            body["spec"] = spec
        if spec_path is not None:
            body["spec_path"] = spec_path
        if max_workers is not None:
            body["max_workers"] = max_workers
        if resume_rows is not None:
            body["resume_rows"] = resume_rows
        if retries is not None:
            body["retries"] = retries
        resp = self._request("POST", "/campaign", body, stream=True,
                             timeout_s=timeout_s)
        return CampaignStream(resp)

    def report(self, spec_path: str, *, check: bool = False,
               tolerance: float | None = None, executor: str = "thread",
               rows: list[dict] | None = None,
               timeout_s: float | None = None) -> dict:
        """Campaign + evaluation report (optionally golden-checked) in
        one round trip."""
        body: dict = {"spec_path": spec_path, "executor": executor}
        if check:
            body["check"] = True
        if tolerance is not None:
            body["tolerance"] = tolerance
        if rows is not None:
            body["rows"] = rows
        return self._request("POST", "/report", body, timeout_s=timeout_s)

    def search(self, *, spec: dict | None = None,
               spec_path: str | None = None, brute_force: bool = False,
               timeout_s: float | None = None) -> dict:
        """Multi-fidelity what-if search against the warm daemon;
        returns the frontier report (see ``docs/search.md``)."""
        body: dict = {}
        if spec is not None:
            body["spec"] = spec
        if spec_path is not None:
            body["spec_path"] = spec_path
        if brute_force:
            body["brute_force"] = True
        return self._request("POST", "/search", body, timeout_s=timeout_s)

    def reload(self, *, timeout_s: float | None = None) -> dict:
        """Replay the daemon's boot-time preloads against the specs'
        current on-disk contents (admin verb; fleets fan it out)."""
        return self._request("POST", "/reload", {}, timeout_s=timeout_s)

    def shutdown(self) -> dict:
        """Ask the daemon to drain and stop (graceful, like SIGTERM)."""
        return self._request("POST", "/shutdown", {})


def write_campaign_artifacts(rows: list[dict], summary: dict | None,
                             out_dir: str) -> dict[str, str]:
    """Materialize a streamed campaign into the exact artifact set a
    local ``run_campaign(out_dir=...)`` writes — ``results.jsonl``,
    ``results.csv``, ``summary.json`` — so downstream tooling (``report
    --results``, the CI golden diff) cannot tell a served campaign from
    a local one.  Returns the written paths."""
    import os

    from ..campaign.runner import _write_csv
    os.makedirs(out_dir, exist_ok=True)
    paths = {"jsonl": os.path.join(out_dir, "results.jsonl"),
             "csv": os.path.join(out_dir, "results.csv"),
             "summary": os.path.join(out_dir, "summary.json")}
    with open(paths["jsonl"], "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    _write_csv(rows, paths["csv"])
    with open(paths["summary"], "w") as f:
        json.dump(summary or {}, f, indent=2)
    return paths
