"""Thin stdlib client for the ``repro.serve`` prediction daemon.

Everything downstream of the daemon — the CI serve job, the campaign
CLI's ``--server`` mode, benchmarks, notebook what-ifs — talks through
:class:`ServeClient` so the wire format lives in exactly one place.
urllib only; no new dependencies.

Connection errors at *connect* time (daemon still booting, socket not
yet listening) are retried with bounded exponential backoff — nothing
has reached the server yet, so the retry is always safe.  HTTP-level
errors are never retried; they surface as :class:`ServeError` with the
daemon's status code and error payload.
"""
from __future__ import annotations

import json
import time
import urllib.error
import urllib.request

__all__ = ["ServeClient", "ServeError", "CampaignStream",
           "write_campaign_artifacts"]


class ServeError(RuntimeError):
    """A request the daemon rejected (or a dead daemon).

    ``status`` is the HTTP status (0 when no response arrived at all);
    ``payload`` is the decoded JSON error body when there was one.
    """

    def __init__(self, message: str, *, status: int = 0,
                 payload: dict | None = None):
        super().__init__(message)
        self.status = status
        self.payload = payload or {}


class CampaignStream:
    """An in-flight streamed campaign: iterate rows as the daemon emits
    them; ``summary`` is populated once the stream's final line arrives
    (iterating to exhaustion guarantees it).  A mid-stream server error
    surfaces as :class:`ServeError` from the iterator."""

    def __init__(self, resp):
        self._resp = resp
        self.summary: dict | None = None

    def __iter__(self):
        with self._resp:
            for raw in self._resp:
                line = raw.strip()
                if not line:
                    continue
                obj = json.loads(line)
                event = obj.get("event")
                if event == "summary":
                    self.summary = obj["summary"]
                elif event == "error":
                    raise ServeError(obj.get("error", "campaign failed"),
                                     status=500, payload=obj)
                else:
                    yield obj

    def collect(self) -> tuple[list[dict], dict | None]:
        """Drain the stream; returns (rows, summary)."""
        rows = list(self)
        return rows, self.summary


class ServeClient:
    """Client for one daemon URL (e.g. ``http://127.0.0.1:8733``)."""

    def __init__(self, url: str, *, timeout_s: float = 120.0,
                 connect_retries: int = 5, backoff_s: float = 0.1):
        self.url = url.rstrip("/")
        self.timeout_s = timeout_s
        self.connect_retries = connect_retries
        self.backoff_s = backoff_s

    # ----------------------------- transport -----------------------------

    def _request(self, method: str, path: str, body: dict | None = None,
                 *, stream: bool = False):
        data = None if body is None else json.dumps(body).encode()
        headers = {"Content-Type": "application/json"} if data else {}
        last: Exception | None = None
        for attempt in range(self.connect_retries + 1):
            req = urllib.request.Request(self.url + path, data=data,
                                         headers=headers, method=method)
            try:
                resp = urllib.request.urlopen(req, timeout=self.timeout_s)
                return resp if stream else json.loads(resp.read())
            except urllib.error.HTTPError as e:
                try:
                    payload = json.loads(e.read())
                except (ValueError, OSError):
                    payload = {}
                raise ServeError(
                    payload.get("error", f"HTTP {e.code} on {path}"),
                    status=e.code, payload=payload) from e
            except urllib.error.URLError as e:
                # retry only failures to *connect* — the request never
                # reached the daemon, so a retry cannot double-execute
                last = e
                if not isinstance(e.reason, ConnectionRefusedError):
                    break
                if attempt < self.connect_retries:
                    time.sleep(self.backoff_s * (2 ** attempt))
        raise ServeError(f"cannot reach daemon at {self.url}: {last}",
                         status=0) from last

    # ----------------------------- endpoints -----------------------------

    def healthz(self) -> dict:
        return self._request("GET", "/healthz")

    def stats(self) -> dict:
        return self._request("GET", "/stats")

    def wait_ready(self, timeout_s: float = 30.0,
                   poll_s: float = 0.1) -> dict:
        """Block until the daemon answers ``/healthz`` (boot race helper
        for scripts that just spawned it)."""
        deadline = time.monotonic() + timeout_s
        while True:
            try:
                return self.healthz()
            except ServeError:
                if time.monotonic() >= deadline:
                    raise
                time.sleep(poll_s)

    def predict(self, workload, *, system: str = "a100",
                estimator="roofline", topology="auto",
                slicer: str = "linear", fidelity: str | None = None,
                overlap: bool = False, straggler_factor: float = 1.0,
                compression: float = 1.0) -> dict:
        """One grid point; returns the result row.  ``workload`` is a
        preloaded name or a workload-spec dict carrying its own source;
        ``estimator``/``topology`` are kind names or spec dicts."""
        body = {"workload": workload, "system": system,
                "estimator": estimator, "topology": topology,
                "slicer": slicer, "overlap": overlap,
                "straggler_factor": straggler_factor,
                "compression": compression}
        if fidelity:
            body["fidelity"] = fidelity
        return self._request("POST", "/predict", body)

    def campaign(self, *, spec: dict | None = None,
                 spec_path: str | None = None, executor: str = "thread",
                 schedule: str = "locality",
                 max_workers: int | None = None) -> CampaignStream:
        """Run a campaign on the daemon; returns a :class:`CampaignStream`
        yielding result rows as jobs finish.  ``spec`` is an inline
        campaign dict; ``spec_path`` a spec file path *on the daemon's
        filesystem* (they are localhost peers)."""
        body: dict = {"executor": executor, "schedule": schedule}
        if spec is not None:
            body["spec"] = spec
        if spec_path is not None:
            body["spec_path"] = spec_path
        if max_workers is not None:
            body["max_workers"] = max_workers
        resp = self._request("POST", "/campaign", body, stream=True)
        return CampaignStream(resp)

    def report(self, spec_path: str, *, check: bool = False,
               tolerance: float | None = None, executor: str = "thread",
               rows: list[dict] | None = None) -> dict:
        """Campaign + evaluation report (optionally golden-checked) in
        one round trip."""
        body: dict = {"spec_path": spec_path, "executor": executor}
        if check:
            body["check"] = True
        if tolerance is not None:
            body["tolerance"] = tolerance
        if rows is not None:
            body["rows"] = rows
        return self._request("POST", "/report", body)

    def shutdown(self) -> dict:
        """Ask the daemon to drain and stop (graceful, like SIGTERM)."""
        return self._request("POST", "/shutdown", {})


def write_campaign_artifacts(rows: list[dict], summary: dict | None,
                             out_dir: str) -> dict[str, str]:
    """Materialize a streamed campaign into the exact artifact set a
    local ``run_campaign(out_dir=...)`` writes — ``results.jsonl``,
    ``results.csv``, ``summary.json`` — so downstream tooling (``report
    --results``, the CI golden diff) cannot tell a served campaign from
    a local one.  Returns the written paths."""
    import os

    from ..campaign.runner import _write_csv
    os.makedirs(out_dir, exist_ok=True)
    paths = {"jsonl": os.path.join(out_dir, "results.jsonl"),
             "csv": os.path.join(out_dir, "results.csv"),
             "summary": os.path.join(out_dir, "summary.json")}
    with open(paths["jsonl"], "w") as f:
        for row in rows:
            f.write(json.dumps(row) + "\n")
    _write_csv(rows, paths["csv"])
    with open(paths["summary"], "w") as f:
        json.dump(summary or {}, f, indent=2)
    return paths
