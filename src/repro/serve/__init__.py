"""Serving: the warm prediction daemon + the decode-loop workload.

Two unrelated-but-cohabiting halves:

* **prediction-as-a-service** — :mod:`repro.serve.server` (the
  long-lived HTTP daemon holding one warm :class:`repro.api.Session`)
  and :mod:`repro.serve.client` (the stdlib thin client everything
  downstream — CI, benchmarks, the campaign CLI's ``--server`` mode —
  talks through).  Start one with ``python -m repro.serve``; scale it
  to a supervised worker fleet with ``--workers N``
  (:mod:`repro.serve.fleet`) and chaos-test it with seeded fault plans
  (:mod:`repro.serve.faults`).  See ``docs/serving.md`` and
  ``docs/robustness.md``.
* **decode-loop workloads** — :mod:`repro.serve.decode`'s batched
  autoregressive serving step (requires jax).

Imports are lazy (PEP 562): the daemon and client are stdlib-weight and
must import without jax; pulling ``greedy_decode`` & co. loads jax only
then.
"""
from __future__ import annotations

_DECODE = ("ServeResult", "greedy_decode", "make_serve_step")
_SERVER = ("PredictionService", "PredictionServer")
_CLIENT = ("ServeClient", "ServeError", "CampaignStream",
           "write_campaign_artifacts")
_FLEET = ("FleetSupervisor", "route_index", "request_class")

__all__ = [*_DECODE, *_SERVER, *_CLIENT, *_FLEET]


def __getattr__(name: str):
    if name in _DECODE:
        from . import decode
        return getattr(decode, name)
    if name in _SERVER:
        from . import server
        return getattr(server, name)
    if name in _CLIENT:
        from . import client
        return getattr(client, name)
    if name in _FLEET:
        from . import fleet
        return getattr(fleet, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
