from .decode import ServeResult, greedy_decode, make_serve_step

__all__ = ["ServeResult", "greedy_decode", "make_serve_step"]
