"""Serving: the warm prediction daemon + the decode-loop workload.

Two unrelated-but-cohabiting halves:

* **prediction-as-a-service** — :mod:`repro.serve.server` (the
  long-lived HTTP daemon holding one warm :class:`repro.api.Session`)
  and :mod:`repro.serve.client` (the stdlib thin client everything
  downstream — CI, benchmarks, the campaign CLI's ``--server`` mode —
  talks through).  Start one with ``python -m repro.serve``; see
  ``docs/serving.md``.
* **decode-loop workloads** — :mod:`repro.serve.decode`'s batched
  autoregressive serving step (requires jax).

Imports are lazy (PEP 562): the daemon and client are stdlib-weight and
must import without jax; pulling ``greedy_decode`` & co. loads jax only
then.
"""
from __future__ import annotations

_DECODE = ("ServeResult", "greedy_decode", "make_serve_step")
_SERVER = ("PredictionService", "PredictionServer")
_CLIENT = ("ServeClient", "ServeError", "write_campaign_artifacts")

__all__ = [*_DECODE, *_SERVER, *_CLIENT]


def __getattr__(name: str):
    if name in _DECODE:
        from . import decode
        return getattr(decode, name)
    if name in _SERVER:
        from . import server
        return getattr(server, name)
    if name in _CLIENT:
        from . import client
        return getattr(client, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
