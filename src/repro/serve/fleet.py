"""Supervised worker fleet behind one listener.

``python -m repro.serve --workers N`` boots a :class:`FleetSupervisor`
instead of a single daemon: N worker processes (each a plain
``python -m repro.serve --port 0`` on an ephemeral port) share the one
file-locked (H, C, R) store, and the supervisor's front listener proxies
every request to a worker chosen by :func:`route_index` — a stable hash
of the request's *cache class*, the observable projection of the
locality scheduler's chain key (:meth:`JobSpec.cache_group` is
``(regions, system, estimator)``; at the HTTP layer the regions are not
known yet, so the fleet routes on ``(workload, system, estimator
kind)``).  Same class -> same worker -> that worker's in-memory plan
store and coalescing window stay warm, and two workers never race the
same cold keyset.

Failure handling, in order of escalation:

* **crashed worker** — a monitor thread (and any request that trips
  over the corpse) respawns it with exponential backoff and a bumped
  *generation* (``REPRO_FAULT_GENERATION``: restarted workers do not
  replay generation-0 fault plans).
* **hung worker** — every proxied request carries the client's
  ``X-Repro-Timeout-S`` budget as its socket timeout; a worker that
  blows the budget is killed outright and the request re-dispatched to
  the next worker (predictions are pure functions of the request
  against a shared store, so re-execution is safe and mostly warm).
* **mid-stream campaign death** — the supervisor buffers every row it
  has already forwarded; on a broken stream it re-POSTs the campaign to
  another worker with those rows as ``resume_rows``, so the client's
  stream continues where it left off and at most the unflushed rows are
  recomputed.
* **circuit breaker** — after ``breaker_threshold`` *consecutive*
  worker deaths on one request class, the class is degraded for
  ``breaker_cooldown_s``: ``/predict`` answers locally from the warm
  store via the analytical (``roofline``) estimator with
  ``degraded: true`` instead of 5xx-ing or killing more workers.

``/stats`` aggregates per-worker stats plus fleet counters (restarts,
deaths, redispatches, degraded answers, breaker state) that
``tools/bench_check.py`` pins in CI.  See ``docs/robustness.md``.
"""
from __future__ import annotations

import hashlib
import json
import os
import select
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import urlsplit

from .client import TIMEOUT_HEADER

__all__ = ["FleetSupervisor", "WorkerHandle", "route_index",
           "request_class"]


# ------------------------------ routing ------------------------------

def route_index(class_key, n: int) -> int:
    """Worker index for a request class — pure and stable across
    processes (``tools/chaos_smoke.py`` imports this to aim its fault
    plan at the worker that will actually serve the campaign)."""
    blob = json.dumps(class_key, sort_keys=True, default=str).encode()
    h = hashlib.sha256(blob).digest()
    return int.from_bytes(h[:8], "big") % max(1, n)


def request_class(path: str, body: dict) -> tuple:
    """The cache class a request belongs to: requests in one class share
    warm state, so they route to one worker and trip one breaker."""
    if path == "/predict":
        w = body.get("workload")
        name = w.get("name") if isinstance(w, dict) else w
        e = body.get("estimator", "roofline")
        kind = e.get("kind") if isinstance(e, dict) else e
        return ("predict", str(name), str(body.get("system", "a100")),
                str(kind))
    if path in ("/campaign", "/report", "/search"):
        spec = body.get("spec")
        name = (spec.get("name") if isinstance(spec, dict)
                else body.get("spec_path"))
        return (path.lstrip("/"), str(name))
    return (path.lstrip("/"),)


# ------------------------------ workers ------------------------------

class WorkerHandle:
    """One live worker process: its subprocess, scraped URL, and
    fault-plan generation."""

    def __init__(self, idx: int, generation: int,
                 proc: subprocess.Popen, url: str):
        self.idx = idx
        self.generation = generation
        self.proc = proc
        self.url = url
        self.started_at = time.monotonic()

    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        if self.proc.poll() is None:
            self.proc.kill()
        self.proc.wait()


class _Breaker:
    """Per-request-class circuit breaker: ``threshold`` consecutive
    worker deaths open it for ``cooldown_s``; any success closes it."""

    def __init__(self, threshold: int, cooldown_s: float):
        self.threshold = threshold
        self.cooldown_s = cooldown_s
        self._lock = threading.Lock()
        self._consec: dict[tuple, int] = {}
        self._open_until: dict[tuple, float] = {}

    def record_death(self, cls: tuple) -> bool:
        """Count a death against ``cls``; True if the breaker opened."""
        with self._lock:
            n = self._consec.get(cls, 0) + 1
            self._consec[cls] = n
            if n >= self.threshold:
                self._open_until[cls] = time.monotonic() + self.cooldown_s
                return True
            return False

    def record_success(self, cls: tuple) -> None:
        with self._lock:
            self._consec.pop(cls, None)
            self._open_until.pop(cls, None)

    def is_open(self, cls: tuple) -> bool:
        with self._lock:
            until = self._open_until.get(cls)
            if until is None:
                return False
            if time.monotonic() >= until:    # cooldown over: close, reset
                del self._open_until[cls]
                self._consec.pop(cls, None)
                return False
            return True

    def open_classes(self) -> list[list]:
        with self._lock:
            now = time.monotonic()
            return [list(c) for c, t in self._open_until.items() if t > now]


class FleetSupervisor:
    """N supervised ``repro.serve`` workers behind one proxy listener.

    The supervisor owns no session of its own until a breaker opens —
    the degraded path lazily builds one local
    :class:`~repro.serve.server.PredictionService` over the same cache
    path, so degraded answers still read and extend the shared warm
    store.
    """

    def __init__(self, *, workers: int = 2, cache_path: str | None = None,
                 systems: tuple | list = (), preload: tuple | list = (),
                 host: str = "127.0.0.1", port: int = 0,
                 fault_plan: str | None = None,
                 default_timeout_s: float = 120.0,
                 backoff_s: float = 0.25, backoff_max_s: float = 5.0,
                 breaker_threshold: int = 3,
                 breaker_cooldown_s: float = 30.0,
                 boot_timeout_s: float = 60.0,
                 redispatch_limit: int = 2, verbose: bool = False):
        if workers < 1:
            raise ValueError("a fleet needs at least 1 worker")
        self.n = workers
        self.cache_path = cache_path
        self.systems = tuple(systems)
        self.preload = tuple(preload)
        self.fault_plan = fault_plan
        self.default_timeout_s = default_timeout_s
        self.backoff_s = backoff_s
        self.backoff_max_s = backoff_max_s
        self.boot_timeout_s = boot_timeout_s
        self.redispatch_limit = redispatch_limit
        self.verbose = verbose
        self.draining = False

        self._workers: list[WorkerHandle | None] = [None] * workers
        self._slot_locks = [threading.Lock() for _ in range(workers)]
        self._consec_deaths = [0] * workers
        self._breaker = _Breaker(breaker_threshold, breaker_cooldown_s)
        self._lock = threading.Lock()
        self._counters = {"restarts": 0, "worker_deaths": 0,
                          "redispatches": 0, "degraded": 0,
                          "hung_kills": 0, "reloads": 0}
        self._local_service = None    # lazy: only built when degrading
        self._monitor: threading.Thread | None = None
        self._thread: threading.Thread | None = None
        self.stopped = threading.Event()
        self.httpd = ThreadingHTTPServer((host, port), _make_handler(self))
        self.httpd.daemon_threads = True

    # ----------------------------- lifecycle -----------------------------

    @property
    def url(self) -> str:
        host, port = self.httpd.server_address[:2]
        return f"http://{host}:{port}"

    def start(self) -> "FleetSupervisor":
        """Boot every worker, then serve the front listener on a
        background thread (tests); raises if any worker fails to boot."""
        for idx in range(self.n):
            self._workers[idx] = self._spawn(idx, generation=0)
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         name="repro-fleet-monitor",
                                         daemon=True)
        self._monitor.start()
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="repro-fleet", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """CLI mode: boot workers and serve on the calling thread."""
        for idx in range(self.n):
            self._workers[idx] = self._spawn(idx, generation=0)
        self._monitor = threading.Thread(target=self._monitor_loop,
                                         name="repro-fleet-monitor",
                                         daemon=True)
        self._monitor.start()
        try:
            self.httpd.serve_forever(poll_interval=0.2)
        finally:
            self.stopped.set()

    def install_signal_handlers(self) -> None:
        import signal

        def _drain(signum, frame):  # noqa: ARG001
            threading.Thread(target=self.drain, daemon=True,
                             name="repro-fleet-drain").start()
        signal.signal(signal.SIGTERM, _drain)
        signal.signal(signal.SIGINT, _drain)

    def drain(self, timeout_s: float = 30.0) -> None:
        """Stop accepting work, drain every worker, stop the listener."""
        self.draining = True
        deadline = time.monotonic() + timeout_s
        for w in self._workers:
            if w is None or not w.alive():
                continue
            try:
                req = urllib.request.Request(w.url + "/shutdown", data=b"{}",
                                             method="POST")
                urllib.request.urlopen(req, timeout=5.0).read()
            except OSError:
                pass
        for w in self._workers:
            if w is None:
                continue
            try:
                w.proc.wait(timeout=max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                w.kill()
        self.httpd.shutdown()
        self.httpd.server_close()
        self.stopped.set()

    # --------------------------- worker spawning ---------------------------

    def _spawn(self, idx: int, generation: int) -> WorkerHandle:
        cmd = [sys.executable, "-m", "repro.serve", "--port", "0"]
        if self.cache_path:
            cmd += ["--cache", self.cache_path]
        for s in self.systems:
            cmd += ["--systems", s]
        for p in self.preload:
            cmd += ["--preload", p]
        env = dict(os.environ)
        env["REPRO_FAULT_WORKER"] = str(idx)
        env["REPRO_FAULT_GENERATION"] = str(generation)
        if self.fault_plan:
            env["REPRO_FAULT_PLAN"] = self.fault_plan
        else:
            env.pop("REPRO_FAULT_PLAN", None)
        proc = subprocess.Popen(
            cmd, env=env, stdout=subprocess.PIPE,
            stderr=None if self.verbose else subprocess.DEVNULL)
        try:
            url = self._scrape_url(proc)
        except Exception:
            if proc.poll() is None:
                proc.kill()
            proc.wait()
            raise
        if self.verbose:
            print(f"fleet: worker {idx} gen {generation} up at {url} "
                  f"(pid {proc.pid})", file=sys.stderr)
        return WorkerHandle(idx, generation, proc, url)

    def _scrape_url(self, proc: subprocess.Popen) -> str:
        """First stdout line is machine-readable: ``{"url": ..., "pid":
        ...}`` — read it with a deadline so a worker that dies at import
        time fails the boot instead of hanging it."""
        deadline = time.monotonic() + self.boot_timeout_s
        fd = proc.stdout.fileno()
        buf = b""
        while b"\n" not in buf:
            if proc.poll() is not None:
                raise RuntimeError(
                    f"worker exited with status {proc.returncode} "
                    "before printing its URL")
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                raise TimeoutError(
                    f"worker did not print its URL within "
                    f"{self.boot_timeout_s:g}s")
            ready, _, _ = select.select([fd], [], [], min(remaining, 0.2))
            if ready:
                chunk = os.read(fd, 4096)
                if not chunk:
                    raise RuntimeError("worker closed stdout before "
                                       "printing its URL")
                buf += chunk
        line = buf.split(b"\n", 1)[0]
        return json.loads(line)["url"]

    def _ensure(self, idx: int) -> WorkerHandle:
        """The live handle for slot ``idx``, restarting a corpse."""
        w = self._workers[idx]
        if w is not None and w.alive():
            return w
        return self._restart(idx, w, reason="found dead")

    def _restart(self, idx: int, dead: WorkerHandle | None,
                 reason: str) -> WorkerHandle:
        """Replace slot ``idx``'s worker (exponential backoff, bumped
        generation).  Idempotent: if another thread already replaced
        ``dead``, the replacement is returned untouched."""
        with self._slot_locks[idx]:
            cur = self._workers[idx]
            if cur is not None and cur is not dead and cur.alive():
                return cur
            gen = (cur.generation if cur is not None else 0) + 1
            if cur is not None:
                cur.kill()
            with self._lock:
                self._counters["worker_deaths"] += 1
                self._consec_deaths[idx] += 1
                n_deaths = self._consec_deaths[idx]
            backoff = min(self.backoff_s * (2 ** (n_deaths - 1)),
                          self.backoff_max_s)
            if self.verbose:
                print(f"fleet: restarting worker {idx} ({reason}), "
                      f"gen {gen}, backoff {backoff:.2f}s",
                      file=sys.stderr)
            time.sleep(backoff)
            new = self._spawn(idx, generation=gen)
            self._workers[idx] = new
            with self._lock:
                self._counters["restarts"] += 1
            return new

    def _monitor_loop(self) -> None:
        """Respawn crashed workers even when no request trips over them."""
        while not self.stopped.is_set():
            if not self.draining:
                for idx in range(self.n):
                    w = self._workers[idx]
                    if w is not None and not w.alive():
                        try:
                            self._restart(idx, w, reason="monitor")
                        except Exception:  # noqa: BLE001 — keep watching
                            pass
            self.stopped.wait(0.2)

    def _mark_success(self, idx: int, cls: tuple) -> None:
        with self._lock:
            self._consec_deaths[idx] = 0
        self._breaker.record_success(cls)

    # ----------------------------- degraded -----------------------------

    def _degraded_service(self):
        """Lazy local service over the same store (breaker-open path)."""
        with self._lock:
            if self._local_service is None:
                from .server import PredictionService
                svc = PredictionService(cache_path=self.cache_path,
                                        systems=self.systems)
                for spec in self.preload:
                    svc.preload(spec)
                self._local_service = svc
            return self._local_service

    def degraded_predict(self, body: dict, reason: str) -> dict:
        """Answer a ``/predict`` locally with the analytical estimator.

        The roofline model is closed-form — it cannot hang or crash the
        way a worker just did — and it reads/writes the shared warm
        store, so repeated degraded answers for one class cost one cold
        evaluation.  The row is tagged ``degraded: true`` (plus the
        originally requested estimator when it was substituted) so no
        caller can mistake it for the real thing."""
        svc = self._degraded_service()
        body = dict(body)
        e = body.get("estimator", "roofline")
        kind = e.get("kind") if isinstance(e, dict) else e
        if kind != "roofline":
            body["estimator"] = "roofline"
        row = svc.predict(body)
        row["degraded"] = True
        row["degraded_reason"] = reason
        if kind != "roofline":
            row["requested_estimator"] = str(kind)
        with self._lock:
            self._counters["degraded"] += 1
        return row

    # ------------------------------ admin ------------------------------

    def reload_workers(self) -> dict:
        """Fan ``POST /reload`` out to every live worker — each replays
        its boot-time preloads against the specs' current on-disk
        contents.  In-flight requests are untouched (reload is just one
        more concurrent request per worker; the per-worker plan store
        only grows or swaps whole entries).  The local degraded-mode
        fallback service, when it has been instantiated, replays its
        preloads too — otherwise a breaker-open fleet would keep serving
        the stale specs while reporting a successful reload."""
        reports = []
        for idx in range(self.n):
            w = self._workers[idx]
            if w is None or not w.alive():
                reports.append({"worker": idx, "alive": False})
                continue
            try:
                req = urllib.request.Request(
                    w.url + "/reload", data=b"{}", method="POST",
                    headers={"Content-Type": "application/json"})
                rep = json.loads(
                    urllib.request.urlopen(req, timeout=30.0).read())
            except (OSError, ValueError) as e:
                reports.append({"worker": idx, "alive": w.alive(),
                                "error": f"{type(e).__name__}: {e}"})
                continue
            rep["worker"] = idx
            reports.append(rep)
        with self._lock:
            svc = self._local_service
        if svc is not None:
            try:
                rep = svc.reload()
            except Exception as e:  # noqa: BLE001 — report, don't crash
                reports.append({"worker": "local-fallback",
                                "error": f"{type(e).__name__}: {e}"})
            else:
                rep["worker"] = "local-fallback"
                reports.append(rep)
        with self._lock:
            self._counters["reloads"] += 1
        return {"reloaded": sum(1 for r in reports if "plans_built" in r),
                "workers": reports}

    # ------------------------------ stats ------------------------------

    def stats(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
        workers = []
        totals = {"predict_served": 0, "campaign_served": 0,
                  "campaign_rows": 0, "search_served": 0,
                  "duplicate_cold_misses": 0,
                  "resumed_rows": 0, "retried_rows": 0}
        for idx in range(self.n):
            w = self._workers[idx]
            if w is None or not w.alive():
                workers.append({"worker": idx, "alive": False})
                continue
            try:
                raw = urllib.request.urlopen(w.url + "/stats",
                                             timeout=10.0).read()
                st = json.loads(raw)
            except (OSError, ValueError) as e:
                workers.append({"worker": idx, "alive": w.alive(),
                                "error": f"{type(e).__name__}: {e}"})
                continue
            st.update({"worker": idx, "alive": True,
                       "generation": w.generation, "pid": w.proc.pid})
            workers.append(st)
            totals["predict_served"] += st["predict"]["served"]
            totals["campaign_served"] += st["campaign"]["served"]
            totals["campaign_rows"] += st["campaign"]["rows"]
            totals["search_served"] += st.get("search", {}).get("served", 0)
            totals["duplicate_cold_misses"] += (
                st["predict"]["duplicate_cold_misses"]
                + st["campaign"]["duplicate_cold_misses"])
            totals["resumed_rows"] += st["campaign"]["resumed_rows"]
            totals["retried_rows"] += st["campaign"]["retried_rows"]
        return {
            "fleet": {
                "workers": self.n,
                "draining": self.draining,
                **counters,
                "breaker_open": self._breaker.open_classes(),
                "generations": [
                    (w.generation if w is not None else None)
                    for w in self._workers],
            },
            "workers": workers,
            "totals": totals,
        }

    def healthz(self) -> dict:
        alive = sum(1 for w in self._workers
                    if w is not None and w.alive())
        status = ("draining" if self.draining
                  else "ok" if alive == self.n
                  else "degraded" if alive else "down")
        return {"status": status, "workers": self.n, "alive": alive}


# ------------------------------ proxying ------------------------------

def _make_handler(fleet: FleetSupervisor):

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"
        server_version = "repro-fleet/0.1"

        def log_message(self, fmt, *args):  # noqa: A003
            if fleet.verbose:
                BaseHTTPRequestHandler.log_message(self, fmt, *args)

        def _json(self, status: int, obj: dict, *,
                  close: bool = False) -> None:
            payload = (json.dumps(obj) + "\n").encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            if close:
                self.send_header("Connection", "close")
                self.close_connection = True
            self.end_headers()
            self.wfile.write(payload)

        def _body(self) -> dict:
            n = int(self.headers.get("Content-Length") or 0)
            raw = self.rfile.read(n) if n else b""
            if not raw:
                return {}
            obj = json.loads(raw)
            if not isinstance(obj, dict):
                raise ValueError("request body must be a JSON object")
            return obj

        def _timeout(self) -> float:
            raw = self.headers.get(TIMEOUT_HEADER)
            try:
                t = float(raw) if raw else fleet.default_timeout_s
            except ValueError:
                t = fleet.default_timeout_s
            return max(0.1, t)

        # ------------------------- dispatch -------------------------

        def do_GET(self):  # noqa: N802
            path = urlsplit(self.path).path
            if path == "/healthz":
                self._json(200, fleet.healthz())
            elif path == "/stats":
                self._json(200, fleet.stats())
            else:
                self._json(404, {"error": f"no such endpoint {path!r}"})

        def do_POST(self):  # noqa: N802
            path = urlsplit(self.path).path
            if path == "/shutdown":
                # drain only after the acknowledgement is flushed, so
                # the process exit behind it cannot tear the response
                # out from under the client
                acked = threading.Event()

                def _drain_after_ack() -> None:
                    acked.wait(timeout=5.0)
                    fleet.drain()

                threading.Thread(target=_drain_after_ack, daemon=True,
                                 name="repro-fleet-drain").start()
                try:
                    self._json(200, {"draining": True}, close=True)
                finally:
                    acked.set()
                return
            if fleet.draining:
                self._json(503, {"error": "draining: fleet is "
                                          "shutting down"}, close=True)
                return
            try:
                body = self._body()
            except (ValueError, OSError) as e:
                self._json(400, {"error": f"bad request body: {e}"})
                return
            try:
                if path == "/predict":
                    self._proxy_unary(path, body, degrade=True)
                elif path == "/report":
                    self._proxy_unary(path, body, degrade=False)
                elif path == "/search":
                    self._proxy_unary(path, body, degrade=False)
                elif path == "/reload":
                    self._json(200, fleet.reload_workers())
                elif path == "/campaign":
                    self._proxy_campaign(body)
                else:
                    self._json(404, {"error": f"no such endpoint {path!r}"})
            except BrokenPipeError:
                self.close_connection = True
            except Exception as e:  # noqa: BLE001 — the fleet must live
                self._json(500, {"error": f"{type(e).__name__}: {e}"})

        # ------------------------- unary proxy -------------------------

        def _forward(self, worker: WorkerHandle, path: str, body: dict,
                     timeout: float):
            """One forwarded POST; returns (status, payload_bytes)."""
            data = json.dumps(body).encode()
            req = urllib.request.Request(
                worker.url + path, data=data, method="POST",
                headers={"Content-Type": "application/json",
                         TIMEOUT_HEADER: f"{timeout:g}"})
            try:
                resp = urllib.request.urlopen(req, timeout=timeout)
                return resp.status, resp.read()
            except urllib.error.HTTPError as e:
                return e.code, e.read()

        def _proxy_unary(self, path: str, body: dict,
                         *, degrade: bool) -> None:
            cls = request_class(path, body)
            budget = self._timeout()
            if degrade and fleet._breaker.is_open(cls):
                self._json(200, fleet.degraded_predict(
                    body, reason="circuit open for this request class"))
                return
            home = route_index(cls, fleet.n)
            attempts = min(fleet.n, fleet.redispatch_limit + 1)
            # the client's budget covers the WHOLE request including
            # redispatches, so each worker attempt gets a slice of it —
            # a hung first worker must leave time to kill it and ask
            # the next one
            timeout = max(0.1, budget * 0.8 / attempts)
            last: str = "no workers available"
            for attempt in range(attempts):
                idx = (home + attempt) % fleet.n
                try:
                    worker = fleet._ensure(idx)
                except Exception as e:  # noqa: BLE001 — spawn failed
                    last = f"worker {idx} failed to start: {e}"
                    continue
                try:
                    status, payload = self._forward(worker, path, body,
                                                    timeout)
                except OSError as e:
                    # timeout (hung) or reset/refused (dead): either way
                    # this worker is not coming back with an answer —
                    # kill it, count the death, go to the next worker
                    last = f"worker {idx}: {type(e).__name__}: {e}"
                    hung = isinstance(e, TimeoutError)
                    worker.kill()
                    with fleet._lock:
                        if hung:
                            fleet._counters["hung_kills"] += 1
                        if attempt + 1 < attempts:
                            fleet._counters["redispatches"] += 1
                    opened = fleet._breaker.record_death(cls)
                    try:
                        fleet._restart(idx, worker, reason=last)
                    except Exception:  # noqa: BLE001 — monitor will retry
                        pass
                    if opened:
                        break
                    continue
                fleet._mark_success(idx, cls)
                self.send_response(status)
                self.send_header("Content-Type", "application/json")
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)
                return
            if degrade:
                self._json(200, fleet.degraded_predict(
                    body, reason=f"workers kept dying ({last})"))
            else:
                self._json(502, {"error": f"all workers failed: {last}"})

        # ------------------------ campaign proxy ------------------------

        def _proxy_campaign(self, body: dict) -> None:
            """Stream a campaign through a worker, re-dispatching to the
            next worker with the already-forwarded rows as
            ``resume_rows`` if the stream breaks before its summary."""
            cls = request_class("/campaign", body)
            # for a stream the budget bounds the silence *gap* between
            # rows, not the whole campaign; halving it leaves slack to
            # kill a hung worker and re-dispatch before the client's
            # own gap timer (the full budget) expires
            timeout = max(0.1, self._timeout() * 0.5)
            home = route_index(cls, fleet.n)
            attempts = fleet.redispatch_limit + 1
            forwarded: list[dict] = []
            headers_sent = False
            last = "no workers available"

            def _send_headers():
                nonlocal headers_sent
                if not headers_sent:
                    self.send_response(200)
                    self.send_header("Content-Type",
                                     "application/x-ndjson")
                    self.send_header("Connection", "close")
                    self.close_connection = True
                    self.end_headers()
                    headers_sent = True

            for attempt in range(attempts):
                idx = (home + attempt) % fleet.n
                try:
                    worker = fleet._ensure(idx)
                except Exception as e:  # noqa: BLE001 — spawn failed
                    last = f"worker {idx} failed to start: {e}"
                    continue
                try_body = dict(body)
                if forwarded or try_body.get("resume_rows"):
                    try_body["resume_rows"] = (
                        list(body.get("resume_rows") or []) + forwarded)
                data = json.dumps(try_body).encode()
                req = urllib.request.Request(
                    worker.url + "/campaign", data=data, method="POST",
                    headers={"Content-Type": "application/json",
                             TIMEOUT_HEADER: f"{timeout:g}"})
                try:
                    resp = urllib.request.urlopen(req, timeout=timeout)
                except urllib.error.HTTPError as e:
                    # the worker rejected the spec: a clean 4xx/5xx,
                    # not a death — pass it through verbatim
                    payload = e.read()
                    if not headers_sent:
                        self.send_response(e.code)
                        self.send_header("Content-Type",
                                         "application/json")
                        self.send_header("Content-Length",
                                         str(len(payload)))
                        self.end_headers()
                        self.wfile.write(payload)
                    return
                except OSError as e:
                    last = f"worker {idx}: {type(e).__name__}: {e}"
                    worker.kill()
                    with fleet._lock:
                        fleet._counters["redispatches"] += 1
                    opened = fleet._breaker.record_death(cls)
                    try:
                        fleet._restart(idx, worker, reason=last)
                    except Exception:  # noqa: BLE001
                        pass
                    if opened:
                        break
                    continue
                # stream rows through, buffering for redispatch
                got_final = False
                try:
                    with resp:
                        for raw in resp:
                            line = raw.strip()
                            if not line:
                                continue
                            obj = json.loads(line)
                            if obj.get("event") in ("summary", "error"):
                                _send_headers()
                                self.wfile.write(line + b"\n")
                                self.wfile.flush()
                                got_final = True
                                break
                            _send_headers()
                            self.wfile.write(line + b"\n")
                            self.wfile.flush()
                            forwarded.append(obj)
                except (OSError, ValueError) as e:
                    last = f"worker {idx} stream: {type(e).__name__}: {e}"
                if got_final:
                    fleet._mark_success(idx, cls)
                    return
                # stream broke before the summary: the worker died (or
                # hung past the budget) mid-campaign — kill, restart,
                # re-dispatch with everything already forwarded
                last = (last if "stream" in last
                        else f"worker {idx} stream ended early")
                worker.kill()
                with fleet._lock:
                    fleet._counters["redispatches"] += 1
                opened = fleet._breaker.record_death(cls)
                try:
                    fleet._restart(idx, worker, reason=last)
                except Exception:  # noqa: BLE001
                    pass
                if opened:
                    break
            # out of attempts (or breaker open): the stream protocol is
            # already NDJSON, so the failure is an in-band error event
            _send_headers()
            final = {"event": "error",
                     "error": f"campaign failed after redispatches: {last}",
                     "rows_forwarded": len(forwarded)}
            try:
                self.wfile.write((json.dumps(final) + "\n").encode())
            except OSError:
                pass

    return Handler
