"""Batched autoregressive serving loop built on decode_step."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models.params import init_params
from ..models.transformer import decode_step, init_cache_specs


@dataclass
class ServeResult:
    tokens: jax.Array            # [B, steps]
    steps: int


def make_serve_step(cfg: ModelConfig):
    """jit-able serve_step(params, cache, tokens[B,1]) -> (next, cache)."""

    def serve_step(params, cache, batch):
        logits, cache = decode_step(cfg, params, cache, batch)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return nxt, cache

    return serve_step


def greedy_decode(cfg: ModelConfig, params, prompt: jax.Array,
                  max_new_tokens: int = 8, max_len: int = 128) -> ServeResult:
    """Greedy generation: prompt [B, S0] -> [B, max_new_tokens]."""
    b, s0 = prompt.shape
    if s0 + max_new_tokens > max_len:
        # decode_step writes one KV slot per step via a clamped
        # dynamic_update_slice — past max_len it would silently
        # overwrite the last slot instead of failing
        raise ValueError(
            f"greedy_decode: prompt length {s0} + max_new_tokens "
            f"{max_new_tokens} exceeds the KV cache (max_len={max_len}) "
            "— raise max_len or generate fewer tokens")
    cache = init_params(init_cache_specs(cfg, b, max_len),
                        jax.random.PRNGKey(0))
    step_fn = jax.jit(make_serve_step(cfg))
    # feed the prompt token-by-token (prefill-by-decode; simple and exact)
    tok = None
    for i in range(s0):
        tok, cache = step_fn(params, cache, {"tokens": prompt[:, i:i + 1]})
    out = []
    for _ in range(max_new_tokens):
        out.append(tok)
        tok, cache = step_fn(params, cache, {"tokens": tok[:, None]})
    return ServeResult(tokens=jnp.stack(out, axis=1), steps=max_new_tokens)
