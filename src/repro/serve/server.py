"""Prediction-as-a-service: a warm, long-lived HTTP daemon.

Every prediction in this repo used to pay full process startup — Python
imports, re-parsing IR, re-warming the (H, C, R) cache — per query.
This module keeps all of that resident instead: one
:class:`repro.api.Session` is constructed at boot and its warm state —
the :class:`~repro.campaign.plans.PlanStore` of parsed workloads and
:class:`~repro.core.pipeline.PredictionPlan`s, and the shared
:class:`~repro.core.estimators.cache.PersistentCache` — serves every
request for the life of the process.  Everything downstream (CI, the
campaign CLI's ``--server`` mode, benchmarks, what-if search) becomes a
thin client of one warm session.

Transport is localhost HTTP on the stdlib ``ThreadingHTTPServer`` — no
new runtime dependencies.  Endpoints (see ``docs/serving.md``):

* ``GET  /healthz`` — liveness + drain state;
* ``GET  /stats``   — requests served, coalescing and duplicate-cold-miss
  accounting, plans resident, cache store counters;
* ``POST /predict`` — one grid point, JSON in / result row out;
* ``POST /campaign``— a campaign spec, result rows streamed back as
  JSONL while jobs finish, terminated by a summary line;
* ``POST /report``  — campaign + evaluation report (MAPE, rank
  preservation, optional golden check) in one round trip;
* ``POST /shutdown``— graceful drain (same as SIGTERM).

**Request coalescing.**  Concurrent ``/predict`` requests whose jobs
share an exact (H, C, R) cache keyset (same
:meth:`~repro.campaign.spec.JobSpec.cache_group`) are coalesced the way
the campaign scheduler chains jobs: the first request is the chain
leader and evaluates; followers wait on the leader's completion event
and then evaluate against the now-warm shared store — pure cache hits.
A burst of identical what-if queries therefore triggers exactly one
cold miss per region, which ``/stats`` proves via
``duplicate_cold_misses`` (total predict misses minus distinct keys
evaluated; 0 unless coalescing broke).

**Graceful drain.**  SIGTERM (or ``POST /shutdown``) stops admission —
new work gets 503 — waits for in-flight requests (a mid-flight campaign
streams to completion), then stops the listener.
"""
from __future__ import annotations

import json
import signal
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from urllib.parse import urlsplit

from ..campaign.spec import (SLICER_NAMES, CampaignSpec, EstimatorSpec,
                             JobSpec, TopologySpec, WorkloadSpec)
from . import faults

DEFAULT_PORT = 8733

#: WorkloadSpec keys that name an IR source (anything else is a knob)
_SOURCE_KEYS = ("stablehlo_path", "hlo_path", "arch", "gemm")


class ServiceError(ValueError):
    """A request the service rejects, carrying its HTTP status."""
    status = 500


class BadRequest(ServiceError):
    status = 400


class PredictionService:
    """The transport-independent core: one warm session + coalescing.

    Owns the :class:`repro.api.Session` (scoped registries, shared
    (H, C, R) store), the session's warm plan store, request/coalescing
    accounting, and the request handlers the HTTP layer dispatches to.
    Thread-safe: the HTTP server calls into one instance from many
    handler threads.
    """

    def __init__(self, session=None, *, cache_path: str | None = None,
                 systems: tuple | list = (),
                 coalesce_timeout_s: float = 300.0):
        from .. import api
        from ..campaign.runner import _Registries
        self.session = session or api.Session(systems=systems,
                                              cache_path=cache_path)
        self.plans = self.session.plan_store
        self.coalesce_timeout_s = coalesce_timeout_s
        self.draining = False
        self._t0 = time.time()
        self._mono0 = time.monotonic()
        self._lock = threading.Lock()
        self._inflight_groups: dict[tuple, threading.Event] = {}
        self._requests: dict[str, int] = {}
        self._predict = {"served": 0, "coalesced": 0, "cache_hits": 0,
                         "cache_misses": 0}
        self._campaign = {"served": 0, "rows": 0, "cache_hits": 0,
                          "cache_misses": 0, "duplicate_cold_misses": 0,
                          "resumed_rows": 0, "retried_rows": 0}
        self._search = {"served": 0, "evaluations": 0,
                        "frontier_points": 0}
        #: spec paths preloaded at boot, replayed by :meth:`reload`
        self._preload_paths: list[str] = []
        self._evaluated_keys: set[str] = set()
        #: name -> WorkloadSpec it was materialized from (identity memo:
        #: an unchanged re-registration skips the rebuild entirely)
        self._sources: dict[str, WorkloadSpec] = {}
        self._regs = _Registries(
            estimators=self.session.estimators,
            topologies=self.session.topologies,
            systems=self.session.systems)

    # ----------------------------- boot-time -----------------------------

    def preload(self, spec_path: str) -> dict:
        """Parse + plan every workload a campaign/suite spec references,
        so the spec's first request hits fully warm plans.  Returns a
        small report (workloads added, plans built)."""
        from ..campaign.__main__ import load_specs
        from ..campaign.runner import _workload_texts
        added, planned = [], 0
        for _, spec in load_specs(spec_path, session=self.session):
            texts = _workload_texts(spec, None)
            self.plans.add_texts(texts)
            for w in spec.workloads:
                self._sources[w.name] = w
                added.append(w.name)
            for job in spec.expand():
                key = self.plans.key_for(job)
                if key not in self.plans.plans:
                    self.plans.get(*key)
                    planned += 1
        with self._lock:
            if spec_path not in self._preload_paths:
                self._preload_paths.append(spec_path)
        return {"spec": spec_path, "workloads": added,
                "plans_built": planned}

    def reload(self) -> dict:
        """Replay every boot-time :meth:`preload` against the specs'
        *current* on-disk contents — an edited spec re-materializes its
        changed workloads and plans, unchanged ones are identity-memo
        no-ops, and in-flight requests keep the plans they already hold
        (the plan store only ever grows or replaces whole entries)."""
        self._count("reload")
        with self._lock:
            paths = list(self._preload_paths)
        reports = [self.preload(p) for p in paths]
        return {"specs": len(reports),
                "workloads": sorted({w for r in reports
                                     for w in r["workloads"]}),
                "plans_built": sum(r["plans_built"] for r in reports)}

    # ---------------------------- request body ----------------------------

    def _count(self, endpoint: str) -> None:
        with self._lock:
            self._requests[endpoint] = self._requests.get(endpoint, 0) + 1

    def _resolve_workload(self, w) -> str:
        """Materialize/locate the request's workload; returns its name."""
        from ..campaign.builders import build_workload
        if isinstance(w, str):
            name, wspec = w, None
        elif isinstance(w, dict):
            if "name" not in w:
                raise BadRequest("workload object needs a 'name'")
            name = str(w["name"])
            if any(k in w for k in _SOURCE_KEYS):
                try:
                    wspec = WorkloadSpec.from_dict(w)
                    wspec.validate()
                except (TypeError, ValueError) as e:
                    raise BadRequest(f"bad workload spec: {e}") from e
            else:
                wspec = None
        else:
            raise BadRequest("'workload' must be a name or a "
                             "workload-spec object")
        if wspec is not None:
            if self._sources.get(name) != wspec:
                built = build_workload(wspec)
                self.plans.add_texts({name: {
                    "raw": built.stablehlo_text,
                    "optimized": built.hlo_text}})
                with self._lock:
                    self._sources[name] = wspec
        elif name not in self.plans.texts:
            raise BadRequest(
                f"unknown workload {name!r}: preload it at boot or "
                "include a source (stablehlo_path/hlo_path/arch/gemm)")
        return name

    def _job_from_body(self, body: dict) -> JobSpec:
        """One fully validated grid point from a ``/predict`` body."""
        if "workload" not in body:
            raise BadRequest("predict request needs a 'workload'")
        name = self._resolve_workload(body["workload"])

        e = body.get("estimator", "roofline")
        try:
            espec = (EstimatorSpec(kind=e) if isinstance(e, str)
                     else EstimatorSpec.from_dict(dict(e)))
        except (TypeError, ValueError) as e_:
            raise BadRequest(f"bad estimator spec: {e_}") from e_
        if espec.kind not in self.session.estimators:
            raise BadRequest(self.session.estimators.unknown_message(
                espec.kind))

        t = body.get("topology", "auto")
        try:
            tspec = (TopologySpec(kind=t) if isinstance(t, str)
                     else TopologySpec.from_dict(dict(t)))
        except (TypeError, ValueError) as e_:
            raise BadRequest(f"bad topology spec: {e_}") from e_
        if tspec.kind not in self.session.topologies:
            raise BadRequest(self.session.topologies.unknown_message(
                tspec.kind))

        system = str(body.get("system", "a100"))
        if system not in self.session.systems:
            raise BadRequest(self.session.systems.unknown_message(system))

        slicer = str(body.get("slicer", "linear"))
        if slicer not in SLICER_NAMES:
            raise BadRequest(f"unknown slicer {slicer!r}; "
                             f"have {SLICER_NAMES}")

        source = self._sources.get(name)
        fidelity = (body.get("fidelity") or espec.fidelity
                    or (source.fidelity if source else None) or "optimized")
        try:
            return JobSpec(
                job_id=0, workload=name, fidelity=str(fidelity),
                system=system, estimator=espec, slicer=slicer,
                topology=tspec, overlap=bool(body.get("overlap", False)),
                straggler_factor=float(body.get("straggler_factor", 1.0)),
                compression=float(body.get("compression", 1.0)))
        except (TypeError, ValueError) as e_:
            raise BadRequest(f"bad knob value: {e_}") from e_

    # ------------------------------ handlers ------------------------------

    def healthz(self) -> dict:
        self._count("healthz")
        return {"status": "draining" if self.draining else "ok",
                "uptime_s": round(time.monotonic() - self._mono0, 3),
                "started_unix": self._t0}

    def stats(self) -> dict:
        self._count("stats")
        with self._lock:
            predict = dict(self._predict)
            predict["distinct_keys_evaluated"] = len(self._evaluated_keys)
            predict["duplicate_cold_misses"] = (
                predict["cache_misses"] - len(self._evaluated_keys))
            campaign = dict(self._campaign)
            search = dict(self._search)
            requests = dict(self._requests)
        out = {
            "uptime_s": round(time.monotonic() - self._mono0, 3),
            "draining": self.draining,
            "requests": requests,
            "predict": predict,
            "campaign": campaign,
            "search": search,
            "plans": {
                "resident": len(self.plans.plans),
                "workloads": len(self.plans.texts),
                "parse_calls": self.plans.parse_count,
                "plans_built": self.plans.plans_built,
            },
            "cache": self.session.cache_store.stats_dict(),
        }
        if faults.active():   # test-only; absent in production stats
            out["faults"] = faults.stats()
        return out

    def predict(self, body: dict) -> dict:
        """One grid point against the warm store, coalesced with any
        concurrent request sharing its (H, C, R) cache keyset."""
        from ..campaign.runner import _execute
        self._count("predict")
        job = self._job_from_body(body)
        try:
            key = self.plans.key_for(job)
            plan = self.plans.get(*key)
        except (KeyError, ValueError) as e:
            raise BadRequest(f"cannot plan workload "
                             f"{job.workload!r}: {e}") from e
        group = job.cache_group(self.plans.fingerprint_set(key))

        with self._lock:
            leader_evt = self._inflight_groups.get(group)
            if leader_evt is None:
                leader_evt = threading.Event()
                self._inflight_groups[group] = leader_evt
                is_leader = True
            else:
                is_leader = False
                self._predict["coalesced"] += 1
        if not is_leader:
            # chain-follower: by the time the leader finishes, every
            # (H, C, R) key this job needs is in the shared store
            leader_evt.wait(self.coalesce_timeout_s)
        try:
            row, new = _execute(job, plan, self.session.cache_store,
                                self._regs)
        finally:
            if is_leader:
                with self._lock:
                    if self._inflight_groups.get(group) is leader_evt:
                        del self._inflight_groups[group]
                leader_evt.set()
        with self._lock:
            self._predict["served"] += 1
            self._predict["cache_hits"] += row.get("cache_hits", 0)
            self._predict["cache_misses"] += row.get("cache_misses", 0)
            self._evaluated_keys.update(new)
        row["coalesced"] = not is_leader
        return row

    def campaign_spec(self, body: dict) -> tuple[CampaignSpec, dict]:
        """Validate a ``/campaign`` body up front (so transport errors
        can still be clean 4xx JSON, not mid-stream noise); returns the
        spec plus runner options."""
        from ..campaign.runner import EXECUTORS, SCHEDULES
        if ("spec" in body) == ("spec_path" in body):
            raise BadRequest(
                "campaign request needs exactly one of 'spec' "
                "(inline campaign dict) or 'spec_path' (server-side "
                "spec file)")
        try:
            if "spec_path" in body:
                spec = CampaignSpec.from_json(str(body["spec_path"]),
                                              session=self.session)
            else:
                spec = CampaignSpec.from_dict(dict(body["spec"]),
                                              session=self.session)
        except OSError as e:
            raise BadRequest(f"cannot read spec: {e}") from e
        except (TypeError, ValueError, KeyError) as e:
            raise BadRequest(f"bad campaign spec: {e}") from e
        opts = {
            "executor": str(body.get("executor", "thread")),
            "schedule": str(body.get("schedule", "locality")),
            "max_workers": body.get("max_workers"),
        }
        if opts["executor"] not in EXECUTORS:
            raise BadRequest(f"executor {opts['executor']!r} "
                             f"not in {EXECUTORS}")
        if opts["schedule"] not in SCHEDULES:
            raise BadRequest(f"schedule {opts['schedule']!r} "
                             f"not in {SCHEDULES}")
        if "resume_rows" in body:
            if not isinstance(body["resume_rows"], list) or not all(
                    isinstance(r, dict) for r in body["resume_rows"]):
                raise BadRequest("'resume_rows' must be a list of result "
                                 "rows (a partial run's results.jsonl)")
            opts["resume_rows"] = body["resume_rows"]
        if "retries" in body:
            try:
                opts["retries"] = max(0, int(body["retries"]))
            except (TypeError, ValueError) as e:
                raise BadRequest(
                    f"'retries' must be an integer: {e}") from e
        return spec, opts

    def run_campaign(self, spec: CampaignSpec, opts: dict, on_row=None):
        """Execute a validated campaign against the warm session state;
        returns the :class:`~repro.campaign.runner.CampaignResult`."""
        from ..campaign.runner import run_campaign
        for w in spec.workloads:
            self._sources.setdefault(w.name, w)
        result = run_campaign(
            spec, executor=opts.get("executor", "thread"),
            max_workers=opts.get("max_workers"),
            schedule=opts.get("schedule", "locality"),
            cache=self.session.cache_store,
            cache_path=self.session.cache_path,
            plan_store=self.plans, on_row=on_row, session=self.session,
            resume_rows=opts.get("resume_rows"),
            retries=opts.get("retries", 0))
        with self._lock:
            self._campaign["served"] += 1
            self._campaign["rows"] += len(result.rows)
            self._campaign["cache_hits"] += result.cache["hits"]
            self._campaign["cache_misses"] += result.cache["misses"]
            # misses are evaluations; new_entries are distinct new keys —
            # any excess is a duplicated cold evaluation (the scheduler
            # keeps this 0 within a run)
            self._campaign["duplicate_cold_misses"] += max(
                0, result.cache["misses"] - result.cache["new_entries"])
            self._campaign["resumed_rows"] += result.resumed_rows
            self._campaign["retried_rows"] += result.retried_rows
        return result

    def campaign(self, body: dict, on_row=None):
        self._count("campaign")
        spec, opts = self.campaign_spec(body)
        return self.run_campaign(spec, opts, on_row=on_row)

    def search(self, body: dict) -> dict:
        """Multi-fidelity what-if search against the warm session state;
        returns the frontier report (see ``docs/search.md``).  The body
        carries exactly one of ``spec`` (inline search dict) or
        ``spec_path`` (server-side spec file), plus an optional
        ``brute_force`` flag."""
        from ..search.engine import run_search
        from ..search.report import build_search_report
        from ..search.spec import SearchSpec
        self._count("search")
        if ("spec" in body) == ("spec_path" in body):
            raise BadRequest(
                "search request needs exactly one of 'spec' (inline "
                "search dict) or 'spec_path' (server-side spec file)")
        try:
            if "spec_path" in body:
                spec = SearchSpec.from_json(str(body["spec_path"]),
                                            session=self.session)
            else:
                spec = SearchSpec.from_dict(dict(body["spec"]),
                                            session=self.session)
        except OSError as e:
            raise BadRequest(f"cannot read spec: {e}") from e
        except (TypeError, ValueError, KeyError) as e:
            raise BadRequest(f"bad search spec: {e}") from e
        for w in spec.workloads:
            self._sources.setdefault(w.name, w)
        result = run_search(
            spec, session=self.session, cache=self.session.cache_store,
            plan_store=self.plans,
            brute_force=bool(body.get("brute_force", False)))
        with self._lock:
            self._search["served"] += 1
            self._search["evaluations"] += len(result.rows)
            self._search["frontier_points"] += len(result.frontier)
        return build_search_report(result)

    def report(self, body: dict) -> dict:
        """Campaign + evaluation report in one request: run the spec (or
        take ``rows``), score MAPE/rank preservation against the recorded
        references, optionally gate against the golden snapshot."""
        from ..campaign.report import (DEFAULT_TOLERANCE, build_report,
                                       check_rows, golden_path, load_json,
                                       reference_path)
        self._count("report")
        spec_path = body.get("spec_path")
        if not spec_path:
            raise BadRequest("report request needs 'spec_path' (golden "
                             "and reference files derive from it)")
        spec, opts = self.campaign_spec(
            {k: v for k, v in body.items() if k != "rows"})
        rows = body.get("rows")
        if rows is None:
            rows = self.run_campaign(spec, opts).rows
        reference = load_json(reference_path(spec_path, spec.name))
        report = build_report(spec.name, rows, reference=reference)
        if body.get("check"):
            golden = load_json(golden_path(spec_path, spec.name))
            if golden is None:
                report["golden_check"] = {
                    "failures": [f"{spec.name}: no golden snapshot at "
                                 f"{golden_path(spec_path, spec.name)}"],
                    "rows_checked": 0, "tolerance": DEFAULT_TOLERANCE}
            else:
                report["golden_check"] = check_rows(
                    golden, rows, tolerance=body.get("tolerance"))
        return report


class PredictionServer:
    """The HTTP front end: admission control, drain, request dispatch.

    ``port=0`` binds an ephemeral port (tests); :attr:`url` is the
    bound address either way.
    """

    def __init__(self, service: PredictionService, *,
                 host: str = "127.0.0.1", port: int = DEFAULT_PORT,
                 drain_timeout_s: float = 60.0, verbose: bool = False):
        self.service = service
        self.drain_timeout_s = drain_timeout_s
        self.verbose = verbose
        self._cv = threading.Condition()
        self._inflight = 0
        self._thread: threading.Thread | None = None
        self.stopped = threading.Event()
        self.httpd = ThreadingHTTPServer((host, port), _make_handler(self))
        self.httpd.daemon_threads = True

    @property
    def url(self) -> str:
        host, port = self.httpd.server_address[:2]
        return f"http://{host}:{port}"

    # ---------------------------- lifecycle ----------------------------

    def start(self) -> "PredictionServer":
        """Serve on a background thread (tests, benchmarks)."""
        self._thread = threading.Thread(
            target=self.httpd.serve_forever, kwargs={"poll_interval": 0.05},
            name="repro-serve", daemon=True)
        self._thread.start()
        return self

    def serve_forever(self) -> None:
        """Serve on the calling thread (the CLI) until drained."""
        try:
            self.httpd.serve_forever(poll_interval=0.2)
        finally:
            self.stopped.set()

    def install_signal_handlers(self) -> None:
        """SIGTERM/SIGINT -> graceful drain (CLI main thread only)."""
        def _drain(signum, frame):  # noqa: ARG001
            threading.Thread(target=self.drain, daemon=True,
                             name="repro-serve-drain").start()
        signal.signal(signal.SIGTERM, _drain)
        signal.signal(signal.SIGINT, _drain)

    def drain(self, timeout_s: float | None = None) -> bool:
        """Graceful shutdown: refuse new work (503), wait for in-flight
        requests up to ``timeout_s``, stop the listener.  Returns True
        when everything in flight completed before the deadline."""
        timeout_s = self.drain_timeout_s if timeout_s is None else timeout_s
        with self._cv:
            self.service.draining = True
            clean = self._cv.wait_for(lambda: self._inflight == 0,
                                      timeout=timeout_s)
        self.httpd.shutdown()
        self.httpd.server_close()
        self.stopped.set()
        return clean

    # ------------------------- admission control -------------------------

    def request_started(self) -> bool:
        with self._cv:
            if self.service.draining:
                return False
            self._inflight += 1
            return True

    def request_finished(self) -> None:
        with self._cv:
            self._inflight -= 1
            self._cv.notify_all()


def _make_handler(server: PredictionServer):
    service = server.service

    class Handler(BaseHTTPRequestHandler):
        protocol_version = "HTTP/1.1"
        server_version = "repro-serve/0.1"

        def log_message(self, fmt, *args):  # noqa: A003
            if server.verbose:
                BaseHTTPRequestHandler.log_message(self, fmt, *args)

        # ------------------------- plumbing -------------------------

        def _json(self, status: int, obj: dict, *,
                  close: bool = False) -> None:
            payload = (json.dumps(obj) + "\n").encode()
            self.send_response(status)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(payload)))
            if close:
                self.send_header("Connection", "close")
                self.close_connection = True
            self.end_headers()
            self.wfile.write(payload)

        def _body(self) -> dict:
            n = self.headers.get("Content-Length")
            if n is None:
                raise BadRequest("missing Content-Length")
            raw = self.rfile.read(int(n))
            if not raw:
                return {}
            try:
                obj = json.loads(raw)
            except json.JSONDecodeError as e:
                raise BadRequest(f"invalid JSON body: {e}") from e
            if not isinstance(obj, dict):
                raise BadRequest("request body must be a JSON object")
            return obj

        # ------------------------- dispatch -------------------------

        def do_GET(self):  # noqa: N802
            path = urlsplit(self.path).path
            # health/stats stay readable while draining — monitors need
            # to watch the drain happen
            if path == "/healthz":
                self._json(200, service.healthz())
            elif path == "/stats":
                self._json(200, service.stats())
            elif path in ("/predict", "/campaign", "/report", "/search",
                          "/reload", "/shutdown"):
                self._json(405, {"error": f"{path} takes POST, not GET"})
            else:
                self._json(404, {"error": f"no such endpoint {path!r}"})

        def do_POST(self):  # noqa: N802
            path = urlsplit(self.path).path
            if path == "/shutdown":
                service._count("shutdown")
                # hold an in-flight slot across the acknowledgement so
                # the drain (and then the process exit behind it) cannot
                # win the race against this response reaching the client
                with server._cv:
                    server._inflight += 1
                threading.Thread(target=server.drain, daemon=True,
                                 name="repro-serve-drain").start()
                try:
                    self._json(200, {"draining": True}, close=True)
                finally:
                    server.request_finished()
                return
            if not server.request_started():
                self._json(503, {"error": "draining: server is "
                                          "shutting down"}, close=True)
                return
            try:
                if path == "/predict":
                    self._json(200, service.predict(self._body()))
                elif path == "/campaign":
                    self._campaign_stream(self._body())
                elif path == "/report":
                    self._json(200, service.report(self._body()))
                elif path == "/search":
                    self._json(200, service.search(self._body()))
                elif path == "/reload":
                    self._body()   # admin verb takes no arguments
                    self._json(200, service.reload())
                else:
                    self._json(404, {"error": f"no such endpoint {path!r}"})
            except ServiceError as e:
                self._json(e.status, {"error": str(e)})
            except (TypeError, ValueError, KeyError) as e:
                self._json(400, {"error": f"{type(e).__name__}: {e}"})
            except BrokenPipeError:
                self.close_connection = True
            except Exception as e:  # noqa: BLE001 — the daemon must live
                self._json(500, {"error": f"{type(e).__name__}: {e}"})
            finally:
                server.request_finished()

        def _campaign_stream(self, body: dict) -> None:
            """Validate, then stream result rows as JSONL while the
            campaign runs, final line = the summary.  The response has
            no Content-Length and closes the connection (clients read
            to EOF)."""
            service._count("campaign")
            spec, opts = service.campaign_spec(body)  # 4xx before headers
            self.send_response(200)
            self.send_header("Content-Type", "application/x-ndjson")
            self.send_header("Connection", "close")
            self.close_connection = True
            self.end_headers()
            wlock = threading.Lock()
            dead = [False]    # client gone: keep running, stop writing

            def _reset_connection() -> None:
                """Hard-close mid-stream (fault op 'reset'): the client
                sees EOF with no summary line — exactly what a worker
                crash looks like from outside."""
                import socket as _socket
                dead[0] = True
                try:
                    self.connection.shutdown(_socket.SHUT_RDWR)
                except OSError:
                    pass

            def on_row(row: dict) -> None:
                line = (json.dumps(row) + "\n").encode()
                with wlock:
                    if dead[0]:
                        return
                    try:
                        self.wfile.write(line)
                        self.wfile.flush()
                    except OSError:
                        # the client disconnected mid-stream; finish the
                        # campaign anyway — every remaining row still
                        # lands in the shared store, so the client's
                        # retry (or a fleet redispatch) replays warm
                        dead[0] = True
                        return
                if faults.active():
                    f = faults.fire("stream", job_id=row.get("job_id"))
                    if f is not None and f.op == "reset":
                        with wlock:
                            _reset_connection()

            try:
                result = service.run_campaign(spec, opts, on_row=on_row)
                final = {"event": "summary", "summary": result.summary}
            except Exception as e:  # noqa: BLE001 — headers already sent
                final = {"event": "error",
                         "error": f"{type(e).__name__}: {e}"}
            with wlock:
                if not dead[0]:
                    try:
                        self.wfile.write((json.dumps(final) + "\n").encode())
                    except OSError:
                        dead[0] = True

    return Handler
