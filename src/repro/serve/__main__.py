"""CLI entry point: ``python -m repro.serve`` — boot the warm daemon.

Constructs one :class:`repro.api.Session` (optionally preloading
campaign specs so their plans are parsed and sliced before the first
request), binds the localhost HTTP server, installs SIGTERM/SIGINT
drain handlers, and serves until drained::

    python -m repro.serve --port 8733 --cache .cache/hcr.jsonl \\
        --preload specs/fig10_gemm.json

``--port 0`` binds an ephemeral port (the chosen URL is printed on the
first line of stdout, so scripts can scrape it).  See
``docs/serving.md`` for the endpoint reference and
``repro.serve.client`` / ``examples/serve_client.py`` for clients.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from .server import DEFAULT_PORT


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.serve",
        description="Warm prediction daemon: one resident Session "
                    "(plans + (H, C, R) cache) serving predict/campaign/"
                    "report over localhost HTTP.")
    ap.add_argument("--host", default="127.0.0.1",
                    help="bind address (default 127.0.0.1; keep it local)")
    ap.add_argument("--port", type=int, default=DEFAULT_PORT,
                    help=f"TCP port (default {DEFAULT_PORT}; 0 = ephemeral)")
    ap.add_argument("--cache", default=None, metavar="PATH",
                    help="persistent (H, C, R) store backing every "
                         "request (default: in-memory only)")
    ap.add_argument("--systems", action="append", default=[],
                    metavar="PATH",
                    help="extra system-catalog file/dir (repeatable)")
    ap.add_argument("--preload", action="append", default=[],
                    metavar="SPEC",
                    help="campaign/suite spec whose workloads are parsed "
                         "and planned at boot (repeatable)")
    ap.add_argument("--drain-timeout", type=float, default=60.0,
                    metavar="S", help="max seconds to wait for in-flight "
                                      "requests on shutdown (default 60)")
    ap.add_argument("--workers", type=int, default=1, metavar="N",
                    help="N > 1 boots a supervised worker fleet behind "
                         "this listener instead of a single daemon "
                         "(see docs/robustness.md)")
    ap.add_argument("--fault-plan", default=None, metavar="PLAN",
                    help="TEST ONLY: seeded fault-injection plan (path "
                         "or inline JSON) activated in the daemon / its "
                         "workers — see repro.serve.faults")
    ap.add_argument("--verbose", action="store_true",
                    help="log every request to stderr")
    args = ap.parse_args(argv)

    if args.workers > 1:
        # the fleet ships work to separate worker *processes*: refuse to
        # boot if any registered backend class could not cross that
        # boundary (same check the process-pool campaign executor makes)
        errs = _portability_errors()
        if errs:
            for e in errs:
                print(f"error: {e}", file=sys.stderr)
            return 2
        from .fleet import FleetSupervisor
        fleet = FleetSupervisor(
            workers=args.workers, cache_path=args.cache,
            systems=tuple(args.systems), preload=tuple(args.preload),
            host=args.host, port=args.port, fault_plan=args.fault_plan,
            verbose=args.verbose)
        fleet.install_signal_handlers()
        fleet.start()       # workers + monitor + front listener thread
        # first stdout line is machine-readable: scripts scrape the URL
        print(json.dumps({"url": fleet.url, "pid": os.getpid(),
                          "workers": args.workers}), flush=True)
        while not fleet.stopped.wait(0.2):   # main thread: signals only
            pass
        return 0

    if args.fault_plan:
        from . import faults
        os.environ[faults.ENV_PLAN] = args.fault_plan

    from .server import PredictionServer, PredictionService
    service = PredictionService(cache_path=args.cache,
                                systems=tuple(args.systems))
    for err in _portability_errors(service):
        # a single daemon serves in-process by default, but a client may
        # still request executor='process' — warn loudly at boot instead
        # of failing at request time
        print(f"warning: {err}", file=sys.stderr)
    for spec in args.preload:
        info = service.preload(spec)
        print(f"preloaded {spec}: {len(info['workloads'])} workloads, "
              f"{info['plans_built']} plans", file=sys.stderr)
    server = PredictionServer(service, host=args.host, port=args.port,
                              drain_timeout_s=args.drain_timeout,
                              verbose=args.verbose)
    # first stdout line is machine-readable: scripts scrape the URL
    print(json.dumps({"url": server.url, "pid": os.getpid()}), flush=True)
    server.install_signal_handlers()
    server.serve_forever()
    return 0


def _portability_errors(service=None) -> list[str]:
    """Boot check: every registered backend class must be importable at
    module level to cross a worker-process boundary (fleet workers, the
    process-pool campaign executor).  Checks the service's session
    registries when given one, else the global vocabularies."""
    if service is not None:
        regs = [service.session.estimators, service.session.topologies]
    else:
        from ..core.registry import ESTIMATORS, TOPOLOGIES
        regs = [ESTIMATORS, TOPOLOGIES]
    errs: list[str] = []
    for reg in regs:
        r = reg
        while r is not None:            # scoped session registries chain
            errs.extend(r.portability_errors())
            r = r.parent
    return errs


if __name__ == "__main__":
    raise SystemExit(main())
