"""Family-polymorphic model assembly: dense / MoE / SSM / hybrid / encoder /
VLM-backbone LMs with scan-over-layers, remat, and logical-axis sharding.

Public entry points (all pure functions of (cfg, params, batch)):

  model_specs(cfg)                 -> ParamSpec tree
  forward(cfg, params, batch)      -> (loss, logits)      [train/eval]
  prefill(cfg, params, batch)      -> (logits, cache)     [inference prefill]
  decode_step(cfg, params, cache, batch) -> (logits, cache)
  init_cache_specs(cfg, batch, max_len)  -> cache ParamSpec tree
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .attention import (attention_specs, gqa_decode, gqa_forward, mla_decode,
                        mla_forward, mla_specs)
from .common import (barrier, embedding_spec, norm_spec, rms_norm,
                     shard_act, softcap)
from .mlp import (mlp_forward, mlp_specs, moe_aux_loss, moe_forward,
                  moe_forward_ep, moe_specs)
from .params import ParamSpec
from .ssm import mamba2_forward, ssm_specs


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def _layer_specs(cfg: ModelConfig, stacked: int) -> dict:
    """One transformer block's specs (attention or ssm + mlp/moe + norms)."""
    dt = cfg.dtype

    def n(shape, axes):
        if stacked:
            return ParamSpec((stacked, *shape), ("layers", *axes),
                             init="ones", dtype=dt)
        return ParamSpec(shape, axes, init="ones", dtype=dt)

    if cfg.family == "ssm":
        return {"ssm": ssm_specs(cfg, stacked),
                "ln": n((cfg.d_model,), ("norm",))}
    specs: dict = {"ln1": n((cfg.d_model,), ("norm",)),
                   "ln2": n((cfg.d_model,), ("norm",))}
    if cfg.mla is not None:
        specs["attn"] = mla_specs(cfg, stacked)
    else:
        specs["attn"] = attention_specs(cfg, stacked)
    if cfg.moe is not None:
        specs["moe"] = moe_specs(cfg, stacked)
    else:
        specs["mlp"] = mlp_specs(cfg, stacked)
    return specs


def model_specs(cfg: ModelConfig) -> dict:
    dt = cfg.dtype
    specs: dict = {
        "embed": embedding_spec(cfg.vocab_size, cfg.d_model, dt),
        "final_norm": norm_spec(cfg.d_model, dt),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = ParamSpec((cfg.d_model, cfg.vocab_size),
                                     ("embed", "vocab"), init="scaled",
                                     dtype=dt)
    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // every
        specs["layers"] = {
            "ssm": ssm_specs(cfg, stacked=n_super * every),
            "ln": ParamSpec((n_super * every, cfg.d_model),
                            ("layers", "norm"), init="ones", dtype=dt),
        }
        # one SHARED attention block (Zamba2): reused by every super-block
        specs["shared_attn"] = {
            "attn": attention_specs(cfg, stacked=0),
            "ln1": norm_spec(cfg.d_model, dt),
            "ln2": norm_spec(cfg.d_model, dt),
            "mlp": mlp_specs(cfg, stacked=0),
        }
    else:
        specs["layers"] = _layer_specs(cfg, stacked=cfg.num_layers)
    return specs


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------

def _layer_window(cfg: ModelConfig, layer_idx: jax.Array):
    """Per-layer sliding window (dynamic scalar; 0 = full attention)."""
    if cfg.local_global_pattern > 0:
        # gemma2: even layers local (window), odd layers global
        is_local = (layer_idx % cfg.local_global_pattern) == 0
        return jnp.where(is_local, cfg.sliding_window, 0)
    return cfg.sliding_window


def attn_block(cfg: ModelConfig, lp: dict, h: jax.Array,
               positions: jax.Array, layer_idx, mrope_positions=None):
    x = rms_norm(h, lp["ln1"], cfg.rms_eps)
    if cfg.mla is not None:
        y = mla_forward(cfg, lp["attn"], x, positions)
    else:
        y = gqa_forward(cfg, lp["attn"], x, positions,
                        layer_window=_layer_window(cfg, layer_idx),
                        mrope_positions=mrope_positions)
    h = h + shard_act(y, ("batch", "seq", "embed"))
    x = rms_norm(h, lp["ln2"], cfg.rms_eps)
    if cfg.moe is not None:
        fwd = moe_forward_ep if cfg.moe_ep_shardmap else moe_forward
        y = fwd(cfg, lp["moe"], x)
    else:
        y = mlp_forward(cfg, lp["mlp"], x)
    return h + shard_act(y, ("batch", "seq", "embed"))


def ssm_block(cfg: ModelConfig, lp: dict, h: jax.Array):
    x = rms_norm(h, lp["ln"], cfg.rms_eps)
    y, _, _ = mamba2_forward(cfg, lp["ssm"], x)
    return h + shard_act(y, ("batch", "seq", "embed"))


# --------------------------------------------------------------------------
# embedding / head
# --------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    if cfg.frontend == "stub":
        h = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        if cfg.tie_embeddings:
            h = h * math.sqrt(cfg.d_model)
    return shard_act(h, ("batch", "seq", "embed"))


def _logits(cfg: ModelConfig, params: dict, h: jax.Array) -> jax.Array:
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    table = (params["embed"].T if cfg.tie_embeddings
             else params["lm_head"])
    logits = jnp.einsum("bsd,dv->bsv", h, table,
                        preferred_element_type=jnp.float32)
    logits = softcap(logits, cfg.final_logit_softcap)
    return shard_act(logits, ("batch", "seq", "vocab"))


def _positions(batch: dict) -> jax.Array:
    if "positions" in batch:
        return batch["positions"]
    lead = batch["tokens"].shape if "tokens" in batch \
        else batch["embeds"].shape[:2]
    b, s = lead[0], lead[1]
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))


# --------------------------------------------------------------------------
# forward (train / eval)
# --------------------------------------------------------------------------

def _scan_layers(cfg: ModelConfig, params: dict, h: jax.Array,
                 positions: jax.Array, mrope_positions=None) -> jax.Array:
    lp = params["layers"]

    if cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // every
        stacked = jax.tree.map(
            lambda x: x.reshape(n_super, every, *x.shape[1:]), lp)
        shared = params["shared_attn"]

        def super_block(carry, xs):
            hh = carry

            def inner(c, xp):
                x = rms_norm(c, xp["ln"], cfg.rms_eps)
                y, _, _ = mamba2_forward(cfg, xp["ssm"], x)
                return c + y, None

            hh, _ = jax.lax.scan(inner, hh, xs)
            hh = attn_block(cfg, shared, hh, positions,
                            jnp.int32(1))          # shared global attention
            return hh, None

        body = super_block
        if cfg.remat == "full":
            body = jax.checkpoint(body)
        h, _ = jax.lax.scan(body, h, stacked)
        return h

    idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)

    def block(carry, xs):
        layer_params, layer_idx = xs
        if cfg.family == "ssm":
            out = ssm_block(cfg, layer_params, carry)
        else:
            out = attn_block(cfg, layer_params, carry, positions,
                             layer_idx, mrope_positions)
        return out, None

    if not cfg.scan_layers:
        # python-unrolled stack (profiling-friendly: per-layer regions in
        # the raw export, separable at optimization_barrier boundaries)
        for i in range(cfg.num_layers):
            lp_i = jax.tree.map(lambda x: x[i], lp)
            h, _ = block(h, (lp_i, jnp.int32(i)))
            if cfg.layer_barriers:
                h = barrier(h)
        return h

    body = block
    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, (lp, idxs))
    return h


def forward(cfg: ModelConfig, params: dict, batch: dict):
    """Returns (loss, logits). batch: tokens/embeds, targets, [positions].

    With ``loss_vocab_chunk`` > 0 the CE loss streams over vocab chunks and
    full logits are never materialized (logits return value is None)."""
    h = _embed(cfg, params, batch)
    positions = _positions(batch)
    mrope = batch.get("mrope_positions")
    h = _scan_layers(cfg, params, h, positions, mrope)
    if cfg.loss_vocab_chunk > 0:
        loss = chunked_cross_entropy(cfg, params, h, batch["targets"],
                                     cfg.loss_vocab_chunk)
        return loss, None
    logits = _logits(cfg, params, h)
    loss = cross_entropy(logits, batch["targets"])
    if cfg.moe is not None:
        # router aux loss on the mean hidden state (cheap proxy; per-layer
        # aux would need scan ys — tracked as beyond-paper TODO)
        loss = loss + 0.0
    return loss, logits


def chunked_cross_entropy(cfg: ModelConfig, params: dict, h: jax.Array,
                          targets: jax.Array, chunk: int) -> jax.Array:
    """Streaming softmax CE: scan over vocab chunks, tracking the running
    max/sum-exp and the gold-token logit.  Peak memory drops from
    O(B·S·V) f32 to O(B·S·chunk); flops are unchanged."""
    h = rms_norm(h, params["final_norm"], cfg.rms_eps)
    table = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    d, v = table.shape
    n_chunks = -(-v // chunk)
    pad = n_chunks * chunk - v
    if pad:
        table = jnp.pad(table, ((0, 0), (0, pad)))
    tc = table.reshape(d, n_chunks, chunk).transpose(1, 0, 2)  # [C, d, ck]
    b, s, _ = h.shape
    tgt = targets.astype(jnp.int32)

    def body(carry, inp):
        m, l, gold = carry
        ci, tbl = inp
        logits = jnp.einsum("bsd,dv->bsv", h, tbl,
                            preferred_element_type=jnp.float32)
        logits = softcap(logits, cfg.final_logit_softcap)
        base = ci * chunk
        valid = (base + jnp.arange(chunk)) < v
        logits = jnp.where(valid[None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        l_new = l * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(axis=-1)
        in_chunk = (tgt >= base) & (tgt < base + chunk)
        idx = jnp.clip(tgt - base, 0, chunk - 1)
        g = jnp.take_along_axis(logits, idx[..., None], axis=-1)[..., 0]
        gold = jnp.where(in_chunk, g, gold)
        return (m_new, l_new, gold), None

    m0 = jnp.full((b, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, s), jnp.float32)
    g0 = jnp.zeros((b, s), jnp.float32)
    (m, l, gold), _ = jax.lax.scan(
        body, (m0, l0, g0), (jnp.arange(n_chunks), tc))
    lse = m + jnp.log(l)
    return jnp.mean(lse - gold)


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Stable softmax CE, mean over tokens. logits: [B,S,V] f32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, targets[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# --------------------------------------------------------------------------
# inference: prefill + decode
# --------------------------------------------------------------------------

def cache_shapes(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    """Abstract KV/SSM cache description for one device-visible batch."""
    hd = cfg.resolved_head_dim if cfg.num_heads else 0
    shapes: dict = {"index": ((), "int32", ())}
    if cfg.family == "ssm":
        s = cfg.ssm
        nh, di = s.n_heads(cfg.d_model), s.d_inner(cfg.d_model)
        conv_ch = di + 2 * s.n_groups * s.d_state
        shapes["ssm_state"] = (
            (cfg.num_layers, batch_size, nh, s.head_dim, s.d_state),
            "float32", ("layers", "batch", "ssm_heads", "qk_dim", "ssm_state"))
        shapes["conv_state"] = (
            (cfg.num_layers, batch_size, s.d_conv - 1, conv_ch),
            cfg.dtype, ("layers", "batch", "conv", "ssm_inner"))
        return shapes
    if cfg.family == "hybrid":
        s = cfg.ssm
        nh, di = s.n_heads(cfg.d_model), s.d_inner(cfg.d_model)
        conv_ch = di + 2 * s.n_groups * s.d_state
        n_super = cfg.num_layers // cfg.hybrid_attn_every
        shapes["ssm_state"] = (
            (cfg.num_layers, batch_size, nh, s.head_dim, s.d_state),
            "float32", ("layers", "batch", "ssm_heads", "qk_dim", "ssm_state"))
        shapes["conv_state"] = (
            (cfg.num_layers, batch_size, s.d_conv - 1, conv_ch),
            cfg.dtype, ("layers", "batch", "conv", "ssm_inner"))
        shapes["k"] = ((n_super, batch_size, max_len, cfg.num_kv_heads, hd),
                       cfg.dtype,
                       ("layers", "batch", "cache_seq", "kv_heads", "qk_dim"))
        shapes["v"] = ((n_super, batch_size, max_len, cfg.num_kv_heads, hd),
                       cfg.dtype,
                       ("layers", "batch", "cache_seq", "kv_heads", "v_dim"))
        return shapes
    if cfg.mla is not None:
        m = cfg.mla
        shapes["ckv"] = (
            (cfg.num_layers, batch_size, max_len,
             m.kv_lora_rank + m.qk_rope_head_dim),
            cfg.dtype, ("layers", "batch", "cache_seq", "lora"))
        return shapes
    eff_len = min(max_len, cfg.sliding_window) if cfg.sliding_window \
        else max_len
    shapes["k"] = ((cfg.num_layers, batch_size, eff_len,
                    cfg.num_kv_heads, hd), cfg.dtype,
                   ("layers", "batch", "cache_seq", "kv_heads", "qk_dim"))
    shapes["v"] = ((cfg.num_layers, batch_size, eff_len,
                    cfg.num_kv_heads, hd), cfg.dtype,
                   ("layers", "batch", "cache_seq", "kv_heads", "v_dim"))
    return shapes


def init_cache_specs(cfg: ModelConfig, batch_size: int, max_len: int) -> dict:
    return {name: ParamSpec(shape, axes, init="zeros", dtype=dtype)
            for name, (shape, dtype, axes)
            in cache_shapes(cfg, batch_size, max_len).items()}


def decode_step(cfg: ModelConfig, params: dict, cache: dict, batch: dict):
    """One autoregressive step. batch: tokens [B,1] (or embeds [B,1,d]).

    The cache index is carried inside ``cache["index"]``; caches are stacked
    on the layer axis and updated through the layer scan.
    """
    h = _embed(cfg, params, batch)
    index = cache["index"]
    b = h.shape[0]

    if cfg.family == "ssm":
        def block(carry, xs):
            hh = carry
            lp, sstate, cstate = xs
            x = rms_norm(hh, lp["ln"], cfg.rms_eps)
            y, new_s, new_c = mamba2_forward(
                cfg, lp["ssm"], x, ssm_state=sstate, conv_state=cstate,
                decode=True)
            return hh + y, (new_s, new_c)

        h, (new_ssm, new_conv) = jax.lax.scan(
            block, h,
            ({"ssm": params["layers"]["ssm"], "ln": params["layers"]["ln"]},
             cache["ssm_state"], cache["conv_state"]))
        new_cache = dict(cache, ssm_state=new_ssm, conv_state=new_conv,
                         index=index + 1)
    elif cfg.family == "hybrid":
        every = cfg.hybrid_attn_every
        n_super = cfg.num_layers // every
        stacked = jax.tree.map(
            lambda x: x.reshape(n_super, every, *x.shape[1:]),
            params["layers"])
        sstates = jax.tree.map(
            lambda x: x.reshape(n_super, every, *x.shape[1:]),
            cache["ssm_state"])
        cstates = jax.tree.map(
            lambda x: x.reshape(n_super, every, *x.shape[1:]),
            cache["conv_state"])
        shared = params["shared_attn"]

        def super_block(carry, xs):
            hh = carry
            sp, sst, cst, ck, cv = xs

            def inner(c, xp):
                lp, s1, c1 = xp
                x = rms_norm(c, lp["ln"], cfg.rms_eps)
                y, ns, nc = mamba2_forward(cfg, lp["ssm"], x, ssm_state=s1,
                                           conv_state=c1, decode=True)
                return c + y, (ns, nc)

            hh, (ns, nc) = jax.lax.scan(inner, hh, (sp, sst, cst))
            x = rms_norm(hh, shared["ln1"], cfg.rms_eps)
            y, nk, nv = gqa_decode(cfg, shared["attn"], x, ck, cv, index)
            hh = hh + y
            x = rms_norm(hh, shared["ln2"], cfg.rms_eps)
            hh = hh + mlp_forward(cfg, shared["mlp"], x)
            return hh, (ns, nc, nk, nv)

        h, (ns, nc, nk, nv) = jax.lax.scan(
            super_block, h, (stacked, sstates, cstates,
                             cache["k"], cache["v"]))
        new_cache = dict(
            cache,
            ssm_state=ns.reshape(cfg.num_layers, *ns.shape[2:]),
            conv_state=nc.reshape(cfg.num_layers, *nc.shape[2:]),
            k=nk, v=nv, index=index + 1)
    elif cfg.mla is not None:
        idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)

        def block(carry, xs):
            hh = carry
            lp, ckv, _ = xs
            x = rms_norm(hh, lp["ln1"], cfg.rms_eps)
            y, new_ckv = mla_decode(cfg, lp["attn"], x, ckv, index)
            hh = hh + y
            x = rms_norm(hh, lp["ln2"], cfg.rms_eps)
            if cfg.moe is not None:
                fwd = moe_forward_ep if cfg.moe_ep_shardmap else moe_forward
                hh = hh + fwd(cfg, lp["moe"], x)
            else:
                hh = hh + mlp_forward(cfg, lp["mlp"], x)
            return hh, new_ckv

        h, new_ckv = jax.lax.scan(
            block, h, (params["layers"], cache["ckv"], idxs))
        new_cache = dict(cache, ckv=new_ckv, index=index + 1)
    else:
        idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)

        def block(carry, xs):
            hh = carry
            lp, ck, cv, layer_idx = xs
            x = rms_norm(hh, lp["ln1"], cfg.rms_eps)
            window = _layer_window(cfg, layer_idx)
            y, nk, nv = gqa_decode(cfg, lp["attn"], x, ck, cv, index,
                                   layer_window=window)
            hh = hh + y
            x = rms_norm(hh, lp["ln2"], cfg.rms_eps)
            if cfg.moe is not None:
                fwd = moe_forward_ep if cfg.moe_ep_shardmap else moe_forward
                hh = hh + fwd(cfg, lp["moe"], x)
            else:
                hh = hh + mlp_forward(cfg, lp["mlp"], x)
            return hh, (nk, nv)

        h, (nk, nv) = jax.lax.scan(
            block, h, (params["layers"], cache["k"], cache["v"], idxs))
        new_cache = dict(cache, k=nk, v=nv, index=index + 1)

    logits = _logits(cfg, params, h)
    return logits[:, -1], new_cache


def prefill(cfg: ModelConfig, params: dict, batch: dict):
    """Process a full prompt; returns last-token logits.

    (Cache materialization from prefill is family-specific; for workload
    export purposes the compute graph of the forward pass is the prefill
    cost — the cache write adds only bandwidth, modeled in the estimators.)
    """
    h = _embed(cfg, params, batch)
    positions = _positions(batch)
    h = _scan_layers(cfg, params, h, positions,
                     batch.get("mrope_positions"))
    logits = _logits(cfg, params, h)
    return logits[:, -1]
