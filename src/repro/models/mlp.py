"""MLPs: gated (SwiGLU/GeGLU) dense blocks and sort-based MoE.

The MoE dispatch is sort-based rather than one-hot-einsum based: tokens are
ordered by expert id with argsort and moved with zero-FLOP gather/scatter,
then each expert runs a capacity-padded grouped GEMM.  This keeps the
compiled HLO's FLOP count ≈ the model's active FLOPs (one-hot dispatch
einsums would add a tokens × E·C × d_model matmul *per layer* that
dominates the real expert compute at E=256 — visible garbage in the
roofline's MODEL_FLOPS/HLO_FLOPS ratio).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import constrain
from .common import activation, dense
from .params import ParamSpec


def mlp_specs(cfg: ModelConfig, stacked: int = 0, d_ff: int | None = None,
              suffix: str = "") -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    dt = cfg.dtype

    def p(shape, axes):
        if stacked:
            return ParamSpec((stacked, *shape), ("layers", *axes),
                             init="scaled", dtype=dt)
        return ParamSpec(shape, axes, init="scaled", dtype=dt)

    return {
        f"w_gate{suffix}": p((d, f), ("embed", "mlp")),
        f"w_up{suffix}": p((d, f), ("embed", "mlp")),
        f"w_down{suffix}": p((f, d), ("mlp", "embed")),
    }


def mlp_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                suffix: str = "") -> jax.Array:
    act = activation(cfg.act)
    g = act(dense(x, p[f"w_gate{suffix}"]))
    u = dense(x, p[f"w_up{suffix}"])
    return dense(g * u, p[f"w_down{suffix}"])


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------

def moe_specs(cfg: ModelConfig, stacked: int = 0) -> dict:
    mo = cfg.moe
    d, dt = cfg.d_model, cfg.dtype
    e, f = mo.num_experts, mo.d_ff_expert

    def p(shape, axes, **kw):
        if stacked:
            return ParamSpec((stacked, *shape), ("layers", *axes),
                             dtype=dt, **kw)
        return ParamSpec(shape, axes, dtype=dt, **kw)

    specs = {
        "router": p((d, e), ("embed", "experts"), init="scaled"),
        "we_gate": p((e, d, f), ("experts", "embed", "mlp_expert"),
                     init="scaled"),
        "we_up": p((e, d, f), ("experts", "embed", "mlp_expert"),
                   init="scaled"),
        "we_down": p((e, f, d), ("experts", "mlp_expert", "embed"),
                     init="scaled"),
    }
    if mo.num_shared_experts:
        fs = mo.d_ff_shared or f
        specs.update(mlp_specs(
            cfg, stacked=stacked, d_ff=fs * mo.num_shared_experts,
            suffix="_shared"))
    return specs


def _capacity(tokens: int, mo) -> int:
    c = int(tokens * mo.top_k * mo.capacity_factor / mo.num_experts)
    return max(8, -(-c // 8) * 8)   # multiple of 8, >= 8


def moe_forward(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Sort-based top-k MoE. x: [B, S, d] -> [B, S, d].

    1. route: logits -> top-k experts/weights per token;
    2. sort the (token, k) assignment list by expert id (argsort);
    3. scatter tokens into an [E, C, d] capacity buffer (zero-FLOP);
    4. grouped GEMMs over the expert axis (sharded: expert parallelism);
    5. gather back and combine with routing weights.

    Tokens beyond an expert's capacity are dropped (standard capacity-based
    MoE; the residual path carries them).
    """
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = mo.top_k
    e = mo.num_experts
    cap = _capacity(t, mo)
    xf = x.reshape(t, d)

    router_dt = jnp.float32 if mo.router_dtype == "float32" else x.dtype
    logits = dense(xf.astype(router_dt), p["router"].astype(router_dt),
                   accum_f32=False)                       # [T, E]
    if cfg.name.startswith("deepseek-v3"):
        scores = jax.nn.sigmoid(logits)                    # DSv3 sigmoid gate
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, k)                # [T, k]
    if cfg.name.startswith("deepseek-v3"):
        top_w = top_w / (top_w.sum(-1, keepdims=True) + 1e-20)

    def shard(arr, axes):
        return constrain(arr, axes) if cfg.moe_dispatch_sharding else arr

    xf = shard(xf, ("batch", "embed"))

    # ---- assignment list, sorted by expert ----
    flat_e = top_e.reshape(t * k)                          # expert of slot i
    flat_w = top_w.reshape(t * k)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    order = jnp.argsort(flat_e)                            # stable
    se, sw, stok = flat_e[order], flat_w[order], flat_tok[order]
    # position within expert segment
    counts = jnp.bincount(se, length=e)                    # [E]
    starts = jnp.cumsum(counts) - counts                   # segment starts
    pos_in_e = jnp.arange(t * k) - starts[se]              # [T*k]
    keep = pos_in_e < cap
    slot = se * cap + jnp.where(keep, pos_in_e, 0)

    # ---- scatter tokens into [E*C, d] (zero-FLOP data movement) ----
    gathered = jnp.take(xf, stok, axis=0)                  # [T*k, d]
    gathered = jnp.where(keep[:, None], gathered, 0)
    gathered = shard(gathered, ("batch", "embed"))
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[slot].add(gathered)                       # scatter-add
    buf = buf.reshape(e, cap, d)
    buf = shard(buf, ("experts", "seq", "embed"))

    # ---- grouped expert GEMMs (expert axis sharded = EP) ----
    act = activation(cfg.act)
    g = jnp.einsum("ecd,edf->ecf", buf, p["we_gate"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    u = jnp.einsum("ecd,edf->ecf", buf, p["we_up"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = jnp.einsum("ecf,efd->ecd", act(g) * u, p["we_down"],
                   preferred_element_type=jnp.float32).astype(x.dtype)
    y = shard(y, ("experts", "seq", "embed")).reshape(e * cap, d)

    # ---- gather back, weighted combine ----
    out_tokens = jnp.take(y, slot, axis=0)                 # [T*k, d]
    out_tokens = out_tokens * (sw * keep)[:, None].astype(x.dtype)
    out_tokens = shard(out_tokens, ("batch", "embed"))
    out = jnp.zeros((t, d), x.dtype).at[stok].add(out_tokens)
    out = shard(out, ("batch", "embed"))

    if mo.num_shared_experts:
        out = out + mlp_forward(cfg, p, xf, suffix="_shared")
    return out.reshape(b, s, d)


# --------------------------------------------------------------------------
# explicit expert parallelism (beyond-paper §Perf optimization)
# --------------------------------------------------------------------------

def moe_forward_ep(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Expert-parallel MoE via shard_map: the partitioner-free dispatch.

    Auto-partitioning the sort-based dispatch lets GSPMD bounce the
    token-major buffers between the data and model axes (measured: ~49 TB
    of all-reduce per chip per step on mixtral-8x22b x train_4k).  Here the
    data flow is explicit and communication-minimal:

      * routing (small GEMM + top-k) runs auto-sharded outside;
      * scheme A (E % |model| == 0, e.g. deepseek-v3 256e/16): each model
        shard builds capacity buffers ONLY for its own E/|model| experts
        from ONLY its own token shard — zero dispatch communication;
      * scheme B (E < |model|, |model| % ... via d_ff % |model| == 0, e.g.
        mixtral 8e/16): every shard processes all experts on its d_ff
        slice (expert-FFN tensor parallelism) — zero dispatch
        communication as well;
      * in both schemes one psum over "model" combines partial token
        outputs — the information-theoretic minimum for the combine.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from ..distributed.sharding import get_abstract_mesh_or_none

    mesh = get_abstract_mesh_or_none()
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = mo.top_k
    e = mo.num_experts
    f = mo.d_ff_expert
    if mesh is None or "model" not in mesh.axis_names:
        return moe_forward(cfg, p, x)
    n_model = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
    if e % n_model == 0:
        scheme = "expert"
    elif f % n_model == 0:
        scheme = "ffn"
    else:
        return moe_forward(cfg, p, x)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in data_axes:
        n_data *= dict(zip(mesh.axis_names, mesh.devices.shape))[a]
    if t % max(n_data, 1) != 0:
        return moe_forward(cfg, p, x)

    xf = x.reshape(t, d)
    router_dt = jnp.float32 if mo.router_dtype == "float32" else x.dtype
    logits = dense(xf.astype(router_dt), p["router"].astype(router_dt),
                   accum_f32=False)
    if cfg.name.startswith("deepseek-v3"):
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(scores, k)
    if cfg.name.startswith("deepseek-v3"):
        top_w = top_w / (top_w.sum(-1, keepdims=True) + 1e-20)
    top_w = top_w.astype(x.dtype)

    t_local = t // max(n_data, 1)
    e_local = e // n_model if scheme == "expert" else e
    cap = max(8, -(-int(t_local * k * mo.capacity_factor / e) // 8) * 8)
    dspec = data_axes if len(data_axes) > 1 else (
        data_axes[0] if data_axes else None)

    def local(xf_l, tw_l, te_l, wg_l, wu_l, wd_l):
        tl = xf_l.shape[0]
        flat_e = te_l.reshape(tl * k)
        flat_w = tw_l.reshape(tl * k)
        flat_tok = jnp.repeat(jnp.arange(tl), k)
        if scheme == "expert":
            midx = jax.lax.axis_index("model")
            lo = midx * e_local
            mine = (flat_e >= lo) & (flat_e < lo + e_local)
            le = jnp.where(mine, flat_e - lo, e_local)   # e_local = discard
        else:
            mine = jnp.ones_like(flat_e, dtype=bool)
            le = flat_e
        order = jnp.argsort(le)
        se, sw, stok = le[order], flat_w[order], flat_tok[order]
        counts = jnp.bincount(se, length=e_local + 1)
        starts = jnp.cumsum(counts) - counts
        pos = jnp.arange(tl * k) - starts[se]
        keep = (pos < cap) & (se < e_local)
        slot = jnp.where(se < e_local, se, 0) * cap + \
            jnp.where(keep, pos, 0)
        gathered = jnp.take(xf_l, stok, axis=0)
        gathered = jnp.where(keep[:, None], gathered, 0)
        buf = jnp.zeros((e_local * cap, d), x.dtype)
        buf = buf.at[slot].add(gathered).reshape(e_local, cap, d)
        act = activation(cfg.act)
        g = jnp.einsum("ecd,edf->ecf", buf, wg_l,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_l,
                       preferred_element_type=jnp.float32).astype(x.dtype)
        yy = jnp.einsum("ecf,efd->ecd", act(g) * u, wd_l,
                        preferred_element_type=jnp.float32).astype(x.dtype)
        yy = yy.reshape(e_local * cap, d)
        out_tok = jnp.take(yy, slot, axis=0)
        out_tok = out_tok * (sw * keep).astype(x.dtype)[:, None]
        partial = jnp.zeros((tl, d), x.dtype).at[stok].add(out_tok)
        return jax.lax.psum(partial, "model")

    if scheme == "expert":
        wspecs = (P("model", None, None), P("model", None, None),
                  P("model", "mlp_pad", None)
                  if False else P("model", None, None))
        wd_spec = P("model", None, None)
        wg_spec = wu_spec = P("model", None, None)
    else:  # ffn: shard d_ff over the model axis
        wg_spec = wu_spec = P(None, None, "model")
        wd_spec = P(None, "model", None)

    out = shard_map(
        local, mesh=mesh,
        in_specs=(P(dspec, None), P(dspec, None), P(dspec, None),
                  wg_spec, wu_spec, wd_spec),
        out_specs=P(dspec, None),
        check_rep=False,
    )(xf, top_w, top_e, p["we_gate"], p["we_up"], p["we_down"])

    if mo.num_shared_experts:
        out = out + mlp_forward(cfg, p, xf, suffix="_shared")
    return out.reshape(b, s, d)


def moe_aux_loss(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    """Load-balancing auxiliary loss (Switch-style)."""
    mo = cfg.moe
    b, s, d = x.shape
    xf = x.reshape(b * s, d).astype(jnp.float32)
    logits = dense(xf, p["router"].astype(jnp.float32), accum_f32=False)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top1, mo.num_experts, dtype=jnp.float32), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    return mo.num_experts * jnp.sum(frac_tokens * frac_probs)
