"""Mamba2 / SSD (state-space duality) blocks — arXiv:2405.21060.

Training/prefill uses the chunked dual form: intra-chunk attention-like
matmuls (MXU-friendly — this is the Pallas kernel target) plus an
inter-chunk state recurrence.  Decode uses the O(1) recurrent update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, SSMConfig
from .common import dense, rms_norm
from .params import ParamSpec


def ssm_specs(cfg: ModelConfig, stacked: int = 0) -> dict:
    s: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    g, n = s.n_groups, s.d_state
    conv_ch = di + 2 * g * n
    dt = cfg.dtype

    def p(shape, axes, **kw):
        if stacked:
            return ParamSpec((stacked, *shape), ("layers", *axes),
                             dtype=dt, **kw)
        return ParamSpec(shape, axes, dtype=dt, **kw)

    return {
        # projects to [z, x, B, C, dt]
        "in_proj": p((d, 2 * di + 2 * g * n + nh), ("embed", "ssm_inner"),
                     init="scaled"),
        "conv_w": p((s.d_conv, conv_ch), ("conv", "ssm_inner"),
                    init="scaled"),
        "conv_b": p((conv_ch,), ("ssm_inner",), init="zeros"),
        "a_log": ParamSpec((stacked, nh) if stacked else (nh,),
                           ("layers", "ssm_heads") if stacked
                           else ("ssm_heads",), init="ssm_a", dtype="float32"),
        "dt_bias": p((nh,), ("ssm_heads",), init="zeros"),
        "d_skip": p((nh,), ("ssm_heads",), init="ones"),
        "out_norm": p((di,), ("norm",), init="ones"),
        "out_proj": p((di, d), ("ssm_inner", "embed"), init="scaled"),
    }


def _segsum(x: jax.Array) -> jax.Array:
    """Stable segment-sum: out[..., i, j] = sum_{k=j+1..i} x[..., k]."""
    l = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
                c_in: jax.Array, chunk: int,
                initial_state: jax.Array | None = None,
                use_pallas: bool = False):
    """SSD dual form.

    x:  [B, S, H, P]  (P = head dim)
    dt: [B, S, H]     (positive step sizes)
    a:  [H]           (negative decay rates)
    b_in, c_in: [B, S, G, N]
    Returns (y [B, S, H, P], final_state [B, H, P, N]).
    """
    if use_pallas:
        try:
            from ..kernels.ssd_scan.ops import ssd_scan
            return ssd_scan(x, dt, a, b_in, c_in, chunk=chunk,
                            initial_state=initial_state)
        except Exception:
            pass
    bsz, s, h, p = x.shape
    g, n = b_in.shape[2], b_in.shape[3]
    nc = s // chunk
    assert nc * chunk == s, f"seq {s} not divisible by chunk {chunk}"
    hpg = h // g
    f32 = jnp.float32

    # [B, C, L, ...] chunked views
    xc = x.reshape(bsz, nc, chunk, h, p).astype(f32)
    dtc = dt.reshape(bsz, nc, chunk, h).astype(f32)
    bc = b_in.reshape(bsz, nc, chunk, g, n).astype(f32)
    cc = c_in.reshape(bsz, nc, chunk, g, n).astype(f32)
    da = dtc * a.astype(f32)[None, None, None, :]         # [B,C,L,H]
    da_cs = jnp.cumsum(da, axis=2)                        # within-chunk cumsum
    da_total = da_cs[:, :, -1]                            # [B,C,H]

    # expand groups to heads for score contractions
    bh = jnp.repeat(bc, hpg, axis=3)                      # [B,C,L,H,N]
    ch = jnp.repeat(cc, hpg, axis=3)

    # ---- intra-chunk (dual / attention-like) ----
    lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))     # [B,C,H,L,L]
    scores = jnp.einsum("bclhn,bcshn->bchls", ch, bh)     # [B,C,H,L,S]
    scores = scores * lmat
    xdt = xc * dtc[..., None]                             # dt-weighted input
    y_diag = jnp.einsum("bchls,bcshp->bclhp", scores, xdt)

    # ---- chunk states ----
    decay_to_end = jnp.exp(da_total[:, :, None, :] - da_cs)  # [B,C,L,H]
    states = jnp.einsum("bclhn,bclh,bclhp->bchpn", bh, decay_to_end * dtc, xc)

    # ---- inter-chunk recurrence ----
    def step(carry, inp):
        st, = (carry,)
        s_c, da_tot = inp
        new = st * jnp.exp(da_tot)[:, :, None, None] + s_c
        return new, st                                   # emit state BEFORE chunk

    init = (jnp.zeros((bsz, h, p, n), f32) if initial_state is None
            else initial_state.astype(f32))
    final, prev_states = jax.lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), da_total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # [B,C,H,P,N]

    # ---- inter-chunk contribution ----
    decay_from_start = jnp.exp(da_cs)                     # [B,C,L,H]
    y_off = jnp.einsum("bclhn,bchpn,bclh->bclhp",
                       ch, prev_states, decay_from_start)

    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(x, dt, a, b_in, c_in, state):
    """Recurrent update for one token.

    x: [B, 1, H, P], dt: [B, 1, H], b_in/c_in: [B, 1, G, N],
    state: [B, H, P, N] -> (y [B,1,H,P], new_state)."""
    bsz, _, h, p = x.shape
    g = b_in.shape[2]
    hpg = h // g
    f32 = jnp.float32
    da = (dt[:, 0].astype(f32) * a.astype(f32)[None, :])  # [B,H]
    bh = jnp.repeat(b_in[:, 0], hpg, axis=1).astype(f32)  # [B,H,N]
    chh = jnp.repeat(c_in[:, 0], hpg, axis=1).astype(f32)
    xdt = (x[:, 0].astype(f32) * dt[:, 0, :, None].astype(f32))  # [B,H,P]
    new_state = (state.astype(f32) * jnp.exp(da)[:, :, None, None]
                 + jnp.einsum("bhn,bhp->bhpn", bh, xdt))
    y = jnp.einsum("bhpn,bhn->bhp", new_state, chh)
    return y[:, None].astype(x.dtype), new_state


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, S, C]; w: [K, C]."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(k):
        out = out + xp[:, i:i + x.shape[1]].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return (out + b.astype(jnp.float32)).astype(x.dtype)


def mamba2_forward(cfg: ModelConfig, p: dict, hidden: jax.Array,
                   ssm_state: jax.Array | None = None,
                   conv_state: jax.Array | None = None,
                   decode: bool = False):
    """Full Mamba2 block. hidden: [B, S, d].

    Train/prefill: decode=False, states None -> returns (y, final_states).
    Decode: decode=True with states -> one-token update.
    """
    s_cfg: SSMConfig = cfg.ssm
    d = cfg.d_model
    di = s_cfg.d_inner(d)
    nh = s_cfg.n_heads(d)
    g, n = s_cfg.n_groups, s_cfg.d_state
    bsz, s, _ = hidden.shape

    zxbcdt = dense(hidden, p["in_proj"])
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, di + di + 2 * g * n], axis=-1)
    if decode:
        # rolling conv state: [B, K-1, conv_ch]
        conv_in = jnp.concatenate([conv_state, xbc], axis=1)
        new_conv_state = conv_in[:, 1:]
        k = p["conv_w"].shape[0]
        xbc_conv = jnp.einsum("bkc,kc->bc", conv_in[:, -k:],
                              p["conv_w"].astype(jnp.float32)) \
            + p["conv_b"].astype(jnp.float32)
        xbc_conv = jax.nn.silu(xbc_conv)[:, None].astype(hidden.dtype)
    else:
        xbc_conv = jax.nn.silu(
            _causal_conv(xbc, p["conv_w"], p["conv_b"]))
        new_conv_state = xbc[:, -(p["conv_w"].shape[0] - 1):]

    x_in, b_in, c_in = jnp.split(xbc_conv, [di, di + g * n], axis=-1)
    x_in = x_in.reshape(bsz, s, nh, s_cfg.head_dim)
    b_in = b_in.reshape(bsz, s, g, n)
    c_in = c_in.reshape(bsz, s, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    if decode:
        y, new_state = ssd_decode_step(x_in, dt, a, b_in, c_in, ssm_state)
    else:
        y, new_state = ssd_chunked(
            x_in, dt, a, b_in, c_in, chunk=min(s_cfg.chunk_size, s),
            initial_state=ssm_state,
            use_pallas=cfg.attn_impl == "pallas")
    y = y + x_in * p["d_skip"].astype(hidden.dtype)[None, None, :, None]
    y = y.reshape(bsz, s, di)
    y = rms_norm(y * jax.nn.silu(z), p["out_norm"], cfg.rms_eps)
    out = dense(y, p["out_proj"])
    return out, new_state, new_conv_state
