"""Shared building blocks: norms, activations, embeddings, positional enc."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..distributed.sharding import constrain
from .params import ParamSpec


def rms_norm(x: jax.Array, weight: jax.Array, eps: float = 1e-5,
             offset: float = 0.0) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (offset + weight.astype(jnp.float32))).astype(dtype)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True),
            "relu": jax.nn.relu}[name]


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0:
        return logits
    return cap * jnp.tanh(logits / cap)


@jax.custom_vjp
def barrier(x):
    """Differentiable optimization_barrier.

    jax < 0.5 has no differentiation rule for the primitive; this wrapper
    barriers both the primal and the cotangents, which is what newer jax
    does natively — per-layer region boundaries survive in both the
    forward and backward segments of the export.

    0.4.x compat shim: retire (use jax.lax.optimization_barrier directly)
    when the repo's jax floor moves to >= 0.6."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return jax.lax.optimization_barrier(x), None


def _barrier_bwd(_, g):
    return (jax.lax.optimization_barrier(g),)


barrier.defvjp(_barrier_fwd, _barrier_bwd)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None,
          accum_f32: bool = True) -> jax.Array:
    """x:[..., in] @ w:[in, out]; accumulates in f32 on the MXU."""
    pet = jnp.float32 if accum_f32 else None
    y = jax.lax.dot_general(
        x, w, (((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=pet)
    if b is not None:
        y = y + b.astype(y.dtype)
    return y.astype(x.dtype)


def embed_lookup(tokens: jax.Array, table: jax.Array) -> jax.Array:
    """Token embedding via one-hot matmul (TPU-friendly gather)."""
    return jnp.take(table, tokens, axis=0)


def embedding_spec(vocab: int, d_model: int, dtype: str) -> ParamSpec:
    return ParamSpec((vocab, d_model), ("vocab", "embed"),
                     init="normal", dtype=dtype)


def norm_spec(d: int, dtype: str) -> ParamSpec:
    return ParamSpec((d,), ("norm",), init="ones", dtype=dtype)


def shard_act(x, axes):
    return constrain(x, axes)
