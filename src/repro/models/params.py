"""Parameter specification trees.

Models declare parameters as ParamSpec pytrees (shape + dtype + logical
axes + initializer).  The same tree serves three purposes:

  * dry-run: ShapeDtypeStructs with NamedShardings (no allocation);
  * training: materialized, sharded initialization;
  * checkpointing: stable flattened names.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[str, ...]
    init: str = "normal"        # normal | zeros | ones | scaled | ssm_a
    scale: float = 0.02
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree, prefix: str = "") -> dict[str, ParamSpec]:
    out: dict[str, ParamSpec] = {}
    if is_spec(tree):
        out[prefix.rstrip("/")] = tree
        return out
    for k, v in tree.items():
        out.update(tree_paths(v, f"{prefix}{k}/"))
    return out


def abstract_params(tree, mesh=None, rules=None):
    """ShapeDtypeStruct pytree (optionally sharded) — for .lower()."""
    from ..distributed.sharding import param_sharding

    def one(s: ParamSpec):
        if mesh is not None:
            return jax.ShapeDtypeStruct(
                s.shape, jnp.dtype(s.dtype),
                sharding=param_sharding(s.axes, mesh, rules, s.shape))
        return jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype))

    return jax.tree.map(one, tree, is_leaf=is_spec)


def init_params(tree, key):
    """Materialize parameters (host-side; used by smoke tests/examples)."""
    flat = tree_paths(tree)
    keys = jax.random.split(key, max(len(flat), 1))
    values: dict[str, jax.Array] = {}
    for (name, s), k in zip(sorted(flat.items()), keys):
        dtype = jnp.dtype(s.dtype)
        if s.init == "zeros":
            v = jnp.zeros(s.shape, dtype)
        elif s.init == "ones":
            v = jnp.ones(s.shape, dtype)
        elif s.init == "ssm_a":   # Mamba A_log init: log(uniform[1,16])
            v = jnp.log(jnp.linspace(1.0, 16.0, num=int(np.prod(s.shape)))
                        ).reshape(s.shape).astype(dtype)
        elif s.init == "scaled":  # fan-in scaled normal
            fan_in = s.shape[0] if s.shape else 1
            v = (jax.random.normal(k, s.shape) / math.sqrt(max(fan_in, 1))
                 ).astype(dtype)
        else:
            v = (jax.random.normal(k, s.shape) * s.scale).astype(dtype)
        values[name] = v

    def rebuild(subtree, prefix=""):
        if is_spec(subtree):
            return values[prefix.rstrip("/")]
        return {k: rebuild(v, f"{prefix}{k}/") for k, v in subtree.items()}

    return rebuild(tree)


def param_bytes(tree) -> int:
    total = 0
    for s in tree_paths(tree).values():
        total += int(np.prod(s.shape)) * jnp.dtype(s.dtype).itemsize
    return total


def param_count(tree) -> int:
    return sum(int(np.prod(s.shape)) for s in tree_paths(tree).values())
