from .registry import (ARCH_IDS, EXTRA_IDS, cache_specs_abstract, get_config,
                       get_smoke_config, input_specs, shape_cells, skip_reason)
from .transformer import (cross_entropy, decode_step, forward,
                          init_cache_specs, model_specs, prefill)

__all__ = [
    "ARCH_IDS", "EXTRA_IDS", "cache_specs_abstract", "get_config",
    "get_smoke_config", "input_specs", "shape_cells", "skip_reason",
    "cross_entropy", "decode_step", "forward", "init_cache_specs",
    "model_specs", "prefill",
]
