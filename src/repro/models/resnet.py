"""ResNet v1.5 (18/34/50/101/152/200) in pure JAX — the paper's Fig 7
workload family (data-parallel ResNet training on 4×A100).

BatchNorm uses batch statistics (training mode); running averages are not
tracked (irrelevant for the exported workload graph — only the compute
matters for the performance model)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import barrier
from .params import ParamSpec

_STAGES = {
    18: ("basic", (2, 2, 2, 2)),
    34: ("basic", (3, 4, 6, 3)),
    50: ("bottleneck", (3, 4, 6, 3)),
    101: ("bottleneck", (3, 4, 23, 3)),
    152: ("bottleneck", (3, 8, 36, 3)),
    200: ("bottleneck", (3, 24, 36, 3)),
}


@dataclass(frozen=True)
class ResNetConfig:
    depth: int = 50
    num_classes: int = 1000
    width: int = 64
    dtype: str = "float16"       # paper Table III: FP16
    block_barriers: bool = False  # optimization_barrier between blocks
    #                               (profiling-slicing region boundaries)

    @property
    def block(self) -> str:
        return _STAGES[self.depth][0]

    @property
    def stage_sizes(self) -> tuple[int, ...]:
        return _STAGES[self.depth][1]


def _conv_spec(k, cin, cout, dt):
    return ParamSpec((k, k, cin, cout), ("conv", "conv", "embed", "mlp"),
                     init="scaled", dtype=dt)


def _bn_specs(c, dt):
    return {"scale": ParamSpec((c,), ("norm",), init="ones", dtype=dt),
            "bias": ParamSpec((c,), ("norm",), init="zeros", dtype=dt)}


def resnet_specs(cfg: ResNetConfig) -> dict:
    dt = cfg.dtype
    specs: dict = {"stem": {"conv": _conv_spec(7, 3, cfg.width, dt),
                            "bn": _bn_specs(cfg.width, dt)}}
    cin = cfg.width
    expansion = 4 if cfg.block == "bottleneck" else 1
    for si, n_blocks in enumerate(cfg.stage_sizes):
        cmid = cfg.width * (2 ** si)
        cout = cmid * expansion
        stage: dict = {}
        for bi in range(n_blocks):
            blk: dict = {}
            if cfg.block == "bottleneck":
                blk["conv1"] = _conv_spec(1, cin, cmid, dt)
                blk["bn1"] = _bn_specs(cmid, dt)
                blk["conv2"] = _conv_spec(3, cmid, cmid, dt)
                blk["bn2"] = _bn_specs(cmid, dt)
                blk["conv3"] = _conv_spec(1, cmid, cout, dt)
                blk["bn3"] = _bn_specs(cout, dt)
            else:
                blk["conv1"] = _conv_spec(3, cin, cmid, dt)
                blk["bn1"] = _bn_specs(cmid, dt)
                blk["conv2"] = _conv_spec(3, cmid, cout, dt)
                blk["bn2"] = _bn_specs(cout, dt)
            if cin != cout or bi == 0:
                blk["proj"] = _conv_spec(1, cin, cout, dt)
                blk["proj_bn"] = _bn_specs(cout, dt)
            stage[f"block{bi}"] = blk
            cin = cout
        specs[f"stage{si}"] = stage
    specs["head"] = ParamSpec((cin, cfg.num_classes), ("embed", "vocab"),
                              init="scaled", dtype=dt)
    return specs


def _conv(x, w, stride=1):
    # no preferred_element_type: its conv transpose rule rejects mixed
    # f16/f32 operands on the CPU backend (cotangents stay in input dtype)
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _bn(x, p, eps=1e-5):
    xf = x.astype(jnp.float32)
    mean = xf.mean(axis=(0, 1, 2), keepdims=True)
    var = xf.var(axis=(0, 1, 2), keepdims=True)
    y = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(x.dtype)


def resnet_forward(cfg: ResNetConfig, params: dict, images: jax.Array,
                   labels: jax.Array):
    """images: [B, H, W, 3]; labels: [B] -> (loss, logits)."""
    x = images.astype(jnp.dtype(cfg.dtype))
    x = _conv(x, params["stem"]["conv"], stride=2)
    x = jax.nn.relu(_bn(x, params["stem"]["bn"]))
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
    for si in range(len(cfg.stage_sizes)):
        stage = params[f"stage{si}"]
        for bi in range(cfg.stage_sizes[si]):
            blk = stage[f"block{bi}"]
            stride = 2 if (bi == 0 and si > 0) else 1
            identity = x
            if cfg.block == "bottleneck":
                y = jax.nn.relu(_bn(_conv(x, blk["conv1"]), blk["bn1"]))
                y = jax.nn.relu(_bn(_conv(y, blk["conv2"], stride),
                                    blk["bn2"]))
                y = _bn(_conv(y, blk["conv3"]), blk["bn3"])
            else:
                y = jax.nn.relu(_bn(_conv(x, blk["conv1"], stride),
                                    blk["bn1"]))
                y = _bn(_conv(y, blk["conv2"]), blk["bn2"])
            if "proj" in blk:
                identity = _bn(_conv(x, blk["proj"], stride),
                               blk["proj_bn"])
            x = jax.nn.relu(y + identity)
            if cfg.block_barriers:
                x = barrier(x)
    x = x.mean(axis=(1, 2))
    logits = (x.astype(jnp.float32)
              @ params["head"].astype(jnp.float32))
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - gold), logits


def resnet_arch_config(arch: str) -> ResNetConfig:
    """``"resnet50"`` -> :class:`ResNetConfig` (campaign ``arch`` ids)."""
    if not arch.startswith("resnet"):
        raise ValueError(f"not a resnet arch id: {arch!r}")
    suffix = arch[len("resnet"):]
    if not suffix.isdigit() or int(suffix) not in _STAGES:
        raise ValueError(
            f"unknown resnet depth in {arch!r}; have {sorted(_STAGES)}")
    return ResNetConfig(depth=int(suffix))


def resnet_train_exports(cfg: ResNetConfig, batch: int, img: int, mesh=None,
                         opt_cfg=None):
    """Jitted ResNet train step + abstract args for workload export.

    Data-parallel fig-7 configuration: loss + grad + optimizer update
    (AdamW by default; any :class:`OptimizerConfig`), FP16 images sharded
    over the mesh "data" axis.  Shared by the fig7 benchmark loop and
    the campaign engine's ``mode="train"`` resnet export, so both
    produce the identical StableHLO/HLO pair.

    Returns ``(jitted_step, (params_abs, opt_abs, images_abs, labels_abs))``.
    """
    from ..distributed.sharding import act_sharding
    from ..models.params import abstract_params
    from ..train.optimizer import (OptimizerConfig, make_optimizer,
                                   opt_state_abstract)

    specs = resnet_specs(cfg)
    opt_cfg = opt_cfg or OptimizerConfig(name="adamw")
    _, update_fn = make_optimizer(opt_cfg)

    def step(params, opt, images, labels):
        loss, grads = jax.value_and_grad(
            lambda p: resnet_forward(cfg, p, images, labels)[0])(params)
        params, opt, _ = update_fn(params, grads, opt, opt_cfg)
        return params, opt, loss

    jitted = jax.jit(step, donate_argnums=(0, 1))
    params_abs = abstract_params(specs, mesh)
    if mesh is None:
        imgs = jax.ShapeDtypeStruct((batch, img, img, 3), jnp.float16)
        lbls = jax.ShapeDtypeStruct((batch,), jnp.int32)
    else:
        img_sh = act_sharding(("batch", "seq", "seq", "embed"), mesh, None,
                              (batch, img, img, 3))
        lbl_sh = act_sharding(("batch",), mesh, None, (batch,))
        imgs = jax.ShapeDtypeStruct((batch, img, img, 3), jnp.float16,
                                    sharding=img_sh)
        lbls = jax.ShapeDtypeStruct((batch,), jnp.int32, sharding=lbl_sh)
    opt_abs = opt_state_abstract(specs, opt_cfg.name, mesh, None)
    return jitted, (params_abs, opt_abs, imgs, lbls)
