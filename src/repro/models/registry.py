"""Architecture registry: --arch <id> -> config + input specs.

``input_specs(cfg, shape, mesh)`` builds ShapeDtypeStruct stand-ins for
every model input (weak-type-correct, shardable, zero allocation) — the
dry-run contract."""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig

ARCH_IDS = [
    "mamba2-370m", "deepseek-67b", "stablelm-12b", "qwen2.5-32b",
    "gemma2-27b", "zamba2-2.7b", "deepseek-v3-671b", "mixtral-8x22b",
    "hubert-xlarge", "qwen2-vl-7b",
]
# paper-reproduction workload families (not part of the 40-cell matrix)
EXTRA_IDS = ["llama3-100m", "llama3-500m", "llama3-1b", "llama3-3b",
             "llama2-7b"]

_MODULE_FOR = {a: a.replace("-", "_").replace(".", "_") for a in
               ARCH_IDS + EXTRA_IDS}


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULE_FOR[arch]}")
    return mod.SMOKE


def shape_cells(cfg: ModelConfig) -> list[str]:
    """Which shape cells apply to this architecture (assignment rules)."""
    cells = ["train_4k", "prefill_32k"]
    if not cfg.is_encoder_only:
        cells.append("decode_32k")
        subquadratic = (cfg.family in ("ssm", "hybrid")
                        or (cfg.sliding_window > 0
                            and cfg.local_global_pattern == 0))
        if subquadratic:
            cells.append("long_500k")
    return cells


def skip_reason(cfg: ModelConfig, shape_name: str) -> str | None:
    if shape_name in shape_cells(cfg):
        return None
    if cfg.is_encoder_only and shape_name in ("decode_32k", "long_500k"):
        return "encoder-only: no autoregressive decode step"
    if shape_name == "long_500k":
        return "full-quadratic attention at 524288 tokens (see DESIGN.md)"
    return "not applicable"


def input_specs(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                rules=None, seq_sharded: bool = False) -> dict:
    """ShapeDtypeStruct stand-ins for one step's inputs."""
    from ..distributed.sharding import ShardingRules, act_sharding

    b, s = shape.global_batch, shape.seq_len
    r = rules or ShardingRules()
    if seq_sharded:
        from ..distributed.sharding import ACT_RULES_SEQ_SHARDED
        r = ShardingRules(r.param_rules, dict(ACT_RULES_SEQ_SHARDED))

    def sds(shp, dtype, axes):
        if mesh is None:
            return jax.ShapeDtypeStruct(shp, jnp.dtype(dtype))
        return jax.ShapeDtypeStruct(
            shp, jnp.dtype(dtype), sharding=act_sharding(axes, mesh, r, shp))

    batch: dict = {}
    if shape.kind == "decode":
        lead = (b, 1)
    else:
        lead = (b, s)
    if cfg.frontend == "stub":
        batch["embeds"] = sds((*lead, cfg.d_model), cfg.dtype,
                              ("batch", "seq", "embed"))
    else:
        batch["tokens"] = sds(lead, "int32", ("batch", "seq"))
    if shape.kind == "train":
        batch["targets"] = sds(lead, "int32", ("batch", "seq"))
    if cfg.mrope_sections and shape.kind != "decode":
        batch["mrope_positions"] = sds((3, *lead), "int32",
                                       ("norm", "batch", "seq"))
    return batch


def cache_specs_abstract(cfg: ModelConfig, shape: ShapeConfig, mesh=None,
                         rules=None, seq_sharded: bool = False) -> dict:
    from ..distributed.sharding import ShardingRules, act_sharding
    from .transformer import cache_shapes

    r = rules or ShardingRules()
    if seq_sharded:
        from ..distributed.sharding import ACT_RULES_SEQ_SHARDED
        r = ShardingRules(r.param_rules, dict(ACT_RULES_SEQ_SHARDED))
    out = {}
    for name, (shp, dtype, axes) in cache_shapes(
            cfg, shape.global_batch, shape.seq_len).items():
        if mesh is None:
            out[name] = jax.ShapeDtypeStruct(shp, jnp.dtype(dtype))
        else:
            out[name] = jax.ShapeDtypeStruct(
                shp, jnp.dtype(dtype),
                sharding=act_sharding(axes, mesh, r, shp))
    return out
