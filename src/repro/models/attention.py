"""Attention: GQA (with SWA / local-global / softcap / bias) and MLA.

Three implementations behind one interface:
  * dense   — materialized [Sq, Skv] scores (small shapes, oracle)
  * chunked — online-softmax scan over KV blocks (pure JAX flash attention;
              memory O(Sq · block) — required for 32k prefill)
  * pallas  — TPU kernel (repro.kernels.flash_attention), same math

Decode (Sq == 1) always uses the dense path over the KV cache; with a
sequence-sharded cache, XLA turns the softmax reductions into the
all-reduce pair of flash-decoding.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .common import dense, rms_norm, softcap
from .params import ParamSpec
from .rope import apply_mrope, apply_rope

NEG_INF = -2.0e38


# --------------------------------------------------------------------------
# parameter specs
# --------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, stacked: int = 0) -> dict:
    """GQA projection specs; ``stacked``>0 prepends a layer axis (for scan)."""
    d, h, kv, hd = (cfg.d_model, cfg.num_heads + cfg.pad_heads,
                    cfg.num_kv_heads, cfg.resolved_head_dim)
    if cfg.pad_heads:
        assert h % kv == 0, (h, kv)
    dt = cfg.dtype

    def p(shape, axes, **kw):
        if stacked:
            return ParamSpec((stacked, *shape), ("layers", *axes),
                             dtype=dt, **kw)
        return ParamSpec(shape, axes, dtype=dt, **kw)

    specs = {
        "wq": p((d, h, hd), ("embed", "heads", "qk_dim"), init="scaled"),
        "wk": p((d, kv, hd), ("embed", "kv_heads", "qk_dim"), init="scaled"),
        "wv": p((d, kv, hd), ("embed", "kv_heads", "v_dim"), init="scaled"),
        "wo": p((h, hd, d), ("heads", "v_dim", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = p((h, hd), ("heads", "qk_dim"), init="zeros")
        specs["bk"] = p((kv, hd), ("kv_heads", "qk_dim"), init="zeros")
        specs["bv"] = p((kv, hd), ("kv_heads", "v_dim"), init="zeros")
    return specs


def mla_specs(cfg: ModelConfig, stacked: int = 0) -> dict:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    dt = cfg.dtype
    qk = m.qk_nope_head_dim

    def p(shape, axes, **kw):
        if stacked:
            return ParamSpec((stacked, *shape), ("layers", *axes),
                             dtype=dt, **kw)
        return ParamSpec(shape, axes, dtype=dt, **kw)

    return {
        "wdq": p((d, m.q_lora_rank), ("embed", "lora"), init="scaled"),
        "q_norm": p((m.q_lora_rank,), ("norm",), init="ones"),
        "wuq": p((m.q_lora_rank, h, qk + m.qk_rope_head_dim),
                 ("lora", "heads", "qk_dim"), init="scaled"),
        "wdkv": p((d, m.kv_lora_rank + m.qk_rope_head_dim),
                  ("embed", "lora"), init="scaled"),
        "kv_norm": p((m.kv_lora_rank,), ("norm",), init="ones"),
        "wuk": p((m.kv_lora_rank, h, qk), ("lora", "heads", "qk_dim"),
                 init="scaled"),
        "wuv": p((m.kv_lora_rank, h, m.v_head_dim),
                 ("lora", "heads", "v_dim"), init="scaled"),
        "wo": p((h, m.v_head_dim, d), ("heads", "v_dim", "embed"),
                init="scaled"),
    }


# --------------------------------------------------------------------------
# masking
# --------------------------------------------------------------------------

def _apply_window(mask: jax.Array, diff: jax.Array, window) -> jax.Array:
    """Sliding-window constraint; ``window`` may be a static int or a traced
    scalar (gemma2 alternates local/global inside a layer scan — the window
    is data there, 0 meaning full attention)."""
    if isinstance(window, int):
        if window <= 0:
            return mask
        return mask & (diff < window)
    w = jnp.asarray(window)
    return mask & ((diff < w) | (w <= 0))


def _block_mask(q_idx: jax.Array, k_idx: jax.Array, *, causal: bool,
                window) -> jax.Array:
    """[Sq, Skv] boolean mask from absolute indices."""
    diff = q_idx[:, None] - k_idx[None, :]
    mask = jnp.ones(diff.shape, dtype=bool)
    if causal:
        mask &= diff >= 0
    return _apply_window(mask, diff, window)


# --------------------------------------------------------------------------
# core attention (dense / chunked)
# --------------------------------------------------------------------------

class AttnArgs(NamedTuple):
    causal: bool = True
    window: int = 0              # >0: sliding window
    logit_cap: float = 0.0
    q_offset: int = 0            # absolute position of q[0] (decode/prefill)


def _dense_attention(q, k, v, args: AttnArgs) -> jax.Array:
    """q: [B, Hq, Sq, D], k/v: [B, Hkv, Skv, D] -> [B, Hq, Sq, D]."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, dh)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    scores = softcap(scores, args.logit_cap)
    q_idx = jnp.arange(sq) + args.q_offset
    k_idx = jnp.arange(skv)
    mask = _block_mask(q_idx, k_idx, causal=args.causal, window=args.window)
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def _chunked_attention(q, k, v, args: AttnArgs, chunk: int) -> jax.Array:
    """Online-softmax scan over KV chunks — the flash-attention recurrence."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    group = hq // hkv
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(b, hkv, n_chunks, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, hkv, n_chunks, chunk, dv).transpose(2, 0, 1, 3, 4)
    qg = (q.reshape(b, hkv, group, sq, dh).astype(jnp.float32)
          / math.sqrt(dh))
    q_idx = jnp.arange(sq) + args.q_offset

    def body(carry, inputs):
        m, l, acc = carry
        ci, (kb, vb) = inputs
        scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kb.astype(jnp.float32))
        scores = softcap(scores, args.logit_cap)
        k_idx = ci * chunk + jnp.arange(chunk)
        valid = k_idx < skv
        diff = q_idx[:, None] - k_idx[None, :]
        mask = jnp.broadcast_to(valid[None, :], diff.shape)
        if args.causal:
            mask = mask & (diff >= 0)
        mask = _apply_window(mask, diff, args.window)
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        m_new = jnp.maximum(m, scores.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(scores - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bhkd->bhgqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    acc0 = jnp.zeros((b, hkv, group, sq, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(n_chunks), (kc, vc)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, hq, sq, dv).astype(q.dtype)


def multihead_attention(q, k, v, args: AttnArgs, impl: str = "chunked",
                        chunk: int = 1024) -> jax.Array:
    if impl == "pallas":
        from ..kernels.flash_attention.ops import flash_attention
        try:
            return flash_attention(q, k, v, causal=args.causal,
                                   window=args.window,
                                   logit_cap=args.logit_cap,
                                   q_offset=args.q_offset)
        except Exception:
            impl = "chunked"  # CPU path: fall back to the jnp recurrence
    if impl == "dense" or q.shape[2] == 1:
        return _dense_attention(q, k, v, args)
    if q.shape[2] <= chunk and k.shape[2] <= chunk:
        return _dense_attention(q, k, v, args)
    return _chunked_attention(q, k, v, args, chunk)


# --------------------------------------------------------------------------
# GQA layer (projections + rope + attention)
# --------------------------------------------------------------------------

def _head_mask(cfg: ModelConfig, out: jax.Array) -> jax.Array:
    """Zero padded-head outputs (out: [..., H+pad, hd]) before W_o.

    GQA maps query head i to kv head i // group_size, so padding must be
    distributed per group (pad % kv == 0) and the real heads of group g
    occupy positions [g·group_new, g·group_new + group_old); masking those
    positions' complement keeps the padding mathematically invisible in
    both passes (pad-row gradients are identically zero)."""
    if not cfg.pad_heads:
        return out
    kv = cfg.num_kv_heads
    assert cfg.pad_heads % kv == 0, (cfg.pad_heads, kv)
    group_new = (cfg.num_heads + cfg.pad_heads) // kv
    group_old = cfg.num_heads // kv
    h_total = cfg.num_heads + cfg.pad_heads
    mask = ((jnp.arange(h_total) % group_new) < group_old).astype(out.dtype)
    return out * mask[..., :, None]


def gqa_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array, *, layer_window: int = 0,
                mrope_positions: jax.Array | None = None) -> jax.Array:
    """Full-sequence GQA for train/prefill. x: [B, S, d]."""
    b, s, d = x.shape
    h, kv, hd = (cfg.num_heads + cfg.pad_heads, cfg.num_kv_heads,
                 cfg.resolved_head_dim)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.mrope_sections and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
    elif cfg.causal or cfg.family == "audio":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    args = AttnArgs(causal=cfg.causal, window=layer_window,
                    logit_cap=cfg.attn_logit_softcap)
    out = multihead_attention(
        q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), args, impl=cfg.attn_impl,
        chunk=cfg.attn_chunk)
    out = out.transpose(0, 2, 1, 3)                      # [B, S, H, hd]
    out = _head_mask(cfg, out)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache_k: jax.Array,
               cache_v: jax.Array, cache_index: jax.Array, *,
               layer_window: int = 0) -> tuple[jax.Array, jax.Array, jax.Array]:
    """One-token decode. x: [B, 1, d]; cache_k/v: [B, S_max, kv, hd].

    Returns (attn_out [B,1,d], new_cache_k, new_cache_v).  With SWA the
    cache is a rolling buffer of size ``window``; absolute positions are
    recovered from ``cache_index``.
    """
    b = x.shape[0]
    s_max = cache_k.shape[1]
    pos = jnp.full((b, 1), cache_index, dtype=jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    if cfg.mrope_sections:
        pos3 = jnp.broadcast_to(pos, (3, b, 1))
        q = apply_mrope(q, pos3, cfg.mrope_sections, cfg.rope_theta)
        k = apply_mrope(k, pos3, cfg.mrope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    # ring buffer iff the window is a static int and the cache was sized to
    # it (pure-SWA archs, e.g. Mixtral).  Dynamic (traced) windows — gemma2's
    # local/global alternation — use a full-length cache with masking.
    ring = isinstance(layer_window, int) and 0 < layer_window >= s_max
    slot = jnp.mod(cache_index, s_max) if ring else cache_index
    cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))
    # scores over the cache; mask invalid (future / unwritten) slots
    kt = cache_k.transpose(0, 2, 1, 3)                   # [B, kv, S, hd]
    vt = cache_v.transpose(0, 2, 1, 3)
    qt = q.transpose(0, 2, 1, 3)                         # [B, H, 1, hd]
    hq, hkv = qt.shape[1], kt.shape[1]
    group = hq // hkv
    qg = qt.reshape(b, hkv, group, 1, -1).astype(jnp.float32)
    scores = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kt.astype(jnp.float32))
    scores = scores / math.sqrt(qt.shape[-1])
    scores = softcap(scores, cfg.attn_logit_softcap)
    slot_idx = jnp.arange(s_max)
    if ring:
        valid = slot_idx < jnp.minimum(cache_index + 1, s_max)
    else:
        valid = slot_idx <= cache_index
        if not (isinstance(layer_window, int) and layer_window == 0):
            w = jnp.asarray(layer_window)
            in_window = (cache_index - slot_idx < w) | (w <= 0)
            valid = valid & in_window
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, vt.astype(jnp.float32))
    out = out.reshape(b, hq, 1, -1).transpose(0, 2, 1, 3).astype(x.dtype)
    out = _head_mask(cfg, out)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache_k, cache_v


# --------------------------------------------------------------------------
# MLA (DeepSeek-V3)
# --------------------------------------------------------------------------

def mla_forward(cfg: ModelConfig, p: dict, x: jax.Array,
                positions: jax.Array) -> jax.Array:
    """Materialized MLA for train/prefill."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    cq = rms_norm(dense(x, p["wdq"]), p["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = dense(x, p["wdkv"])                       # [B,S,rank+rope]
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["wuk"])
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["wuv"])
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_head_dim))],
        axis=-1)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    args = AttnArgs(causal=True, logit_cap=0.0)
    out = multihead_attention(
        qf.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
        v.transpose(0, 2, 1, 3), args, impl=cfg.attn_impl,
        chunk=cfg.attn_chunk)
    out = out.transpose(0, 2, 1, 3)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache_ckv: jax.Array,
               cache_index: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Absorbed-form MLA decode against the *compressed* KV cache.

    cache_ckv: [B, S_max, kv_lora_rank + qk_rope_head_dim] — the DeepSeek
    inference trick: W_uk is absorbed into the query, W_uv into the output,
    so per-step compute and cache stay in the compressed space.
    """
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    s_max = cache_ckv.shape[1]
    pos = jnp.full((b, 1), cache_index, dtype=jnp.int32)

    cq = rms_norm(dense(x, p["wdq"]), p["q_norm"], cfg.rms_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    # absorb W_uk: q_c[b,1,h,rank] = q_nope . W_uk^T
    q_c = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])

    ckv_full = dense(x, p["wdkv"])
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv = rms_norm(ckv, p["kv_norm"], cfg.rms_eps)
    k_rope = apply_rope(k_rope[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0, :]
    entry = jnp.concatenate([ckv, k_rope], axis=-1)
    cache_ckv = jax.lax.dynamic_update_slice(
        cache_ckv, entry, (0, cache_index, 0))

    c_k = cache_ckv[:, :, :m.kv_lora_rank].astype(jnp.float32)
    r_k = cache_ckv[:, :, m.kv_lora_rank:].astype(jnp.float32)
    scores = (jnp.einsum("bshr,btr->bhst", q_c.astype(jnp.float32), c_k)
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32), r_k))
    scores = scores / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    valid = jnp.arange(s_max) <= cache_index
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", probs, c_k)        # compressed ctx
    out = jnp.einsum("bshr,rhk->bshk", ctx.astype(x.dtype), p["wuv"])
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, cache_ckv
