"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (int)."""
    freqs = rope_freqs(x.shape[-1], theta)                    # [D/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [B, S, D/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions3: jax.Array,
                sections: tuple[int, ...], theta: float = 10000.0) -> jax.Array:
    """Qwen2-VL multimodal RoPE.

    x: [B, S, H, D]; positions3: [3, B, S] (temporal, height, width ids —
    equal to the text position for pure-text tokens).  The D/2 frequency
    channels are partitioned into ``sections`` (t, h, w); each section's
    angle uses the corresponding position stream.
    """
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                    # [D/2]
    # angles per stream: [3, B, S, D/2]
    angles = positions3.astype(jnp.float32)[..., None] * freqs
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(angles[i, :, :, start:start + sec])
        start += sec
    ang = jnp.concatenate(parts, axis=-1)                     # [B, S, D/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
