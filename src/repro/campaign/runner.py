"""Campaign execution: expand the grid, run jobs in parallel, stream
results, share one persistent (H, C, R) cache across all of it.

Executors:

  * ``serial``  — in-process, deterministic order;
  * ``thread``  — ThreadPoolExecutor; jobs share one live cache store, so a
    fingerprint evaluated by one job is a hit for every later job;
  * ``process`` — ProcessPoolExecutor.  With a ``cache_path``, every
    worker opens the same file-locked append-log store: misses are
    written through immediately and lookups tail the log, so workers
    observe each other's fresh entries *mid-campaign*.  Without a path,
    each worker falls back to a startup snapshot and ships its fresh
    entries back for the parent to merge.

Results stream to ``results.jsonl`` as jobs finish (crash-safe: a killed
campaign keeps everything completed so far), then consolidate into
``results.csv`` and ``summary.json``.
"""
from __future__ import annotations

import csv
import json
import os
import threading
import time
from concurrent.futures import (FIRST_COMPLETED, ProcessPoolExecutor,
                                ThreadPoolExecutor, wait)
from dataclasses import dataclass, field

from ..core.estimators.cache import PersistentCache
from ..core.pipeline import PredictionJob, Workload
from .builders import (build_estimator, build_system, build_topology,
                       build_workload)
from .spec import CampaignSpec, JobSpec
from .summary import summarize

EXECUTORS = ("serial", "thread", "process")

# -------------------------- single-job execution --------------------------


def _program_for(job: JobSpec, texts: dict, programs: dict,
                 lock: threading.Lock | None = None):
    """Parse (memoized) the right fidelity of the job's workload.

    Returns (program, effective_fidelity) — the fidelity actually used,
    which falls back optimized -> raw when no optimized HLO exists."""
    from ..core.ir.parser import parse

    wtexts = texts[job.workload]
    fidelity = job.fidelity
    if fidelity == "optimized" and not wtexts.get("optimized"):
        fidelity = "raw"
    key = (job.workload, fidelity)

    def lookup_or_parse():
        if key not in programs:
            text = wtexts.get(fidelity)
            if text is None:
                raise ValueError(
                    f"workload {job.workload!r}: no {fidelity} text")
            programs[key] = parse(text)
        return programs[key]

    if lock:
        # parse under the lock: concurrent first jobs of a thread campaign
        # would otherwise each pay the (expensive) parse of the same text
        with lock:
            return lookup_or_parse(), fidelity
    return lookup_or_parse(), fidelity


def _execute(job: JobSpec, texts: dict, programs: dict, store,
             lock: threading.Lock | None = None) -> tuple[dict, dict]:
    """Run one grid point; returns (result_row, freshly_computed_entries)."""
    t0 = time.perf_counter()
    program, fidelity = _program_for(job, texts, programs, lock)
    system = build_system(job.system)
    estimator = build_estimator(job.estimator, system,
                                system_name=job.system, program=program)
    topology = build_topology(job.topology, system)
    pjob = PredictionJob(
        program=program, estimator=estimator, topology=topology,
        slicer=job.slicer, overlap=job.overlap,
        straggler_factor=job.straggler_factor, compression=job.compression,
        name=job.workload, system_name=system.name, cache_store=store)
    p = pjob.run()
    row = dict(job.to_row())
    row["fidelity"] = fidelity  # the fidelity actually costed
    pred = p.to_row()
    row["toolchain"] = pred.pop("estimator")
    for k in ("workload", "system", "slicer"):
        pred.pop(k, None)
    row.update(pred)
    row["job_wall_s"] = time.perf_counter() - t0
    return row, dict(pjob.cached.new_entries)


# process-pool worker state (one store per worker process)
_WORKER: dict = {}


def _worker_init(texts: dict, cache_entries: dict,
                 cache_path: str | None = None) -> None:
    """Per-worker setup.  With a ``cache_path`` the worker opens the
    shared file-locked store — live view, write-through appends; without
    one it degrades to a private snapshot of the parent's entries."""
    _WORKER["texts"] = texts
    _WORKER["programs"] = {}
    if cache_path:
        _WORKER["store"] = PersistentCache(cache_path)
    else:
        _WORKER["store"] = dict(cache_entries)


def _worker_run(job: JobSpec) -> tuple[dict, dict]:
    """Execute one job against this worker's store; returns the result
    row plus the ``key -> (value, cost)`` entries it computed itself."""
    return _execute(job, _WORKER["texts"], _WORKER["programs"],
                    _WORKER["store"])


# ------------------------------ the campaign ------------------------------


@dataclass
class CampaignResult:
    """Everything a finished campaign produced: job_id-ordered result
    rows (error rows included), the summary dict, paths of any streamed
    artifacts, wall time, and the cache report."""
    name: str
    rows: list[dict]                 # job_id-ordered; error rows included
    summary: dict
    jsonl_path: str | None = None
    csv_path: str | None = None
    summary_path: str | None = None
    wall_s: float = 0.0
    cache: dict = field(default_factory=dict)

    @property
    def ok_rows(self) -> list[dict]:
        return [r for r in self.rows if "error" not in r]


def _workload_texts(spec: CampaignSpec,
                    workloads: dict[str, Workload] | None) -> dict:
    """name -> {"raw": stablehlo, "optimized": hlo} for every grid workload.

    In-memory ``workloads`` take precedence; anything else is materialized
    from its spec (file read or jax export)."""
    provided = dict(workloads or {})
    texts: dict[str, dict] = {}
    for wspec in spec.workloads:
        w = provided.get(wspec.name)
        if w is None:
            w = build_workload(wspec)
        texts[wspec.name] = {"raw": w.stablehlo_text,
                             "optimized": w.hlo_text}
    return texts


def run_campaign(spec: CampaignSpec, *,
                 workloads: dict[str, Workload] | None = None,
                 out_dir: str | None = None,
                 executor: str = "serial",
                 max_workers: int | None = None,
                 cache_path: str | None = None,
                 progress: bool = False) -> CampaignResult:
    """Expand ``spec`` into jobs, run them, and collect/stream results.

    ``workloads`` supplies in-memory :class:`Workload` objects by name
    (anything else is materialized from its spec — file read, jax
    export, or GEMM synthesis).  ``cache_path`` points every job — and,
    under the process executor, every live worker — at one shared
    append-log (H, C, R) store; the log is compacted once on completion
    and the returned ``cache`` report includes the across-run
    ``time_saving_fraction`` from persisted per-key costs."""
    if executor not in EXECUTORS:
        raise ValueError(f"executor {executor!r} not in {EXECUTORS}")
    t0 = time.perf_counter()
    spec.validate(provided=set(workloads or {}))
    jobs = spec.expand()
    texts = _workload_texts(spec, workloads)

    cache = PersistentCache(cache_path) if cache_path else PersistentCache()
    loaded = cache.loaded_entries

    jsonl_path = None
    jsonl_file = None
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        jsonl_path = os.path.join(out_dir, "results.jsonl")
        jsonl_file = open(jsonl_path, "w")
    jsonl_lock = threading.Lock()

    def emit_row(row: dict) -> None:
        if jsonl_file:
            with jsonl_lock:
                jsonl_file.write(json.dumps(row) + "\n")
                jsonl_file.flush()
        if progress:
            tag = (f"{row['step_time_s'] * 1e3:9.3f} ms"
                   if "step_time_s" in row else f"ERROR {row.get('error')}")
            print(f"  [{row['job_id']:4d}/{len(jobs)}] "
                  f"{row['workload']} × {row['system']} × "
                  f"{row['estimator']} × {row['slicer']}: {tag}",
                  flush=True)

    rows: list[dict] = []
    new_entry_count = 0
    try:
        if executor == "process":
            rows, new_entry_count = _run_process_pool(
                jobs, texts, cache, max_workers, emit_row)
        else:
            rows, new_entry_count = _run_in_process(
                jobs, texts, cache, emit_row,
                max_workers if executor == "thread" else 0)
    finally:
        if jsonl_file:
            jsonl_file.close()

    rows.sort(key=lambda r: r["job_id"])
    if cache_path:
        cache.save(cache_path)

    total_hits = sum(r.get("cache_hits", 0) for r in rows)
    total_misses = sum(r.get("cache_misses", 0) for r in rows)
    saved = sum(r.get("cache_saved_s", 0.0) for r in rows)
    miss_cost = sum(r.get("cache_miss_cost_s", 0.0) for r in rows)
    wall = time.perf_counter() - t0
    cache_report = {
        "path": cache_path,
        "loaded_entries": loaded,
        "total_entries": len(cache),
        "new_entries": new_entry_count,
        "hits": total_hits,
        "misses": total_misses,
        "hit_rate": total_hits / (total_hits + total_misses)
        if total_hits + total_misses else 0.0,
        # the paper's §III-B(c) metric, across-run thanks to persisted
        # per-key evaluation costs: fraction of estimator wall time that
        # hits avoided (hits on entries from previous runs count too)
        "saved_seconds": saved,
        "miss_cost_seconds": miss_cost,
        "time_saving_fraction": saved / (saved + miss_cost)
        if (saved + miss_cost) > 0 else 0.0,
    }
    summary = summarize(spec.name, rows)
    summary["wall_s"] = wall
    summary["cache"] = cache_report

    csv_path = summary_path = None
    if out_dir:
        csv_path = os.path.join(out_dir, "results.csv")
        _write_csv(rows, csv_path)
        summary_path = os.path.join(out_dir, "summary.json")
        with open(summary_path, "w") as f:
            json.dump(summary, f, indent=2)

    return CampaignResult(
        name=spec.name, rows=rows, summary=summary, jsonl_path=jsonl_path,
        csv_path=csv_path, summary_path=summary_path, wall_s=wall,
        cache=cache_report)


def _run_in_process(jobs: list[JobSpec], texts: dict, cache: PersistentCache,
                    emit_row, thread_workers: int) -> tuple[list[dict], int]:
    """Serial or thread-pool execution over one shared live cache store."""
    programs: dict = {}
    lock = threading.Lock()
    new_keys: set[str] = set()
    rows: list[dict] = []
    rows_lock = threading.Lock()

    def run_one(job: JobSpec) -> None:
        try:
            row, new = _execute(job, texts, programs, cache, lock)
            new_keys.update(new)
        except Exception as e:  # noqa: BLE001 — keep the campaign going
            row = dict(job.to_row())
            row["error"] = f"{type(e).__name__}: {e}"
        with rows_lock:
            rows.append(row)
        emit_row(row)

    if thread_workers == 0:
        for job in jobs:
            run_one(job)
    else:
        with ThreadPoolExecutor(max_workers=thread_workers) as pool:
            futures = [pool.submit(run_one, j) for j in jobs]
            wait(futures)
            for f in futures:
                f.result()
    return rows, len(new_keys)


def _run_process_pool(jobs: list[JobSpec], texts: dict,
                      cache: PersistentCache, max_workers: int | None,
                      emit_row) -> tuple[list[dict], int]:
    """Process-pool execution.

    With a path-backed cache the workers share the live append-log store
    (see :func:`_worker_init`); fresh entries are additionally merged
    into the parent for accounting.  Pathless caches fall back to
    snapshot-out / merge-in."""
    import multiprocessing
    import sys

    # prefer spawn: the parent may hold live jax threads and fork of a
    # threaded process risks deadlock.  spawn re-imports __main__, which
    # only works when __main__ is a real file (CLI, pytest, scripts) —
    # fall back to fork for stdin/interactive parents.
    main_mod = sys.modules.get("__main__")
    method = ("spawn" if getattr(main_mod, "__file__", None)
              and os.path.exists(getattr(main_mod, "__file__"))
              else "fork")
    rows: list[dict] = []
    new_total = 0
    # path-backed workers open the shared store themselves — don't ship
    # them a (potentially large) snapshot they would never read
    snapshot = {} if cache.path else dict(cache.entries)
    with ProcessPoolExecutor(
            max_workers=max_workers, initializer=_worker_init,
            initargs=(texts, snapshot, cache.path),
            mp_context=multiprocessing.get_context(method)) as pool:
        pending = {pool.submit(_worker_run, j): j for j in jobs}
        while pending:
            done, _ = wait(pending, return_when=FIRST_COMPLETED)
            for fut in done:
                job = pending.pop(fut)
                try:
                    row, new = fut.result()
                    new_total += cache.merge(new)
                except Exception as e:  # noqa: BLE001
                    row = dict(job.to_row())
                    row["error"] = f"{type(e).__name__}: {e}"
                rows.append(row)
                emit_row(row)
    return rows, new_total


def _write_csv(rows: list[dict], path: str) -> None:
    """Consolidate result rows into one CSV (union of all columns)."""
    fields: list[str] = []
    for r in rows:
        for k in r:
            if k not in fields:
                fields.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fields)
        w.writeheader()
        w.writerows(rows)


def load_jsonl(path: str) -> list[dict]:
    """Read back a streamed results file."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
